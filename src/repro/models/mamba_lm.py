"""Attention-free Mamba2 LM (SSD) — mamba2-370m family.

Decode state is O(1) in sequence length, which is what makes the
``long_500k`` (524288-token context) cell feasible for this family.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_activation
from repro.models import layers as L
from repro.models import ssm
from repro.models.transformer import _remat, chunked_ce_loss

PyTree = Any


class Mamba2LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.pdt = L.dtype_of(cfg.param_dtype)
        self.cdt = L.dtype_of(cfg.dtype)

    def init(self, rng) -> PyTree:
        cfg = self.cfg
        k_emb, k_layers = jax.random.split(rng)

        def layer_init(k):
            return {
                "m": ssm.init_mamba_block(k, cfg, self.pdt),
                "ln": jnp.zeros((cfg.d_model,), self.pdt),
            }

        params = {
            "embed": L.embed_init(k_emb, (cfg.vocab_padded, cfg.d_model), self.pdt),
            "layers": jax.vmap(layer_init)(jax.random.split(k_layers, cfg.n_layers)),
            "final_norm": jnp.zeros((cfg.d_model,), self.pdt),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = L.dense_init(jax.random.fold_in(rng, 7),
                                             (cfg.d_model, cfg.vocab_padded), self.pdt)
        return params

    def _unembed(self, params):
        return params["embed"].T if self.cfg.tie_embeddings else params["unembed"]

    def _body(self, params, x):
        cfg = self.cfg

        def block(h, lp):
            h = shard_activation(h, "residual")
            y = ssm.mamba_forward(lp["m"], L.rms_norm(h, lp["ln"], cfg.norm_eps), cfg)
            return h + y, None

        x, _ = jax.lax.scan(_remat(block, cfg), x, params["layers"])
        return x

    def forward(self, params, batch) -> jax.Array:
        x = params["embed"].astype(self.cdt)[batch["tokens"]]
        x = self._body(params, x)
        x = L.rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        return (x @ self._unembed(params).astype(self.cdt)).astype(jnp.float32)

    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        x = params["embed"].astype(self.cdt)[batch["tokens"]]
        x = self._body(params, x)
        mask = batch.get("mask")
        if mask is None:
            mask = jnp.ones(batch["labels"].shape, jnp.float32)
        loss, cnt = chunked_ce_loss(x, self._unembed(params), batch["labels"], mask,
                                    norm_w=params["final_norm"], eps=self.cfg.norm_eps)
        return loss, {"loss": loss, "tokens": cnt}

    # ---------------- serve ----------------
    def cache_spec(self, batch_size: int, max_len: int = 0) -> PyTree:
        cfg = self.cfg
        conv_dim = cfg.d_inner + 2 * cfg.ssm_state
        return {
            "state": jax.ShapeDtypeStruct(
                (cfg.n_layers, batch_size, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state),
                jnp.float32),
            "conv": jax.ShapeDtypeStruct(
                (cfg.n_layers, batch_size, cfg.ssm_conv - 1, conv_dim), self.cdt),
        }

    def init_cache(self, batch_size: int, max_len: int = 0) -> PyTree:
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), self.cache_spec(batch_size))

    def prefill(self, params, batch) -> Tuple[jax.Array, PyTree]:
        cfg = self.cfg
        x = params["embed"].astype(self.cdt)[batch["tokens"]]

        def block(h, lp):
            y, st, conv = ssm.mamba_forward(lp["m"], L.rms_norm(h, lp["ln"], cfg.norm_eps),
                                            cfg, return_cache=True)
            return h + y, (st, conv)

        x, (states, convs) = jax.lax.scan(_remat(block, cfg), x, params["layers"])
        x = L.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        logits = (x @ self._unembed(params).astype(self.cdt))[:, 0].astype(jnp.float32)
        return logits, {"state": states, "conv": convs}

    def decode_step(self, params, cache, tokens) -> Tuple[jax.Array, PyTree]:
        cfg = self.cfg
        x = params["embed"].astype(self.cdt)[tokens][:, None]

        def block(h, xs):
            lp, st, conv = xs
            y, nst, nconv = ssm.mamba_step(lp["m"], L.rms_norm(h, lp["ln"], cfg.norm_eps),
                                           cfg, st, conv)
            return h + y, (nst, nconv)

        x, (nstates, nconvs) = jax.lax.scan(block, x, (params["layers"], cache["state"], cache["conv"]))
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = (x @ self._unembed(params).astype(self.cdt))[:, 0].astype(jnp.float32)
        return logits, {"state": nstates, "conv": nconvs}
