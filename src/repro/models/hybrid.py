"""Zamba2-style hybrid: Mamba2 backbone + *shared* attention blocks.

``cfg.n_layers`` Mamba2 blocks; after every ``cfg.shared_every`` blocks one
of ``cfg.n_shared`` alternating shared transformer blocks (full attention +
SwiGLU MLP, weights reused across applications) is applied.  Each shared
application keeps its own KV cache at decode time (inputs differ per depth).

Simplification vs. the released Zamba2 checkpoints: we share weights exactly
(no per-application LoRA deltas) — noted in DESIGN.md.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_activation
from repro.models import layers as L
from repro.models import ssm
from repro.models.transformer import _remat, chunked_ce_loss

PyTree = Any


def _segments(cfg: ModelConfig) -> List[int]:
    full, rem = divmod(cfg.n_layers, cfg.shared_every)
    segs = [cfg.shared_every] * full
    if rem:
        segs.append(rem)
    return segs


def _n_apps(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.shared_every


def _tree_slice(tree, start: int, size: int):
    return jax.tree.map(lambda a: jax.lax.slice_in_dim(a, start, start + size, axis=0), tree)


def _tree_index(tree, idx: int):
    return jax.tree.map(lambda a: a[idx], tree)


class Zamba2LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.pdt = L.dtype_of(cfg.param_dtype)
        self.cdt = L.dtype_of(cfg.dtype)

    def init(self, rng) -> PyTree:
        cfg = self.cfg
        k_emb, k_m, k_s, k_un = jax.random.split(rng, 4)

        def mamba_layer(k):
            return {"m": ssm.init_mamba_block(k, cfg, self.pdt),
                    "ln": jnp.zeros((cfg.d_model,), self.pdt)}

        def shared_block(k):
            ka, kf = jax.random.split(k)
            return {"attn": L.init_attn(ka, cfg, self.pdt),
                    "mlp": L.init_mlp(kf, cfg, self.pdt),
                    "ln1": jnp.zeros((cfg.d_model,), self.pdt),
                    "ln2": jnp.zeros((cfg.d_model,), self.pdt)}

        return {
            "embed": L.embed_init(k_emb, (cfg.vocab_padded, cfg.d_model), self.pdt),
            "layers": jax.vmap(mamba_layer)(jax.random.split(k_m, cfg.n_layers)),
            "shared": jax.vmap(shared_block)(jax.random.split(k_s, cfg.n_shared)),
            "final_norm": jnp.zeros((cfg.d_model,), self.pdt),
            "unembed": L.dense_init(k_un, (cfg.d_model, cfg.vocab_padded), self.pdt),
        }

    # ---------------- full-sequence body ----------------
    def _shared_fwd(self, sp, h, positions):
        cfg = self.cfg
        a = L.attn_forward(sp["attn"], L.rms_norm(h, sp["ln1"], cfg.norm_eps),
                           cfg, positions, causal=True)
        h = h + a
        f = L.mlp_forward(sp["mlp"], L.rms_norm(h, sp["ln2"], cfg.norm_eps))
        return h + f

    def _body(self, params, x, positions):
        cfg = self.cfg

        def mblock(h, lp):
            h = shard_activation(h, "residual")
            y = ssm.mamba_forward(lp["m"], L.rms_norm(h, lp["ln"], cfg.norm_eps), cfg)
            return h + y, None

        mblock = _remat(mblock, cfg)
        start = 0
        for i, size in enumerate(_segments(cfg)):
            seg = _tree_slice(params["layers"], start, size)
            x, _ = jax.lax.scan(mblock, x, seg)
            start += size
            if size == cfg.shared_every:  # a full segment is followed by a shared block
                sp = _tree_index(params["shared"], i % cfg.n_shared)
                x = self._shared_fwd(sp, x, positions)
        return x

    def forward(self, params, batch) -> jax.Array:
        x = params["embed"].astype(self.cdt)[batch["tokens"]]
        positions = jnp.arange(x.shape[1])[None, :]
        x = self._body(params, x, positions)
        x = L.rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        return (x @ params["unembed"].astype(self.cdt)).astype(jnp.float32)

    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        x = params["embed"].astype(self.cdt)[batch["tokens"]]
        positions = jnp.arange(x.shape[1])[None, :]
        x = self._body(params, x, positions)
        mask = batch.get("mask")
        if mask is None:
            mask = jnp.ones(batch["labels"].shape, jnp.float32)
        loss, cnt = chunked_ce_loss(x, params["unembed"], batch["labels"], mask,
                                    norm_w=params["final_norm"], eps=self.cfg.norm_eps)
        return loss, {"loss": loss, "tokens": cnt}

    # ---------------- serve ----------------
    def cache_spec(self, batch_size: int, max_len: int) -> PyTree:
        cfg = self.cfg
        conv_dim = cfg.d_inner + 2 * cfg.ssm_state
        napps = _n_apps(cfg)
        kv = jax.ShapeDtypeStruct(
            (napps, batch_size, max_len, cfg.n_kv_heads, cfg.head_dim), self.cdt)
        return {
            "state": jax.ShapeDtypeStruct(
                (cfg.n_layers, batch_size, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state),
                jnp.float32),
            "conv": jax.ShapeDtypeStruct(
                (cfg.n_layers, batch_size, cfg.ssm_conv - 1, conv_dim), self.cdt),
            "k": kv, "v": kv,
            "len": jax.ShapeDtypeStruct((), jnp.int32),
        }

    def init_cache(self, batch_size: int, max_len: int) -> PyTree:
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_spec(batch_size, max_len))

    def prefill(self, params, batch, max_len=None) -> Tuple[jax.Array, PyTree]:
        cfg = self.cfg
        x = params["embed"].astype(self.cdt)[batch["tokens"]]
        b, s, _ = x.shape
        positions = jnp.arange(s)[None, :]
        hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

        def mblock(h, lp):
            y, st, conv = ssm.mamba_forward(lp["m"], L.rms_norm(h, lp["ln"], cfg.norm_eps),
                                            cfg, return_cache=True)
            return h + y, (st, conv)

        mblock = _remat(mblock, cfg)
        states, convs, ks, vs = [], [], [], []
        start = 0
        for i, size in enumerate(_segments(cfg)):
            seg = _tree_slice(params["layers"], start, size)
            x, (st, cv) = jax.lax.scan(mblock, x, seg)
            states.append(st)
            convs.append(cv)
            start += size
            if size == cfg.shared_every:
                sp = _tree_index(params["shared"], i % cfg.n_shared)
                hn = L.rms_norm(x, sp["ln1"], cfg.norm_eps)
                q = (hn @ sp["attn"]["wq"].astype(self.cdt)).reshape(b, s, hq, dh)
                k = (hn @ sp["attn"]["wk"].astype(self.cdt)).reshape(b, s, hkv, dh)
                v = (hn @ sp["attn"]["wv"].astype(self.cdt)).reshape(b, s, hkv, dh)
                q = L.apply_rope(q, positions, cfg.rope_theta)
                k = L.apply_rope(k, positions, cfg.rope_theta)
                o = L.attention_chunked(q, k, v, causal=True, chunk=cfg.attn_chunk)
                x = x + o.reshape(b, s, hq * dh) @ sp["attn"]["wo"].astype(self.cdt)
                x = x + L.mlp_forward(sp["mlp"], L.rms_norm(x, sp["ln2"], cfg.norm_eps))
                ks.append(k)
                vs.append(v)
        kst, vst = jnp.stack(ks), jnp.stack(vs)
        if max_len is not None and max_len > s:
            pad = ((0, 0), (0, 0), (0, max_len - s), (0, 0), (0, 0))
            kst, vst = jnp.pad(kst, pad), jnp.pad(vst, pad)
        cache = {
            "state": jnp.concatenate(states, 0), "conv": jnp.concatenate(convs, 0),
            "k": kst, "v": vst, "len": jnp.int32(s),
        }
        x = L.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        logits = (x @ params["unembed"].astype(self.cdt))[:, 0].astype(jnp.float32)
        return logits, cache

    def decode_step(self, params, cache, tokens) -> Tuple[jax.Array, PyTree]:
        cfg = self.cfg
        x = params["embed"].astype(self.cdt)[tokens][:, None]
        clen = cache["len"]

        def mstep(h, xs):
            lp, st, conv = xs
            y, nst, nconv = ssm.mamba_step(lp["m"], L.rms_norm(h, lp["ln"], cfg.norm_eps),
                                           cfg, st, conv)
            return h + y, (nst, nconv)

        nstates, nconvs, nks, nvs = [], [], [], []
        start = 0
        for i, size in enumerate(_segments(cfg)):
            seg = _tree_slice(params["layers"], start, size)
            st = jax.lax.slice_in_dim(cache["state"], start, start + size, axis=0)
            cv = jax.lax.slice_in_dim(cache["conv"], start, start + size, axis=0)
            x, (nst, ncv) = jax.lax.scan(mstep, x, (seg, st, cv))
            nstates.append(nst)
            nconvs.append(ncv)
            start += size
            if size == cfg.shared_every:
                sp = _tree_index(params["shared"], i % cfg.n_shared)
                hn = L.rms_norm(x, sp["ln1"], cfg.norm_eps)
                a, nk, nv = L.attn_decode_forward(sp["attn"], hn, cfg,
                                                  cache["k"][i], cache["v"][i], clen)
                x = x + a
                x = x + L.mlp_forward(sp["mlp"], L.rms_norm(x, sp["ln2"], cfg.norm_eps))
                nks.append(nk)
                nvs.append(nv)
        new_cache = {
            "state": jnp.concatenate(nstates, 0), "conv": jnp.concatenate(nconvs, 0),
            "k": jnp.stack(nks), "v": jnp.stack(nvs), "len": clen + 1,
        }
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = (x @ params["unembed"].astype(self.cdt))[:, 0].astype(jnp.float32)
        return logits, new_cache
