"""Mamba2 (state-space duality) blocks — chunked SSD forward + decode step.

The chunked algorithm follows arXiv:2405.21060 §6: within-chunk outputs are
computed with a masked attention-like quadratic form; chunk-boundary states
are carried with a ``lax.scan``.  ``ssd_reference`` is the O(S) sequential
oracle used by the tests; ``kernels/ssd`` is the Pallas TPU version of the
within-chunk compute.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, gated_rms_norm

# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def ssd_reference(x, dt, A, B, C, *, initial_state=None):
    """Sequential scan oracle.

    x:  [b, s, h, p]   (inputs, already multiplied by nothing)
    dt: [b, s, h]      (positive step sizes)
    A:  [h]            (negative decay rates)
    B:  [b, s, n]      (input projection, single group)
    C:  [b, s, n]      (output projection, single group)
    Returns (y [b,s,h,p], final_state [b,h,p,n]).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    state0 = initial_state if initial_state is not None else jnp.zeros((b, h, p, n), jnp.float32)

    def step(state, inp):
        xt, dtt, Bt, Ct = inp  # [b,h,p], [b,h], [b,n], [b,n]
        dA = jnp.exp(dtt * A)  # [b,h]
        dBx = jnp.einsum("bhp,bn,bh->bhpn", xt.astype(jnp.float32), Bt.astype(jnp.float32), dtt)
        state = state * dA[..., None, None] + dBx
        y = jnp.einsum("bhpn,bn->bhp", state, Ct.astype(jnp.float32))
        return state, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0), jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0))
    state, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), state


def ssd_chunked(x, dt, A, B, C, *, chunk: int, initial_state=None):
    """Chunked SSD with identical semantics to :func:`ssd_reference`.

    Work per chunk is O(L^2) attention-like + O(L·p·n) state math, giving the
    sub-quadratic O(S·L) total that makes the ``long_500k`` cell feasible.
    """
    b, s_orig, h, p = x.shape
    n = B.shape[-1]
    if s_orig % chunk != 0:
        # zero-pad the tail: dt=0 there => decay 1, dBx 0 => state unaffected.
        pad = chunk - s_orig % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    s = x.shape[1]
    nc = s // chunk
    f32 = jnp.float32
    xc = x.reshape(b, nc, chunk, h, p).astype(f32)
    dtc = dt.reshape(b, nc, chunk, h).astype(f32)
    Bc = B.reshape(b, nc, chunk, n).astype(f32)
    Cc = C.reshape(b, nc, chunk, n).astype(f32)

    dA = dtc * A  # [b,nc,l,h]
    cum = jnp.cumsum(dA, axis=2)  # inclusive cumsum along chunk
    # decay from j (exclusive) to i (inclusive): exp(cum_i - cum_j)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [b,nc,i,j,h]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(seg), 0.0)
    # intra-chunk: y[i] = sum_j<=i exp(cum_i-cum_j) dt_j (C_i·B_j) x_j
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)  # [b,nc,i,j]
    w = cb[..., None] * L * dtc[:, :, None, :, :]  # [b,nc,i,j,h]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xc)
    # chunk summary state: S_c = sum_j exp(cum_L - cum_j) dt_j B_j x_j
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # [b,nc,l,h]
    states = jnp.einsum("bclh,bclh,bcln,bclhp->bchpn", decay_to_end, dtc, Bc, xc)
    # carry across chunks: S_{c} (entering chunk c)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # [b,nc,h]
    state0 = initial_state if initial_state is not None else jnp.zeros((b, h, p, n), f32)

    def carry(stat, inp):
        st_c, dec_c = inp  # [b,h,p,n], [b,h]
        out = stat
        new = stat * dec_c[..., None, None] + st_c
        return new, out

    final, prev_states = jax.lax.scan(
        carry, state0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # [b,nc,h,p,n] entering each chunk
    # inter-chunk contribution: y[i] += exp(cum_i) C_i · S_enter
    y_inter = jnp.einsum("bclh,bcln,bchpn->bclhp", jnp.exp(cum), Cc, prev_states)
    y = (y_intra + y_inter).reshape(b, s, h, p)[:, :s_orig]
    return y.astype(x.dtype), final


def ssd_step(state, x, dt, A, B, C):
    """Single decode step.  state: [b,h,p,n]; x: [b,h,p]; dt: [b,h]; B/C: [b,n]."""
    dA = jnp.exp(dt * A)
    dBx = jnp.einsum("bhp,bn,bh->bhpn", x.astype(jnp.float32), B.astype(jnp.float32), dt)
    state = state * dA[..., None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", state, C.astype(jnp.float32))
    return state, y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Mamba2 block (projections + conv + SSD + gated norm)
# ---------------------------------------------------------------------------


def init_mamba_block(key, cfg: ModelConfig, pdt) -> Dict[str, jax.Array]:
    d, di, n, nh, w = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv
    conv_dim = di + 2 * n
    ks = jax.random.split(key, 6)
    dt_bias = jnp.log(jnp.expm1(jnp.exp(jax.random.uniform(
        ks[4], (nh,), jnp.float32, math.log(1e-3), math.log(1e-1)))))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * n + nh), pdt),
        "conv_w": dense_init(ks[1], (w, conv_dim), pdt, scale=1.0 / math.sqrt(w)),
        "conv_b": jnp.zeros((conv_dim,), pdt),
        "out_proj": dense_init(ks[2], (di, d), pdt),
        "A_log": jnp.log(jax.random.uniform(ks[3], (nh,), jnp.float32, 1.0, 16.0)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm_w": jnp.zeros((di,), pdt),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv.  x: [B,S,C]; w: [W,C]."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):  # W==4: unrolled, cheap
        out = out + xp[:, i: i + x.shape[1], :] * w[i]
    return out + b


def mamba_forward(p, x, cfg: ModelConfig, *, initial_state=None, conv_init=None,
                  return_cache: bool = False):
    """Full-sequence Mamba2 block.  x: [B,S,d] -> [B,S,d]."""
    b, s, d = x.shape
    di, n, nh, ph = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    cdt = x.dtype
    zxbcdt = x @ p["in_proj"].astype(cdt)
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    if conv_init is not None:
        xbc_in = jnp.concatenate([conv_init.astype(cdt), xbc], axis=1)
        xbc_conv = _causal_conv(xbc_in, p["conv_w"].astype(cdt), p["conv_b"].astype(cdt))[:, conv_init.shape[1]:]
    else:
        xbc_conv = _causal_conv(xbc, p["conv_w"].astype(cdt), p["conv_b"].astype(cdt))
    xbc_conv = jax.nn.silu(xbc_conv)
    xs, B, C = jnp.split(xbc_conv, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    A = -jnp.exp(p["A_log"])  # [H]
    xh = xs.reshape(b, s, nh, ph)
    y, final_state = ssd_chunked(xh, dt, A, B, C, chunk=min(cfg.ssm_chunk, s),
                                 initial_state=initial_state)
    y = y + xh.astype(jnp.float32).astype(cdt) * p["D"].astype(cdt)[:, None]
    y = y.reshape(b, s, di)
    y = gated_rms_norm(y, z, p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(cdt)
    if return_cache:
        w1 = cfg.ssm_conv - 1
        hist = xbc if conv_init is None else jnp.concatenate([conv_init.astype(cdt), xbc], axis=1)
        if hist.shape[1] >= w1:
            conv_cache = hist[:, hist.shape[1] - w1:, :]
        else:
            conv_cache = jnp.pad(hist, ((0, 0), (w1 - hist.shape[1], 0), (0, 0)))
        return out, final_state, conv_cache
    return out


def mamba_step(p, x, cfg: ModelConfig, state, conv_cache):
    """One-token Mamba2 step.

    x: [B,1,d]; state: [B,H,P,N] fp32; conv_cache: [B,W-1,conv_dim].
    Returns (out [B,1,d], new_state, new_conv_cache).
    """
    b = x.shape[0]
    di, n, nh, ph = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    cdt = x.dtype
    zxbcdt = x[:, 0] @ p["in_proj"].astype(cdt)  # [B, ...]
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    window = jnp.concatenate([conv_cache.astype(cdt), xbc[:, None]], axis=1)  # [B,W,cd]
    conv_out = jnp.einsum("bwc,wc->bc", window, p["conv_w"].astype(cdt)) + p["conv_b"].astype(cdt)
    conv_out = jax.nn.silu(conv_out)
    xs, B, C = jnp.split(conv_out, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B,H]
    A = -jnp.exp(p["A_log"])
    state, y = ssd_step(state, xs.reshape(b, nh, ph), dt, A, B, C)
    y = y + xs.reshape(b, nh, ph) * p["D"].astype(cdt)[:, None]
    y = y.reshape(b, di)
    y = gated_rms_norm(y, z, p["norm_w"], cfg.norm_eps)
    out = (y @ p["out_proj"].astype(cdt))[:, None]
    return out, state, window[:, 1:]
