"""Decoder-only transformer LM (dense / MoE / VLM families).

Layers are *stacked* along a leading L dim and the body is a single
``lax.scan`` (optionally ``jax.checkpoint``-ed), keeping the HLO small for
512-device dry-run compiles and matching production JAX LM frameworks.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_activation
from repro.models import layers as L

PyTree = Any


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            prevent_cse=False)
    return jax.checkpoint(fn, prevent_cse=False)


def chunked_ce_loss(x, unembed, labels, mask, *, chunk: int = 512,
                    norm_w=None, eps: float = 1e-5):
    """Memory-bounded cross-entropy: scan over sequence chunks.

    x: [B,S,d] (pre-final-norm); unembed: [d,V]; labels/mask: [B,S].
    Returns (mean_loss, n_tokens).
    """
    b, s, d = x.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    nc = s // chunk
    xc = jnp.moveaxis(x.reshape(b, nc, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)
    mc = jnp.moveaxis(mask.reshape(b, nc, chunk), 1, 0)

    def body(carry, inp):
        tot, cnt = carry
        xi, li, mi = inp
        if norm_w is not None:
            xi = L.rms_norm(xi, norm_w, eps)
        logits = (xi @ unembed.astype(xi.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mi
        return (tot + nll.sum(), cnt + mi.sum()), None

    body = jax.checkpoint(body, prevent_cse=False)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (xc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0), cnt


class TransformerLM:
    """families: dense | moe | vlm."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.pdt = L.dtype_of(cfg.param_dtype)
        self.cdt = L.dtype_of(cfg.dtype)

    # ---------------- params ----------------
    def init(self, rng) -> PyTree:
        cfg = self.cfg
        k_emb, k_un, k_layers, k_extra = jax.random.split(rng, 4)

        def layer_init(k):
            ka, kf = jax.random.split(k)
            p = {
                "attn": L.init_attn(ka, cfg, self.pdt),
                "ln1": jnp.zeros((cfg.d_model,), self.pdt),
                "ln2": jnp.zeros((cfg.d_model,), self.pdt),
            }
            if cfg.family == "moe":
                p["moe"] = L.init_moe(kf, cfg, self.pdt)
            else:
                p["mlp"] = L.init_mlp(kf, cfg, self.pdt)
            return p

        params = {
            "embed": L.embed_init(k_emb, (cfg.vocab_padded, cfg.d_model), self.pdt),
            "layers": jax.vmap(layer_init)(jax.random.split(k_layers, cfg.n_layers)),
            "final_norm": jnp.zeros((cfg.d_model,), self.pdt),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = L.dense_init(k_un, (cfg.d_model, cfg.vocab_padded), self.pdt)
        if cfg.family == "vlm":
            params["patch_proj"] = L.dense_init(k_extra, (cfg.d_model, cfg.d_model), self.pdt)
        return params

    def _unembed(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["unembed"]

    # ---------------- body ----------------
    def _embed_inputs(self, params, batch) -> jax.Array:
        cfg = self.cfg
        x = params["embed"].astype(self.cdt)[batch["tokens"]]
        if cfg.family == "vlm":
            patches = batch["patch_embeds"].astype(self.cdt) @ params["patch_proj"].astype(self.cdt)
            x = jnp.concatenate([patches, x], axis=1)
        return x

    def _body(self, params, x, positions):
        cfg = self.cfg

        def block(h, lp):
            h = shard_activation(h, "residual")
            a = L.attn_forward(lp["attn"], L.rms_norm(h, lp["ln1"], cfg.norm_eps),
                               cfg, positions, causal=True)
            h = h + a
            hn = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                f = L.moe_forward(lp["moe"], hn, cfg)
            else:
                f = L.mlp_forward(lp["mlp"], hn)
            return h + f, None

        x, _ = jax.lax.scan(_remat(block, cfg), x, params["layers"])
        return x

    def forward(self, params, batch) -> jax.Array:
        """Full logits [B, S_total, V] (small inputs only; tests)."""
        x = self._embed_inputs(params, batch)
        positions = jnp.arange(x.shape[1])[None, :]
        x = self._body(params, x, positions)
        x = L.rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        return (x @ self._unembed(params).astype(self.cdt)).astype(jnp.float32)

    # ---------------- train ----------------
    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        positions = jnp.arange(x.shape[1])[None, :]
        x = self._body(params, x, positions)
        labels, mask = batch["labels"], batch.get("mask")
        if cfg.family == "vlm":  # loss only over text positions
            x = x[:, -labels.shape[1]:]
        if mask is None:
            mask = jnp.ones(labels.shape, jnp.float32)
        loss, cnt = chunked_ce_loss(x, self._unembed(params), labels, mask,
                                    norm_w=params["final_norm"], eps=cfg.norm_eps)
        return loss, {"loss": loss, "tokens": cnt}

    # ---------------- serve ----------------
    def prefill(self, params, batch, max_len: Optional[int] = None) -> Tuple[jax.Array, PyTree]:
        """Process the full prompt; return last-token logits + KV cache."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        b, s, _ = x.shape
        positions = jnp.arange(s)[None, :]

        def block(h, lp):
            h = shard_activation(h, "residual")
            hn = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
            hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
            k = (hn @ lp["attn"]["wk"].astype(h.dtype)).reshape(b, s, hkv, dh)
            v = (hn @ lp["attn"]["wv"].astype(h.dtype)).reshape(b, s, hkv, dh)
            k = L.apply_rope(k, positions, cfg.rope_theta)
            q = (hn @ lp["attn"]["wq"].astype(h.dtype)).reshape(b, s, hq, dh)
            q = L.apply_rope(q, positions, cfg.rope_theta)
            if cfg.attn_mode == "naive":
                o = L.attention_naive(q, k, v, causal=True)
            else:
                o = L.attention_chunked(q, k, v, causal=True, chunk=cfg.attn_chunk)
            h = h + o.reshape(b, s, hq * dh) @ lp["attn"]["wo"].astype(h.dtype)
            hn2 = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                f = L.moe_forward(lp["moe"], hn2, cfg)
            else:
                f = L.mlp_forward(lp["mlp"], hn2)
            return h + f, (k, v)

        x, (ks, vs) = jax.lax.scan(_remat(block, cfg), x, params["layers"])
        x = L.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        logits = (x @ self._unembed(params).astype(self.cdt))[:, 0].astype(jnp.float32)
        if max_len is not None and max_len > s:
            pad = ((0, 0), (0, 0), (0, max_len - s), (0, 0), (0, 0))
            ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
        if cfg.kv_quant:
            kq, k_s = _kv_quantize(ks)
            vq, v_s = _kv_quantize(vs)
            cache = {"k": kq, "v": vq, "k_s": k_s, "v_s": v_s, "len": jnp.int32(s)}
        else:
            cache = {"k": ks, "v": vs, "len": jnp.int32(s)}
        return logits, cache

    def cache_spec(self, batch_size: int, max_len: int) -> PyTree:
        cfg = self.cfg
        shape = (cfg.n_layers, batch_size, max_len, cfg.n_kv_heads, cfg.head_dim)
        if cfg.kv_quant:
            kv = jax.ShapeDtypeStruct(shape, jnp.int8)
            sc = jax.ShapeDtypeStruct(shape[:-1], jnp.float32)
            return {"k": kv, "v": kv, "k_s": sc, "v_s": sc,
                    "len": jax.ShapeDtypeStruct((), jnp.int32)}
        kv = jax.ShapeDtypeStruct(shape, self.cdt)
        return {"k": kv, "v": kv, "len": jax.ShapeDtypeStruct((), jnp.int32)}

    def init_cache(self, batch_size: int, max_len: int) -> PyTree:
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), self.cache_spec(batch_size, max_len))

    def decode_step(self, params, cache, tokens) -> Tuple[jax.Array, PyTree]:
        """tokens: [B] -> (logits [B,V], cache)."""
        if self.cfg.kv_quant:
            return self._decode_step_q(params, cache, tokens)
        cfg = self.cfg
        x = params["embed"].astype(self.cdt)[tokens][:, None]  # [B,1,d]
        clen = cache["len"]

        def block(h, xs):
            lp, kc, vc = xs
            hn = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
            a, nk, nv = L.attn_decode_forward(lp["attn"], hn, cfg, kc, vc, clen)
            h = h + a
            hn2 = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                f = L.moe_forward(lp["moe"], hn2, cfg)
            else:
                f = L.mlp_forward(lp["mlp"], hn2)
            return h + f, (nk, nv)

        x, (nks, nvs) = jax.lax.scan(block, x, (params["layers"], cache["k"], cache["v"]))
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = (x @ self._unembed(params).astype(self.cdt))[:, 0].astype(jnp.float32)
        return logits, {"k": nks, "v": nvs, "len": clen + 1}

    def _decode_step_q(self, params, cache, tokens) -> Tuple[jax.Array, PyTree]:
        """int8-KV decode: dequantise per layer inside the scan (HBM reads
        the int8 buffers + fp32 per-(token, head) scales: ~half the bf16
        traffic); the new token's K/V are quantised before the write."""
        cfg = self.cfg
        x = params["embed"].astype(self.cdt)[tokens][:, None]
        clen = cache["len"]
        b = x.shape[0]
        hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

        def block(h, xs):
            lp, kc, vc, ks_s, vs_s = xs
            hn = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
            q = (hn @ lp["attn"]["wq"].astype(h.dtype)).reshape(b, 1, hq, dh)
            k = (hn @ lp["attn"]["wk"].astype(h.dtype)).reshape(b, 1, hkv, dh)
            v = (hn @ lp["attn"]["wv"].astype(h.dtype)).reshape(b, 1, hkv, dh)
            pos = jnp.full((b, 1), clen, jnp.int32)
            q = L.apply_rope(q, pos, cfg.rope_theta)
            k = L.apply_rope(k, pos, cfg.rope_theta)
            kq, ksc = _kv_quantize(k)
            vq, vsc = _kv_quantize(v)
            nk = jax.lax.dynamic_update_slice(kc, kq, (0, clen, 0, 0))
            nv = jax.lax.dynamic_update_slice(vc, vq, (0, clen, 0, 0))
            nks = jax.lax.dynamic_update_slice(ks_s, ksc, (0, clen, 0))
            nvs = jax.lax.dynamic_update_slice(vs_s, vsc, (0, clen, 0))
            k_full = _kv_dequantize(nk, nks, h.dtype)
            v_full = _kv_dequantize(nv, nvs, h.dtype)
            o = L.attention_decode(q, k_full, v_full, clen + 1)
            h = h + o.reshape(b, 1, hq * dh) @ lp["attn"]["wo"].astype(h.dtype)
            hn2 = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                f = L.moe_forward(lp["moe"], hn2, cfg)
            else:
                f = L.mlp_forward(lp["mlp"], hn2)
            return h + f, (nk, nv, nks, nvs)

        x, (nk, nv, nks, nvs) = jax.lax.scan(
            block, x, (params["layers"], cache["k"], cache["v"],
                       cache["k_s"], cache["v_s"]))
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = (x @ self._unembed(params).astype(self.cdt))[:, 0].astype(jnp.float32)
        return logits, {"k": nk, "v": nv, "k_s": nks, "v_s": nvs, "len": clen + 1}


def _kv_quantize(x):
    """x: [..., Dh] -> (int8 [..., Dh], fp32 absmax scale [...])."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(absmax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _kv_dequantize(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)
