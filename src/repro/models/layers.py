"""Shared transformer building blocks (pure-jnp, shard-friendly).

Everything here is written against *stacked* per-layer parameter trees so
model bodies can ``lax.scan`` over layers (small HLO, fast 512-device
compiles — the same trick MaxText uses).

Attention uses a flash-style *chunked* path by default (``lax.scan`` over
query chunks) so that the 32k prefill cells never materialise an
``S x S`` score tensor.  The Pallas kernels in ``repro.kernels`` are
drop-in replacements for the TPU target; the chunked jnp path is the
portable oracle that the dry-run lowers.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

PyTree = Any

NEG_INF = -2.0e38


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms / rotary
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(dt)


def gated_rms_norm(x, gate, w, eps: float = 1e-5):
    """Mamba2-style norm(x * silu(gate))."""
    dt = x.dtype
    x = x.astype(jnp.float32) * jax.nn.silu(gate.astype(jnp.float32))
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, D]; positions: [..., S] int32."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta))  # [D/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # [..., S, 1, D/2]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def init_attn(key, cfg: ModelConfig, pdt) -> Dict[str, jax.Array]:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, hq * dh), pdt),
        "wk": dense_init(ks[1], (d, hkv * dh), pdt),
        "wv": dense_init(ks[2], (d, hkv * dh), pdt),
        "wo": dense_init(ks[3], (hq * dh, d), pdt, scale=1.0 / math.sqrt(hq * dh)),
    }


def _gqa_scores(q, k):
    """q: [B,Sq,Hkv,G,D]  k: [B,Sk,Hkv,D] -> [B,Hkv,G,Sq,Sk] (fp32)."""
    return jnp.einsum("bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32)


def _gqa_out(p, v):
    """p: [B,Hkv,G,Sq,Sk]  v: [B,Sk,Hkv,D] -> [B,Sq,Hkv,G,D]."""
    return jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)


def attention_naive(q, k, v, *, causal: bool, q_offset=0):
    """Reference attention.  q: [B,Sq,Hq,D], k/v: [B,Sk,Hkv,D]."""
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, dh) * (dh ** -0.5)
    s = _gqa_scores(qg, k)
    if causal:
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(k.shape[1])
        mask = qpos[:, None] >= kpos[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return _gqa_out(p, v).reshape(b, sq, hq, dh)


def attention_chunked(q, k, v, *, causal: bool, chunk: int, q_offset=0):
    """Flash-style memory-efficient attention: scan over query chunks.

    Never materialises more than [B,Hkv,G,chunk,Sk] scores at once.
    """
    b, sq, hq, dh = q.shape
    if sq % chunk != 0 or sq <= chunk:
        return attention_naive(q, k, v, causal=causal, q_offset=q_offset)
    hkv = k.shape[2]
    g = hq // hkv
    nq = sq // chunk
    qg = (q * (dh ** -0.5)).reshape(b, nq, chunk, hkv, g, dh)
    kpos = jnp.arange(k.shape[1])

    def body(_, xs):
        qc, idx = xs  # qc: [B,chunk,Hkv,G,D]
        s = _gqa_scores(qc, k)  # [B,Hkv,G,chunk,Sk]
        if causal:
            qpos = idx * chunk + jnp.arange(chunk) + q_offset
            mask = qpos[:, None] >= kpos[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return None, _gqa_out(p, v)  # [B,chunk,Hkv,G,D]

    _, out = jax.lax.scan(body, None, (jnp.moveaxis(qg, 1, 0), jnp.arange(nq)))
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq, hq, dh)
    return out


def attention_decode(q, k_cache, v_cache, cache_len):
    """Single-step decode.  q: [B,1,Hq,D]; caches: [B,Smax,Hkv,D]."""
    b, _, hq, dh = q.shape
    hkv = k_cache.shape[2]
    g = hq // hkv
    qg = q.reshape(b, 1, hkv, g, dh) * (dh ** -0.5)
    s = _gqa_scores(qg, k_cache)  # [B,Hkv,G,1,Smax]
    valid = jnp.arange(k_cache.shape[1]) < cache_len
    s = jnp.where(valid[None, None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return _gqa_out(p, v_cache).reshape(b, 1, hq, dh)


def attn_forward(p, x, cfg: ModelConfig, positions, *, causal=True, kv_override=None):
    """Full-sequence attention block body.  x: [B,S,d]."""
    b, s, _ = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cdt = x.dtype
    q = (x @ p["wq"].astype(cdt)).reshape(b, s, hq, dh)
    if kv_override is None:
        k = (x @ p["wk"].astype(cdt)).reshape(b, s, hkv, dh)
        v = (x @ p["wv"].astype(cdt)).reshape(b, s, hkv, dh)
        k = apply_rope(k, positions, cfg.rope_theta)
    else:  # cross attention: kv from encoder states
        enc = kv_override
        k = (enc @ p["wk"].astype(cdt)).reshape(b, enc.shape[1], hkv, dh)
        v = (enc @ p["wv"].astype(cdt)).reshape(b, enc.shape[1], hkv, dh)
    q = apply_rope(q, positions, cfg.rope_theta) if kv_override is None else q
    if cfg.attn_mode == "naive":
        o = attention_naive(q, k, v, causal=causal)
    else:
        o = attention_chunked(q, k, v, causal=causal, chunk=cfg.attn_chunk)
    return o.reshape(b, s, hq * dh) @ p["wo"].astype(cdt)


def attn_decode_forward(p, x, cfg: ModelConfig, cache_k, cache_v, cache_len):
    """One-token attention with KV cache update.

    x: [B,1,d].  Returns (out [B,1,d], new_k, new_v).
    """
    b = x.shape[0]
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cdt = x.dtype
    q = (x @ p["wq"].astype(cdt)).reshape(b, 1, hq, dh)
    k = (x @ p["wk"].astype(cdt)).reshape(b, 1, hkv, dh)
    v = (x @ p["wv"].astype(cdt)).reshape(b, 1, hkv, dh)
    pos = jnp.full((b, 1), cache_len, dtype=jnp.int32)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    new_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype), (0, cache_len, 0, 0))
    new_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype), (0, cache_len, 0, 0))
    o = attention_decode(q, new_k.astype(cdt), new_v.astype(cdt), cache_len + 1)
    return o.reshape(b, 1, hq * dh) @ p["wo"].astype(cdt), new_k, new_v


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, pdt, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "wg": dense_init(ks[0], (d, f), pdt),
        "wi": dense_init(ks[1], (d, f), pdt),
        "wo": dense_init(ks[2], (f, d), pdt),
    }


def mlp_forward(p, x):
    cdt = x.dtype
    h = jax.nn.silu(x @ p["wg"].astype(cdt)) * (x @ p["wi"].astype(cdt))
    return h @ p["wo"].astype(cdt)


def init_moe(key, cfg: ModelConfig, pdt):
    # Experts padded to a TP-friendly count (padded experts are masked out
    # of the router and never receive tokens).
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts_padded
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "wg": dense_init(ks[1], (e, d, f), pdt),
        "wi": dense_init(ks[2], (e, d, f), pdt),
        "wo": dense_init(ks[3], (e, f, d), pdt),
    }


def moe_forward(p, x, cfg: ModelConfig):
    """Top-k MoE FFN.  x: [B,S,d] -> [B,S,d].

    ``cfg.moe_mode``:
      * ``dense``    – every expert computes every token; combine with
                       (sparse) gate weights.  Correctness oracle; used by
                       smoke tests and as the *paper-faithful framework
                       baseline* in the dry-run.
      * ``dispatch`` – sort-based capacity dispatch (dropless up to
                       ``capacity_factor``): gather token rows per expert,
                       batched expert matmuls, scatter-add back.  The
                       hillclimbed production path.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts_padded, cfg.topk
    cdt = x.dtype
    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # [B,S,E]
    if e > cfg.n_experts:  # mask padded experts out of routing
        pad_mask = jnp.arange(e) >= cfg.n_experts
        logits = jnp.where(pad_mask, -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)  # [B,S,K]
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    if cfg.moe_mode == "dense":
        h = jnp.einsum("bsd,edf->bsef", x, p["wg"].astype(cdt))
        u = jnp.einsum("bsd,edf->bsef", x, p["wi"].astype(cdt))
        y = jnp.einsum("bsef,efd->bsed", jax.nn.silu(h) * u, p["wo"].astype(cdt))
        dense_w = jnp.sum(
            jax.nn.one_hot(topi, e, dtype=jnp.float32) * topw[..., None], axis=2
        )  # [B,S,E]
        return jnp.einsum("bsed,bse->bsd", y, dense_w.astype(cdt))

    # ---- dispatch mode: sort-based capacity dispatch over token groups ----
    # Under a mesh (production) and a full sequence, use the EXPLICIT
    # shard_map expert-parallel path: local bucketing, all-to-all to the
    # expert shards, local expert matmuls (weight grads stay local — each
    # shard owns its experts), all-to-all back.  Otherwise (single device /
    # decode) the pure-jit gather-based path below.
    from repro.distributed.sharding import current_rules, moe_constraint

    rules = current_rules()
    if rules is not None and s > 1:
        out = _moe_shardmap(p, x, topi, topw.astype(cdt), cfg, rules)
        if out is not None:
            return out

    g = cfg.moe_groups if s % cfg.moe_groups == 0 and s >= cfg.moe_groups else 1
    tg = s // g  # tokens per group
    xf = x.reshape(b * g, tg, d)
    ti = topi.reshape(b * g, tg, k)
    tw = topw.reshape(b * g, tg, k).astype(cdt)
    out = _moe_dispatch_batched(xf, ti, tw, p, cfg, groups_per_row=g,
                                constraint=moe_constraint)
    return out.reshape(b, s, d)


def _moe_shardmap(p, x, topi, topw, cfg: ModelConfig, rules):
    """Explicit EP: shard_map over (dp x model); returns None if shapes
    don't tile the mesh (caller falls back to the pure-jit path)."""
    import math as _math
    from functools import partial as _partial

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    e, k = cfg.n_experts_padded, cfg.topk
    mesh = rules.mesh
    maxis = rules.model_axis
    m = mesh.shape[maxis]
    dp = rules.dp
    dp_size = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        dp_size *= mesh.shape[a]
    if b % dp_size or s % m or e % m:
        return None
    t_loc = (b // dp_size) * (s // m)
    cap = max(4, int(_math.ceil(t_loc * k / e * cfg.capacity_factor)))

    def local_fn(xl, ti_l, tw_l, wg_l, wi_l, wo_l):
        # xl: [B_loc, S_loc, d]; ti/tw: [B_loc, S_loc, K]
        bl, sl, _ = xl.shape
        xf = xl.reshape(1, bl * sl, d)
        ti_f = ti_l.reshape(1, bl * sl, k)
        tw_f = tw_l.reshape(1, bl * sl, k)

        def expert_fn(xg):
            # xg: [1, E, cap, d] local buffer for ALL experts ->
            # a2a so each shard keeps its E_loc experts from all peers
            xg = xg.reshape(e, cap, d)
            recv = jax.lax.all_to_all(xg, maxis, split_axis=0, concat_axis=1,
                                      tiled=True)              # [E_loc, M*cap, d]
            h = jnp.einsum("ecd,edf->ecf", recv, wg_l.astype(xg.dtype))
            u = jnp.einsum("ecd,edf->ecf", recv, wi_l.astype(xg.dtype))
            yg = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u,
                            wo_l.astype(xg.dtype))             # [E_loc, M*cap, d]
            back = jax.lax.all_to_all(yg, maxis, split_axis=1, concat_axis=0,
                                      tiled=True)              # [E, cap, d]
            return back.reshape(1, e, cap, d)

        out = _moe_dispatch_batched(xf, ti_f, tw_f, p, cfg, groups_per_row=1,
                                    constraint=None, expert_fn=expert_fn,
                                    cap_override=cap)
        return out.reshape(bl, sl, d)

    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dp, maxis, None), P(dp, maxis, None), P(dp, maxis, None),
                  P(maxis, None, None), P(maxis, None, None), P(maxis, None, None)),
        out_specs=P(dp, maxis, None),
        check_rep=False,
    )
    cdt = x.dtype
    return fn(x, topi, topw, p["wg"].astype(cdt), p["wi"].astype(cdt),
              p["wo"].astype(cdt))


def _moe_dispatch_batched(xf, ti, tw, p, cfg: ModelConfig, *, groups_per_row: int,
                          constraint=None, expert_fn=None, cap_override=None):
    """Batched capacity dispatch, SCATTER-FREE.  xf: [G,T,d]; ti/tw: [G,T,K].

    Both the token->expert-buffer build and the combine are expressed as
    batched GATHERS (take_along_axis with a leading group batch dim), which
    GSPMD partitions along G without the partial-result all-reduces that a
    generic scatter triggers (the scatter formulation cost a full
    [G, T, d] fp32 all-reduce per layer — see EXPERIMENTS.md §Perf).
    """
    gdim, t, d = xf.shape
    e, k = cfg.n_experts_padded, cfg.topk
    cdt = xf.dtype
    if cap_override is not None:
        cap = cap_override
    else:
        cap = int(math.ceil(t * k / e * cfg.capacity_factor))
        cap = max(4, min(cap, t))
    tk = t * k
    ar = jnp.arange(tk)
    flat_e = ti.reshape(gdim, tk)
    flat_row = jnp.tile(jnp.repeat(jnp.arange(t), k)[None], (gdim, 1))
    order = jnp.argsort(flat_e, axis=1, stable=True)            # [G, TK]
    e_sorted = jnp.take_along_axis(flat_e, order, axis=1)
    row_sorted = jnp.take_along_axis(flat_row, order, axis=1)
    # per-expert slot counts and exclusive starts (gather-only bookkeeping)
    counts = jnp.sum(flat_e[:, :, None] == jnp.arange(e)[None, None, :],
                     axis=1, dtype=jnp.int32)                    # [G, E]
    start = jnp.cumsum(counts, axis=1) - counts                  # [G, E]
    # expert buffer of token-row indices: position (e, c) holds the c-th
    # sorted slot of expert e (sentinel t = zero-pad row when overflowing)
    s_pos = start[:, :, None] + jnp.arange(cap)[None, None, :]   # [G, E, cap]
    valid = jnp.arange(cap)[None, None, :] < jnp.minimum(counts[:, :, None], cap)
    s_clip = jnp.clip(s_pos, 0, tk - 1).reshape(gdim, e * cap)
    buf_idx = jnp.where(valid.reshape(gdim, e * cap),
                        jnp.take_along_axis(row_sorted, s_clip, axis=1),
                        t).astype(jnp.int32)                     # [G, E*cap]
    x_pad = jnp.concatenate([xf, jnp.zeros((gdim, 1, d), cdt)], axis=1)
    xg = jnp.take_along_axis(x_pad, buf_idx[..., None], axis=1)  # [G, E*cap, d]
    xg = xg.reshape(gdim, e, cap, d)
    if expert_fn is not None:   # shard_map EP path supplies the expert block
        yg = expert_fn(xg)
    else:
        if constraint is not None:  # group->expert reshard (the EP a2a)
            xg = constraint(xg, "expert_in", groups_per_row)
        h = jnp.einsum("gecd,edf->gecf", xg, p["wg"].astype(cdt))
        u = jnp.einsum("gecd,edf->gecf", xg, p["wi"].astype(cdt))
        yg = jnp.einsum("gecf,efd->gecd", jax.nn.silu(h) * u, p["wo"].astype(cdt))
        if constraint is not None:  # expert-sharded -> back to group-sharded
            yg = constraint(yg, "expert_out", groups_per_row)
    # combine via the INVERSE mapping: for each original (token, slot), the
    # buffer position it landed in (or the zero sentinel if dropped)
    inv_perm = jnp.argsort(order, axis=1)                        # [G, TK]
    start_g = jnp.take_along_axis(start, e_sorted, axis=1)       # [G, TK]
    pos_in_e = ar[None] - start_g
    bp_sorted = jnp.where(pos_in_e < cap, e_sorted * cap + pos_in_e, e * cap)
    bp = jnp.take_along_axis(bp_sorted, inv_perm, axis=1)        # [G, TK]
    yg_pad = jnp.concatenate(
        [yg.reshape(gdim, e * cap, d), jnp.zeros((gdim, 1, d), cdt)], axis=1)
    contrib = jnp.take_along_axis(yg_pad, bp[..., None], axis=1)  # [G, TK, d]
    out = jnp.einsum("gtkd,gtk->gtd", contrib.reshape(gdim, t, k, d),
                     tw.astype(cdt))
    return out
