"""Model factory + per-(arch, shape) input specs for lowering and smoke runs.

``input_specs(cfg, shape)`` returns ``ShapeDtypeStruct`` stand-ins for every
model input of the step that the shape's ``kind`` lowers:

  * ``train``   -> ``train_step(state, batch)``
  * ``prefill`` -> ``prefill_step(params, batch)``
  * ``decode``  -> ``serve_step(params, cache, tokens)`` (one new token
                   against a KV/state cache of ``seq_len``)

Modality frontends are STUBS per the assignment: VLM patch embeddings and
audio frame embeddings appear as precomputed inputs.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.encdec import EncDecLM
from repro.models.hybrid import Zamba2LM
from repro.models.mamba_lm import Mamba2LM
from repro.models.transformer import TransformerLM

PyTree = Any


def get_model(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        return TransformerLM(cfg)
    if cfg.family == "encdec":
        return EncDecLM(cfg)
    if cfg.family == "ssm":
        return Mamba2LM(cfg)
    if cfg.family == "hybrid":
        return Zamba2LM(cfg)
    raise ValueError(f"unknown family {cfg.family}")


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_specs(cfg: ModelConfig, b: int, s: int, *, with_labels: bool) -> Dict[str, Any]:
    cdt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]
    if cfg.family == "vlm":
        s_text = s - cfg.n_patches
        out = {"tokens": _sds((b, s_text), jnp.int32),
               "patch_embeds": _sds((b, cfg.n_patches, cfg.d_model), cdt)}
        if with_labels:
            out["labels"] = _sds((b, s_text), jnp.int32)
        return out
    if cfg.family == "encdec":
        out = {"frames": _sds((b, s, cfg.d_model), cdt),
               "tokens": _sds((b, s), jnp.int32)}
        if with_labels:
            out["labels"] = _sds((b, s), jnp.int32)
        return out
    out = {"tokens": _sds((b, s), jnp.int32)}
    if with_labels:
        out["labels"] = _sds((b, s), jnp.int32)
    return out


def decode_specs(cfg: ModelConfig, b: int, s: int) -> Tuple[PyTree, Any]:
    """(cache_specs, token_specs) for serve_step."""
    model = get_model(cfg)
    if cfg.family in ("dense", "moe", "vlm"):
        cache = model.cache_spec(b, s)
    elif cfg.family == "encdec":
        cache = model.cache_spec(b, s, s)
    elif cfg.family == "ssm":
        cache = model.cache_spec(b)
    else:  # hybrid
        cache = model.cache_spec(b, s)
    return cache, _sds((b,), jnp.int32)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"batch": batch_specs(cfg, b, s, with_labels=True)}
    if shape.kind == "prefill":
        return {"batch": batch_specs(cfg, b, s, with_labels=False)}
    cache, toks = decode_specs(cfg, b, s)
    return {"cache": cache, "tokens": toks}


def make_concrete_batch(cfg: ModelConfig, b: int, s: int, rng: jax.Array,
                        *, with_labels: bool = True) -> Dict[str, Any]:
    """Random concrete batch matching ``batch_specs`` (smoke tests/examples)."""
    specs = batch_specs(cfg, b, s, with_labels=with_labels)
    out = {}
    for name, sd in specs.items():
        rng, k = jax.random.split(rng)
        if sd.dtype == jnp.int32:
            out[name] = jax.random.randint(k, sd.shape, 0, cfg.vocab, jnp.int32)
        else:
            out[name] = jax.random.normal(k, sd.shape, sd.dtype)
    return out
