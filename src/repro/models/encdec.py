"""Encoder-decoder transformer (seamless-m4t family).

The speech/audio frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings ``[B, S_enc, d_model]`` supplied by
``input_specs()``; everything downstream (bidirectional encoder, causal
decoder with cross-attention, serving with self- + cross-KV caches) is real.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_activation
from repro.models import layers as L
from repro.models.transformer import _remat, chunked_ce_loss

PyTree = Any


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.pdt = L.dtype_of(cfg.param_dtype)
        self.cdt = L.dtype_of(cfg.dtype)

    # ---------------- params ----------------
    def init(self, rng) -> PyTree:
        cfg = self.cfg
        k_fp, k_enc, k_emb, k_dec, k_un = jax.random.split(rng, 5)

        def enc_layer(k):
            ka, kf = jax.random.split(k)
            return {
                "attn": L.init_attn(ka, cfg, self.pdt),
                "mlp": L.init_mlp(kf, cfg, self.pdt),
                "ln1": jnp.zeros((cfg.d_model,), self.pdt),
                "ln2": jnp.zeros((cfg.d_model,), self.pdt),
            }

        def dec_layer(k):
            ka, kc, kf = jax.random.split(k, 3)
            return {
                "attn": L.init_attn(ka, cfg, self.pdt),
                "cross": L.init_attn(kc, cfg, self.pdt),
                "mlp": L.init_mlp(kf, cfg, self.pdt),
                "ln1": jnp.zeros((cfg.d_model,), self.pdt),
                "ln2": jnp.zeros((cfg.d_model,), self.pdt),
                "ln3": jnp.zeros((cfg.d_model,), self.pdt),
            }

        return {
            "frame_proj": L.dense_init(k_fp, (cfg.d_model, cfg.d_model), self.pdt),
            "enc_layers": jax.vmap(enc_layer)(jax.random.split(k_enc, cfg.enc_layers)),
            "embed": L.embed_init(k_emb, (cfg.vocab_padded, cfg.d_model), self.pdt),
            "dec_layers": jax.vmap(dec_layer)(jax.random.split(k_dec, cfg.n_layers)),
            "enc_norm": jnp.zeros((cfg.d_model,), self.pdt),
            "final_norm": jnp.zeros((cfg.d_model,), self.pdt),
            "unembed": L.dense_init(k_un, (cfg.d_model, cfg.vocab_padded), self.pdt),
        }

    # ---------------- encoder ----------------
    def encode(self, params, frames) -> jax.Array:
        cfg = self.cfg
        x = frames.astype(self.cdt) @ params["frame_proj"].astype(self.cdt)
        positions = jnp.arange(x.shape[1])[None, :]

        def block(h, lp):
            h = shard_activation(h, "residual")
            a = L.attn_forward(lp["attn"], L.rms_norm(h, lp["ln1"], cfg.norm_eps),
                               cfg, positions, causal=False)
            h = h + a
            f = L.mlp_forward(lp["mlp"], L.rms_norm(h, lp["ln2"], cfg.norm_eps))
            return h + f, None

        x, _ = jax.lax.scan(_remat(block, cfg), x, params["enc_layers"])
        return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)

    # ---------------- decoder ----------------
    def _decoder_body(self, params, x, enc_out, positions):
        cfg = self.cfg

        def block(h, lp):
            h = shard_activation(h, "residual")
            a = L.attn_forward(lp["attn"], L.rms_norm(h, lp["ln1"], cfg.norm_eps),
                               cfg, positions, causal=True)
            h = h + a
            c = L.attn_forward(lp["cross"], L.rms_norm(h, lp["ln2"], cfg.norm_eps),
                               cfg, positions, causal=False, kv_override=enc_out)
            h = h + c
            f = L.mlp_forward(lp["mlp"], L.rms_norm(h, lp["ln3"], cfg.norm_eps))
            return h + f, None

        x, _ = jax.lax.scan(_remat(block, cfg), x, params["dec_layers"])
        return x

    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        x = params["embed"].astype(self.cdt)[batch["tokens"]]
        positions = jnp.arange(x.shape[1])[None, :]
        x = self._decoder_body(params, x, enc_out, positions)
        mask = batch.get("mask")
        if mask is None:
            mask = jnp.ones(batch["labels"].shape, jnp.float32)
        loss, cnt = chunked_ce_loss(x, params["unembed"], batch["labels"], mask,
                                    norm_w=params["final_norm"], eps=cfg.norm_eps)
        return loss, {"loss": loss, "tokens": cnt}

    def forward(self, params, batch) -> jax.Array:
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        x = params["embed"].astype(self.cdt)[batch["tokens"]]
        positions = jnp.arange(x.shape[1])[None, :]
        x = self._decoder_body(params, x, enc_out, positions)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        return (x @ params["unembed"].astype(self.cdt)).astype(jnp.float32)

    # ---------------- serve ----------------
    def cache_spec(self, batch_size: int, max_len: int, enc_len: int) -> PyTree:
        cfg = self.cfg
        kv = jax.ShapeDtypeStruct(
            (cfg.n_layers, batch_size, max_len, cfg.n_kv_heads, cfg.head_dim), self.cdt)
        ckv = jax.ShapeDtypeStruct(
            (cfg.n_layers, batch_size, enc_len, cfg.n_kv_heads, cfg.head_dim), self.cdt)
        return {"k": kv, "v": kv, "ck": ckv, "cv": ckv,
                "len": jax.ShapeDtypeStruct((), jnp.int32)}

    def init_cache(self, batch_size: int, max_len: int, enc_len: int) -> PyTree:
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_spec(batch_size, max_len, enc_len))

    def prefill_cross(self, params, enc_out) -> Tuple[jax.Array, jax.Array]:
        """Precompute per-layer cross K/V from encoder output."""
        cfg = self.cfg
        b, se, _ = enc_out.shape
        hkv, dh = cfg.n_kv_heads, cfg.head_dim

        def per_layer(_, lp):
            k = (enc_out @ lp["cross"]["wk"].astype(self.cdt)).reshape(b, se, hkv, dh)
            v = (enc_out @ lp["cross"]["wv"].astype(self.cdt)).reshape(b, se, hkv, dh)
            return None, (k, v)

        _, (ck, cv) = jax.lax.scan(per_layer, None, params["dec_layers"])
        return ck, cv

    def prefill(self, params, batch, max_len=None) -> Tuple[jax.Array, PyTree]:
        """Encoder pass + cross-KV precompute + decoder prompt prefill."""
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        ck, cv = self.prefill_cross(params, enc_out)
        x = params["embed"].astype(self.cdt)[batch["tokens"]]
        b, s, _ = x.shape
        positions = jnp.arange(s)[None, :]
        hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

        def block(h, xs):
            lp, ckl, cvl = xs
            h = shard_activation(h, "residual")
            hn = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
            k = (hn @ lp["attn"]["wk"].astype(h.dtype)).reshape(b, s, hkv, dh)
            v = (hn @ lp["attn"]["wv"].astype(h.dtype)).reshape(b, s, hkv, dh)
            k = L.apply_rope(k, positions, cfg.rope_theta)
            q = (hn @ lp["attn"]["wq"].astype(h.dtype)).reshape(b, s, hq, dh)
            q = L.apply_rope(q, positions, cfg.rope_theta)
            o = L.attention_chunked(q, k, v, causal=True, chunk=cfg.attn_chunk)
            h = h + o.reshape(b, s, hq * dh) @ lp["attn"]["wo"].astype(h.dtype)
            hn2 = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
            q2 = (hn2 @ lp["cross"]["wq"].astype(h.dtype)).reshape(b, s, hq, dh)
            co = L.attention_chunked(q2, ckl.astype(h.dtype), cvl.astype(h.dtype),
                                     causal=False, chunk=cfg.attn_chunk)
            h = h + co.reshape(b, s, hq * dh) @ lp["cross"]["wo"].astype(h.dtype)
            f = L.mlp_forward(lp["mlp"], L.rms_norm(h, lp["ln3"], cfg.norm_eps))
            return h + f, (k, v)

        x, (ks, vs) = jax.lax.scan(_remat(block, cfg), x,
                                   (params["dec_layers"], ck, cv))
        x = L.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        logits = (x @ params["unembed"].astype(self.cdt))[:, 0].astype(jnp.float32)
        if max_len is not None and max_len > s:
            pad = ((0, 0), (0, 0), (0, max_len - s), (0, 0), (0, 0))
            ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
        return logits, {"k": ks, "v": vs, "ck": ck, "cv": cv, "len": jnp.int32(s)}

    def decode_step(self, params, cache, tokens) -> Tuple[jax.Array, PyTree]:
        cfg = self.cfg
        x = params["embed"].astype(self.cdt)[tokens][:, None]
        clen = cache["len"]
        b = x.shape[0]
        hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

        def block(h, xs):
            lp, kc, vc, ck, cv = xs
            hn = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
            a, nk, nv = L.attn_decode_forward(lp["attn"], hn, cfg, kc, vc, clen)
            h = h + a
            hn2 = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
            q = (hn2 @ lp["cross"]["wq"].astype(h.dtype)).reshape(b, 1, hq, dh)
            co = L.attention_decode(q, ck.astype(h.dtype), cv.astype(h.dtype),
                                    jnp.int32(ck.shape[1]))
            h = h + co.reshape(b, 1, hq * dh) @ lp["cross"]["wo"].astype(h.dtype)
            f = L.mlp_forward(lp["mlp"], L.rms_norm(h, lp["ln3"], cfg.norm_eps))
            return h + f, (nk, nv)

        x, (nks, nvs) = jax.lax.scan(
            block, x, (params["dec_layers"], cache["k"], cache["v"], cache["ck"], cache["cv"]))
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = (x @ params["unembed"].astype(self.cdt))[:, 0].astype(jnp.float32)
        new_cache = dict(cache, k=nks, v=nvs, len=clen + 1)
        return logits, new_cache
