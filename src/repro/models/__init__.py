from repro.models.registry import (
    batch_specs,
    decode_specs,
    get_model,
    input_specs,
    make_concrete_batch,
)

__all__ = ["get_model", "input_specs", "batch_specs", "decode_specs", "make_concrete_batch"]
