"""Gradient compression for bandwidth-constrained data parallelism.

Two pieces:

* :func:`compress_with_error_feedback` — int8 per-tensor-block quantization
  with an error-feedback accumulator (EF-SGD style).  Applied between
  backward and optimizer inside ``train_step``; works under any GSPMD
  partitioning because it transforms gradient *values* (the all-reduce then
  moves 4x fewer effective bits when paired with the shard_map collective
  below, and even in plain-jit mode it faithfully models the quantization
  noise the compressed system would see).

* :func:`compressed_psum` — explicit int8 quantize -> ``psum`` -> dequantize
  for use inside ``shard_map`` when the launcher runs the explicit-DP path;
  this is the collective that actually shrinks bytes on the wire.

There is a thematic rhyme with the paper: both trade exactness of advertised
state (indicators / gradients) for bandwidth, and both make the *consumer*
compensate for the induced error (FNA policies / error feedback).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any

_BLOCK = 1024


def _quant_leaf(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """int8-quantize with per-block scales. Returns (q, scales)."""
    flat = g.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % _BLOCK
    flat = jnp.pad(flat, (0, pad)).reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
    q = jnp.round(flat / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale


def _dequant_leaf(q: jax.Array, scale: jax.Array, shape, dtype) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def quantize_dequantize(g: jax.Array) -> jax.Array:
    q, s = _quant_leaf(g)
    return _dequant_leaf(q, s, g.shape, g.dtype)


def compress_with_error_feedback(grads: PyTree, ef: PyTree) -> Tuple[PyTree, PyTree]:
    """g_hat = Q(g + ef);  ef' = (g + ef) - g_hat."""

    def leaf(g, e):
        corrected = g.astype(jnp.float32) + e
        ghat = quantize_dequantize(corrected)
        return ghat.astype(g.dtype), corrected - ghat.astype(jnp.float32)

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(ef)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), tdef.unflatten([o[1] for o in out])


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8 all-reduce: agree on shared per-block scales (pmax, tiny), then
    integer ``psum``, then dequantize.

    Use inside ``shard_map``.  Bytes on the wire: 1B payload per element +
    4B per 1024-block scale, instead of 4B per element -- a ~3.9x
    collective-term reduction for DP gradient sync.
    """
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % _BLOCK
    blocks = jnp.pad(flat, (0, pad)).reshape(-1, _BLOCK)
    absmax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    shared = jax.lax.pmax(absmax, axis_name)          # phase 1: scale agreement
    scale = jnp.maximum(shared / 127.0, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name)  # phase 2: int payload
    out = (summed.astype(jnp.float32) * scale).reshape(-1)[:n]
    return out.reshape(x.shape).astype(x.dtype)
