"""Sharding rules: logical parameter/activation axes -> mesh PartitionSpecs.

Layout (Megatron-style TP on the ``model`` axis, DP over ``data`` and the
multi-pod ``pod`` axis, sequence-parallel residual stream):

  * attention / MLP in-projections  : output dim on ``model``
  * attention / MLP out-projections : input dim on ``model``
  * MoE expert weights              : expert dim on ``model`` (EP)
  * Mamba2 projections              : inner dim / heads on ``model``
  * embeddings                      : hidden dim on ``model`` (untied) or
                                      vocab on ``model`` (tied, small tables)
  * residual activations            : [B, S, d] -> (dp, "model", None)
                                      (sequence parallel between blocks)

Models call :func:`shard_activation`, which is a no-op unless a launcher
installed rules via :func:`activation_rules` — smoke tests on one device
never touch device state.
"""
from __future__ import annotations

import re
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass
class MeshRules:
    mesh: Mesh
    data_axes: Tuple[str, ...]  # ("data",) or ("pod", "data")
    model_axis: str = "model"
    seq_parallel: bool = True

    @property
    def dp(self):
        return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]


_CURRENT: Optional[MeshRules] = None


@contextmanager
def activation_rules(rules: Optional[MeshRules]):
    global _CURRENT
    prev, _CURRENT = _CURRENT, rules
    try:
        yield
    finally:
        _CURRENT = prev


def current_rules() -> Optional[MeshRules]:
    return _CURRENT


def shard_activation(x, kind: str):
    r = _CURRENT
    if r is None:
        return x
    if kind == "residual" and x.ndim == 3:
        if x.shape[1] > 1 and r.seq_parallel and x.shape[1] % _axis_size(r, r.model_axis) == 0:
            spec = P(r.dp, r.model_axis, None)
        else:
            spec = P(r.dp, None, None)
        return jax.lax.with_sharding_constraint(x, NamedSharding(r.mesh, spec))
    return x


def _axis_size(r: MeshRules, name: str) -> int:
    return r.mesh.shape[name]


def moe_constraint(x, kind: str, groups_per_row: int):
    """Sharding constraints around the MoE dispatch buffers.

    x: [G, E, cap, d] with G = batch * seq_groups.  ``expert_in`` places the
    expert dim on the model axis (the group->expert reshard is the EP
    all-to-all); ``expert_out`` moves the result back to group-sharded form.
    """
    r = _CURRENT
    if r is None:
        return x
    m = r.model_axis
    gdim = x.shape[0]
    all_axes = tuple(r.data_axes) + ((m,) if groups_per_row % _axis_size(r, m) == 0 else ())
    batch_axes = r.dp
    if kind == "expert_in":
        if x.shape[1] % _axis_size(r, m) != 0:
            return x
        spec = P(batch_axes, m, None, None)
    elif kind == "expert_out":
        spec = P(all_axes if len(all_axes) > 1 else batch_axes, None, None, None)
    else:
        return x
    if gdim % _mesh_size(r.mesh, spec[0]) != 0:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(r.mesh, spec))


def _mesh_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


# ---------------------------------------------------------------------------
# parameter specs (path-based rules)
# ---------------------------------------------------------------------------

# leaf-name -> (rule). Axis entries are applied right-aligned to the leaf
# rank so the same rule covers stacked ([L, ...]) and unstacked tensors.
_PARAM_RULES = [
    (r"embed$", ("model_if_tied", "model_if_untied")),
    (r"unembed$", (None, "model")),
    (r"(patch_proj|frame_proj)$", (None, None)),
    (r"(wq|wk|wv|wg|wi)$", (None, "model")),          # in-projections [.., d, out]
    (r"wo$", ("model", None)),                        # out-projections [.., in, d]
    (r"router$", (None, None)),
    (r"in_proj$", (None, "model")),
    (r"out_proj$", ("model", None)),
    (r"conv_w$", (None, "model")),
    (r"conv_b$", ("model",)),
    (r"(A_log|D|dt_bias)$", ("model",)),
    (r"norm_w$", ("model",)),
    (r"(ln1|ln2|ln3|final_norm)$", (None,)),
]

# MoE expert tensors are 4-D stacked [L, E, d, f]: shard experts (dim 1).
_MOE_EXPERT_RE = re.compile(r"moe.*(wg|wi|wo)$")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _validate(spec, shape, axis_sizes):
    """Drop axis assignments whose dim isn't divisible (jit in_shardings are
    strict, unlike with_sharding_constraint)."""
    if axis_sizes is None:
        return P(*spec)
    out = []
    for i, a in enumerate(spec):
        if a is not None and shape[i] % axis_sizes.get(a, 1) != 0:
            out.append(None)
        else:
            out.append(a)
    return P(*out)


def spec_for_param(path_str: str, shape, tied: bool, axis_sizes=None) -> P:
    ndim = len(shape)
    if _MOE_EXPERT_RE.search(path_str):
        # [L, E, d, f] or [E, d, f]: shard experts (EP); if the expert count
        # doesn't divide the model axis (e.g. 40 experts on 16 shards),
        # fall back to TP inside each expert (dim -2: d for wg/wi, f for wo).
        spec = [None] * ndim
        e_dim, inner_dim = ndim - 3, ndim - 2
        msize = (axis_sizes or {}).get("model", 1)
        if shape[e_dim] % msize == 0:
            spec[e_dim] = "model"
        elif shape[inner_dim] % msize == 0:
            spec[inner_dim] = "model"
        return P(*spec)
    for pat, rule in _PARAM_RULES:
        if re.search(pat, path_str):
            if rule == ("model_if_tied", "model_if_untied"):
                spec = ["model", None] if tied else [None, "model"]
                return _validate(spec, shape, axis_sizes)
            axes = list(rule)
            full = [None] * (ndim - len(axes)) + axes
            spec = full[:ndim] if ndim >= len(axes) else axes[-ndim:]
            return _validate(spec, shape, axis_sizes)
    return P()  # replicate by default


def param_pspecs(params, tied: bool = False, axis_sizes=None):
    """PartitionSpec tree matching a parameter tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for_param(_path_str(path), leaf.shape, tied, axis_sizes),
        params,
    )


def param_shardings(mesh: Mesh, params, tied: bool = False):
    axis_sizes = dict(mesh.shape)
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(params, tied, axis_sizes),
                        is_leaf=lambda s: isinstance(s, P))


def zero1_pspecs(params, tied: bool, axis_sizes, data_axes):
    """ZeRO-1: optimizer-state specs = param specs + the data axes folded
    onto the first free, divisible dim.  Optimizer updates are elementwise,
    so any layout works; sharding m/v over data removes their replication
    (fp32 m+v for a 30B model is 244GB — replicated per data shard it
    cannot fit 16GB HBM; sharded it does).  XLA then reduce-scatters the
    gradients and all-gathers updated params (the ZeRO-1 schedule) on its
    own from the output shardings."""
    base = param_pspecs(params, tied, axis_sizes)
    dsize = 1
    for a in data_axes:
        dsize *= axis_sizes.get(a, 1)
    dax = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]

    def upgrade(path, spec, leaf):
        spec_l = list(spec) + [None] * (leaf.ndim - len(spec))
        for i, s in enumerate(spec_l):
            if s is None and leaf.shape[i] % dsize == 0 and leaf.shape[i] > 0:
                spec_l[i] = dax
                return P(*spec_l)
        return P(*spec_l)  # nothing divisible: keep replicated over data

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: upgrade(path, _lookup(base, path), leaf), params)


def _lookup(tree, path):
    node = tree
    for p in path:
        key = p.key if hasattr(p, "key") else p.idx
        node = node[key]
    return node


# ---------------------------------------------------------------------------
# sweep-grid cell sharding (launch.mesh.make_sweep_mesh's 1-D "cells" mesh)
# ---------------------------------------------------------------------------

def cells_sharding(mesh: Mesh, axis: str = "cells") -> NamedSharding:
    """Row sharding along the sweep mesh's cell axis."""
    return NamedSharding(mesh, P(axis))


def replicate_to_mesh(x, mesh: Mesh):
    """Place ``x`` fully replicated on every device of ``mesh`` (the
    shared (pi, nu) view history of a sweep-grid table build).  Call
    under the caller's ``enable_x64`` — device_put canonicalises dtypes."""
    return jax.device_put(x, NamedSharding(mesh, P()))


def shard_cells(arrays, mesh: Mesh, axis_name: str = "cells"):
    """Row-shard a list of cell-axis arrays over a 1-D sweep mesh.

    Every array's leading dim is the cell count; it is padded to a
    multiple of the mesh size by repeating the final row (redundant work
    on the last shard, no host-side gather logic) before ``device_put``
    with a :func:`cells_sharding`.  Returns ``(sharded_arrays,
    original_count)`` so callers can slice the padding back off.  Like
    :func:`replicate_to_mesh`, call under the caller's ``enable_x64``.
    """
    import numpy as np

    sh = cells_sharding(mesh, axis_name)
    size = mesh.shape[axis_name]
    count = int(np.asarray(arrays[0]).shape[0])
    out = []
    for a in arrays:
        a = np.asarray(a)
        pad = (-a.shape[0]) % size
        if pad:
            a = np.concatenate([a, np.repeat(a[-1:], pad, axis=0)])
        out.append(jax.device_put(a, sh))
    return out, count
