"""Fault tolerance: preemption handling, straggler detection, elastic meshes.

* :class:`PreemptionHandler` — SIGTERM/SIGUSR1 -> "checkpoint and exit 42"
  (the restart contract cluster schedulers expect; the launcher re-invokes
  with ``--resume``).
* :class:`StepTimer` — EMA/variance step-time tracker flagging stragglers
  (on a real pod the per-host step times come from a collective of local
  timings; here the same detector runs on the local stream).
* :func:`elastic_mesh` — builds the largest usable (data, model) mesh from
  the CURRENTLY live device set: model dim fixed (weights must fit),
  data dim = largest divisor of live devices.  Combined with
  checkpoint.restore(shardings=...) this is the elastic-restart path:
  lose a host, rebuild a smaller mesh, reshard, continue.
"""
from __future__ import annotations

import math
import signal
import threading
import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import numpy as np


class PreemptionHandler:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGUSR1)):
        self._flag = threading.Event()
        self._installed = False
        self._signals = signals

    def install(self) -> "PreemptionHandler":
        if not self._installed:
            for s in self._signals:
                try:
                    signal.signal(s, self._on_signal)
                except ValueError:  # non-main thread (tests)
                    pass
            self._installed = True
        return self

    def _on_signal(self, signum, frame):
        self._flag.set()

    def trigger(self) -> None:  # for tests / manual drills
        self._flag.set()

    @property
    def preempted(self) -> bool:
        return self._flag.is_set()


@dataclass
class StepTimer:
    """EMA step-time straggler detector."""
    alpha: float = 0.1
    threshold: float = 2.0     # x mean => straggler
    warmup: int = 5
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    stragglers: List[int] = field(default_factory=list)
    _t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> float:
        dt = time.perf_counter() - self._t0
        self.observe(step, dt)
        return dt

    def observe(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.n <= self.warmup:
            # seed the EMA
            self.mean = dt if self.n == 1 else (self.mean + dt) / 2
            return False
        is_straggler = dt > self.threshold * max(self.mean, 1e-9)
        if is_straggler:
            self.stragglers.append(step)
        # straggler steps don't poison the EMA
        w = self.alpha if not is_straggler else self.alpha * 0.1
        self.var = (1 - w) * self.var + w * (dt - self.mean) ** 2
        self.mean = (1 - w) * self.mean + w * dt
        return is_straggler


def elastic_mesh(model_dim: int = 1, devices=None):
    """Largest (data, model) mesh from the live device set.

    model_dim is fixed by weight sharding; data = floor(live / model_dim),
    rounded down to a power of two so batch sharding stays divisible.
    """
    devices = list(devices if devices is not None else jax.devices())
    live = len(devices)
    if live < model_dim:
        raise RuntimeError(f"only {live} devices live; need >= model_dim={model_dim}")
    data = live // model_dim
    data = 2 ** int(math.log2(data)) if data > 0 else 1
    n = data * model_dim
    try:
        return jax.make_mesh((data, model_dim), ("data", "model"),
                             devices=devices[:n])
    except TypeError:
        from jax.sharding import Mesh
        return Mesh(np.asarray(devices[:n]).reshape(data, model_dim),
                    ("data", "model"))
