from repro.data.pipeline import DataConfig, SyntheticLMData, Prefetcher

__all__ = ["DataConfig", "SyntheticLMData", "Prefetcher"]
