"""Deterministic synthetic LM data pipeline with prefetch + exact resume.

Tokens follow a noisy affine map ``x_{t+1} = (a x_t + b) mod V`` with
epsilon-uniform corruption — a low-entropy, learnable language so training
examples show real loss curves without external data.  Batch ``i`` is a
pure function of (seed, i): resuming at step i reproduces the exact
stream (checkpoint restores just carry the step counter).

``Prefetcher`` overlaps host-side batch synthesis with device compute via
a background thread and a bounded queue (the standard input-pipeline
overlap trick; see DESIGN.md §Overlap).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    batch: int = 8
    seq: int = 256
    vocab: int = 256
    seed: int = 17
    noise: float = 0.1
    a: int = 31
    b: int = 7


class SyntheticLMData:
    def __init__(self, cfg: DataConfig, model_cfg: Optional[ModelConfig] = None):
        self.cfg = cfg
        self.model_cfg = model_cfg

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng((c.seed, step))
        x0 = rng.integers(0, c.vocab, size=(c.batch, 1))
        toks = [x0]
        for _ in range(c.seq):
            nxt = (c.a * toks[-1] + c.b) % c.vocab
            corrupt = rng.random((c.batch, 1)) < c.noise
            rand = rng.integers(0, c.vocab, size=(c.batch, 1))
            toks.append(np.where(corrupt, rand, nxt))
        seq = np.concatenate(toks, axis=1).astype(np.int32)  # [B, S+1]
        out = {"tokens": seq[:, :-1], "labels": seq[:, 1:]}
        mc = self.model_cfg
        if mc is not None and mc.family == "vlm":
            out["patch_embeds"] = rng.standard_normal(
                (c.batch, mc.n_patches, mc.d_model)).astype(np.float32)
        if mc is not None and mc.family == "encdec":
            out["frames"] = rng.standard_normal(
                (c.batch, c.seq, mc.d_model)).astype(np.float32)
        return out

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Bounded-queue background prefetch; exceptions propagate on get()."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._err: Optional[BaseException] = None
        self._stop = threading.Event()

        def worker():
            try:
                for item in it:
                    if self._stop.is_set():
                        return
                    self._q.put(item)
            except BaseException as e:  # noqa: BLE001
                self._err = e
                self._q.put(None)

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def get(self):
        item = self._q.get()
        if item is None and self._err is not None:
            raise self._err
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
