import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first init).  This module is the ONLY place that forces 512
# placeholder devices — smoke tests and benches see the real 1-CPU world.

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract roofline inputs from the compiled artifact.

Usage:
  python -m repro.launch.dryrun --arch smollm-135m --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--force]

Each cell writes ``artifacts/dryrun/<arch>__<shape>__<mesh>.json`` with
memory analysis, cost analysis, and per-collective byte counts.  Failures
here (sharding mismatch, OOM at compile, unsupported collective) are bugs
in the framework — the sweep fails loudly.
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

import jax

from repro.configs import SHAPES_BY_NAME, cells, get_config, get_shape
from repro.distributed.sharding import activation_rules, param_pspecs
from repro.launch import hlo_cost
from repro.launch import specs as S
from repro.launch.hlo_analysis import Roofline, essential_bytes, model_flops
from repro.launch.mesh import make_production_mesh
from repro.models import get_model, input_specs
from repro.optim import OptConfig, init_train_state, make_train_step

ART = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _mem_analysis(compiled):
    ma = compiled.memory_analysis()
    out = {}
    for f in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "temp_size_in_bytes", "alias_size_in_bytes"):
        v = getattr(ma, f, None)
        if v is not None:
            out[f] = int(v)
    if not out and ma is not None:
        out["repr"] = str(ma)
    return out


def _ns_tree(mesh, pspec_tree):
    from jax.sharding import NamedSharding, PartitionSpec as P
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def lower_cell(arch: str, shape_name: str, mesh_kind: str,
               serve_bf16: bool = False, kv_quant: bool = False):
    import dataclasses as _dc
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if serve_bf16 and shape_name.startswith(("decode", "long", "prefill")):
        # production serving stores weights in the compute dtype
        cfg = _dc.replace(cfg, param_dtype="bfloat16")
    if kv_quant and shape_name.startswith(("decode", "long")):
        cfg = _dc.replace(cfg, kv_quant=True)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    model = get_model(cfg)
    rules = S.make_rules(mesh, cfg)
    sp = input_specs(cfg, shape)

    with mesh:
        with activation_rules(rules):
            if shape.kind == "train":
                ocfg = OptConfig()
                step = make_train_step(model, ocfg)
                state_sds = jax.eval_shape(
                    lambda: init_train_state(model.init(jax.random.PRNGKey(0)), ocfg))
                state_sh = S.state_shardings(mesh, cfg, state_sds)
                batch_sh = S.batch_shardings(mesh, cfg, sp["batch"])
                jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                                 out_shardings=(state_sh, None),
                                 donate_argnums=(0,))
                lowered = jitted.lower(state_sds, sp["batch"])
            elif shape.kind == "prefill":
                params_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
                params_sh = _ns_tree(mesh, param_pspecs(params_sds, cfg.tie_embeddings, dict(mesh.shape)))
                batch_sh = S.batch_shardings(mesh, cfg, sp["batch"])

                def prefill_step(params, batch):
                    return model.prefill(params, batch)

                jitted = jax.jit(prefill_step, in_shardings=(params_sh, batch_sh))
                lowered = jitted.lower(params_sds, sp["batch"])
            else:  # decode
                params_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
                params_sh = _ns_tree(mesh, param_pspecs(params_sds, cfg.tie_embeddings, dict(mesh.shape)))
                cache_sh = S.cache_shardings(mesh, cfg, sp["cache"], shape)
                tok_sh = S.token_shardings(mesh, shape)

                def serve_step(params, cache, tokens):
                    return model.decode_step(params, cache, tokens)

                jitted = jax.jit(serve_step,
                                 in_shardings=(params_sh, cache_sh, tok_sh),
                                 donate_argnums=(1,))
                lowered = jitted.lower(params_sds, sp["cache"], sp["tokens"])
    return cfg, shape, mesh, lowered


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
             dump_hlo: bool = False, serve_bf16: bool = False,
             kv_quant: bool = False) -> dict:
    t0 = time.time()
    cfg, shape, mesh, lowered = lower_cell(arch, shape_name, mesh_kind,
                                           serve_bf16=serve_bf16,
                                           kv_quant=kv_quant)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = _mem_analysis(compiled)
    print(f"memory_analysis: {mem}")
    # cost_analysis() returns one dict on current JAX, a list of per-device
    # dicts on older releases
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    cost = dict(cost)
    print(f"cost_analysis (loops-once): flops={cost.get('flops')} "
          f"bytes={cost.get('bytes accessed')}")
    hlo = compiled.as_text()
    la = hlo_cost.analyze(hlo)  # loop-aware: multiplies scan trip counts
    chips = mesh.devices.size

    rl = Roofline(
        flops_per_device=la["flops"],
        hbm_bytes_per_device=la["bytes"],
        collective_bytes_per_device=la["collective_bytes"],
        chips=chips,
        model_flops_total=model_flops(cfg, shape),
    )
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "chips": chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem,
        "cost_flops_loops_once": float(cost.get("flops", 0.0)),
        "cost_bytes_loops_once": float(cost.get("bytes accessed", 0.0)),
        "collectives": la["collectives"],
        "roofline": rl.to_dict(),
        "essential_bytes_per_device": essential_bytes(cfg, shape, chips),
        "attn_score_bytes": la.get("attn_score_bytes", 0.0),
        "convert_bytes": la.get("convert_bytes", 0.0),
        "hlo_bytes": len(hlo),
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"{arch}__{shape_name}__{mesh_kind}.json"
    path.write_text(json.dumps(rec, indent=1))
    if dump_hlo:
        (out_dir / f"{arch}__{shape_name}__{mesh_kind}.hlo.txt").write_text(hlo)
    print(f"[dryrun] OK {arch} x {shape_name} x {mesh_kind}: "
          f"lower {t_lower:.1f}s compile {t_compile:.1f}s "
          f"bottleneck={rl.bottleneck} t=({rl.t_compute:.4f},{rl.t_memory:.4f},"
          f"{rl.t_collective:.4f})s -> {path.name}")
    return rec


def sweep(mesh_kinds, force: bool, out_dir: Path):
    """Run every applicable cell in a fresh subprocess (clean device state,
    bounded compiler memory); resumable — existing JSONs are skipped."""
    todo = []
    for arch, shape_name in cells():
        for mk in mesh_kinds:
            path = out_dir / f"{arch}__{shape_name}__{mk}.json"
            if path.exists() and not force:
                continue
            todo.append((arch, shape_name, mk))
    print(f"[dryrun] {len(todo)} cells to run")
    failures = []
    for i, (arch, shape_name, mk) in enumerate(todo):
        print(f"[dryrun] ({i+1}/{len(todo)}) {arch} x {shape_name} x {mk}")
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
             "--shape", shape_name, "--mesh", mk, "--out", str(out_dir)],
            capture_output=True, text=True)
        if r.returncode != 0:
            failures.append((arch, shape_name, mk))
            print(f"[dryrun] FAIL {arch} x {shape_name} x {mk}\n{r.stdout[-2000:]}\n{r.stderr[-4000:]}")
        else:
            print(r.stdout.strip().splitlines()[-1] if r.stdout.strip() else "")
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES: {failures}")
        sys.exit(1)
    print("[dryrun] sweep complete, all cells green")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--dump-hlo", action="store_true")
    ap.add_argument("--serve-bf16", action="store_true",
                    help="store serving weights in bf16 (production default; "
                         "kept off for the baseline table)")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache for decode cells (§Perf C3)")
    ap.add_argument("--out", default=str(ART))
    args = ap.parse_args()
    out_dir = Path(args.out)
    mesh_kinds = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        sweep(mesh_kinds, args.force, out_dir)
        return
    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    for mk in mesh_kinds:
        run_cell(args.arch, args.shape, mk, out_dir, args.dump_hlo,
                 serve_bf16=args.serve_bf16, kv_quant=args.kv_quant)


if __name__ == "__main__":
    main()
