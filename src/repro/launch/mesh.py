"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (smoke tests and benches must see 1 device).

Single pod: 16x16 = 256 chips ("data", "model").
Multi-pod:  2x16x16 = 512 chips ("pod", "data", "model") — the "pod" axis
is a second data-parallel dimension spanning the (slower) inter-pod links.
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)}. "
            "Set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import (dryrun.py does this for you).")
    try:
        return jax.make_mesh(shape, axes, devices=devices[:n])
    except TypeError:  # older jax without the devices kwarg
        import numpy as np
        from jax.sharding import Mesh
        return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def data_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def make_sweep_mesh(*, max_devices: int = None):
    """1-D ("cells",) mesh over the local devices for sweep-grid table
    builds (``cachesim.sweep.run_grid(backend="jax")``): decision cells
    are row-sharded along it, the shared view history replicated.

    Returns None with <= 1 visible device — the sweep path then runs the
    same jitted computation unsharded, so single-device CI needs no
    special casing.  CPU hosts can fake a multi-device mesh with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` set before
    any jax import.
    """
    devices = jax.devices()
    if max_devices is not None:
        devices = devices[:max_devices]
    n = len(devices)
    if n <= 1:
        return None
    try:
        return jax.make_mesh((n,), ("cells",), devices=devices)
    except TypeError:  # older jax without the devices kwarg
        import numpy as np
        from jax.sharding import Mesh
        return Mesh(np.asarray(devices), ("cells",))
