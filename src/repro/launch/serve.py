"""Serving launcher: model engine + FNA prefix-cache routing tier.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --requests 200 --policy fna_cal

On a pod, the same entry point runs the engine under the production mesh
(decode shardings from launch/specs.py) with one router process per
front-end; here it drives the full data path single-host: route -> probe ->
(hit: reuse prefix KV | miss: real prefill) -> decode -> place.

``--replay`` switches to the concurrent-client router replay harness
(``repro.serving.replay``): N client threads drive a scenario-defined
cluster regime (``--regime``) and the run reports throughput plus
p50/p99 decision latency — model-free (stub KV payloads), so the numbers
isolate the routing path the paper contributes.

  PYTHONPATH=src python -m repro.launch.serve --replay \
      --regime staggered_adverts --requests 8000 --clients 8 \
      --batch-sizes 1,4,16 --json /tmp/replay.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np


def _run_replay(args) -> int:
    from repro.serving.replay import REGIMES, batch_sweep

    batches = [int(b) for b in str(args.batch_sizes).split(",") if b]
    reports = batch_sweep(args.regime, policy=args.policy,
                          batch_sizes=batches, n_requests=args.requests,
                          n_clients=args.clients, mode=args.mode,
                          seed=args.seed)
    for r in reports:
        print(f"[replay] regime={r.regime} policy={r.policy} "
              f"clients={r.n_clients} batch={r.batch_size} "
              f"reqs={r.requests} rps={r.achieved_rps:,.0f} "
              f"p50={r.p50_us:.1f}us p99={r.p99_us:.1f}us "
              f"mean-cost={r.mean_cost:.2f} hit={r.hit_ratio:.3f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump([r.to_dict() for r in reports], f, indent=1)
        print(f"[replay] wrote {args.json}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--policy", default="fna_cal",
                    choices=["fna", "fna_cal", "fno", "pi"])
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--node-capacity", type=int, default=64)
    ap.add_argument("--update-interval", type=int, default=32)
    ap.add_argument("--miss-penalty", type=float, default=40.0)
    ap.add_argument("--prefix-len", type=int, default=24)
    ap.add_argument("--decode-steps", type=int, default=8)
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 prefix-KV caches (see EXPERIMENTS.md §Perf C3)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--replay", action="store_true",
                    help="concurrent-client router replay (model-free): "
                         "throughput + p50/p99 decision latency")
    ap.add_argument("--regime", default="hetero_tiers",
                    help="--replay cluster regime (see "
                         "repro.serving.replay.REGIMES)")
    ap.add_argument("--clients", type=int, default=4,
                    help="--replay concurrent client count")
    ap.add_argument("--batch-sizes", default="1",
                    metavar="B[,B...]",
                    help="--replay per-turn request batch sizes; several "
                         "values sweep (fresh cluster each)")
    ap.add_argument("--mode", choices=("threads", "sequential"),
                    default="threads",
                    help="--replay client model: threaded (live "
                         "contention) or deterministic round-robin")
    ap.add_argument("--json", default="",
                    help="--replay: write the reports to this path")
    args = ap.parse_args(argv)

    if args.replay:
        return _run_replay(args)

    import dataclasses

    import jax.numpy as jnp

    from repro.cachesim.traces import recency_trace
    from repro.configs import get_config
    from repro.serving import ClusterConfig, PrefixServeCluster, ServeEngine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.kv_quant and cfg.family in ("dense", "moe", "vlm"):
        cfg = dataclasses.replace(cfg, kv_quant=True)
    engine = ServeEngine(cfg)
    rng = np.random.default_rng(args.seed)
    prefixes = [rng.integers(0, cfg.vocab, (1, args.prefix_len)).astype(np.int32)
                for _ in range(256)]
    stream = recency_trace(args.requests, p_new=0.15, window=96,
                           seed=args.seed + 1)

    ccfg = ClusterConfig(n_nodes=args.nodes, node_capacity=args.node_capacity,
                         update_interval=args.update_interval,
                         miss_penalty=args.miss_penalty, policy=args.policy)
    cluster = PrefixServeCluster(ccfg, seed=args.seed)
    max_len = args.prefix_len + args.decode_steps + 2

    t0 = time.time()
    prefill_s = 0.0
    tokens_out = 0
    for i in range(args.requests):
        pid = int(stream[i])
        toks = prefixes[pid % len(prefixes)]

        def make_kv():
            nonlocal prefill_s
            t1 = time.time()
            _, c = engine.prefill(toks, max_len=max_len)
            prefill_s += time.time() - t1
            return c

        kv, cost = cluster.request(pid, make_kv=make_kv)
        first = jnp.zeros((toks.shape[0],), jnp.int32)
        out, _ = engine.decode(kv, first, args.decode_steps)
        tokens_out += out.size
        if (i + 1) % 50 == 0:
            s = cluster.stats
            print(f"[serve] {i + 1:5d} reqs  mean-cost {s.mean_cost:7.2f}  "
                  f"kv-hit {s.hit_ratio:.3f}  prefills {s.prefills}  "
                  f"neg-probes {s.neg_probes}")
    wall = time.time() - t0
    s = cluster.stats
    print(f"[serve] policy={args.policy} requests={s.requests} "
          f"mean-cost={s.mean_cost:.2f} hit={s.hit_ratio:.3f} "
          f"prefills={s.prefills} neg_probes={s.neg_probes} "
          f"tok/s={tokens_out / wall:,.0f} wall={wall:.1f}s "
          f"(prefill {prefill_s:.1f}s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
