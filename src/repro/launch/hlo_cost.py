"""Loop-aware cost model over optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts every ``while`` body ONCE, but our
models ``lax.scan`` over layers / query chunks / loss chunks, so its FLOP
and byte numbers undercount by orders of magnitude.  This module re-derives
roofline inputs from ``compiled.as_text()`` with trip-count multiplication:

  * FLOPs           — every ``dot`` op: 2 x numel(result) x prod(contracting)
  * HBM bytes       — per materialising op: result + operand bytes
                      (post-fusion HLO only materialises fusion/dot/copy/...
                      boundaries, so this is a fair HBM-traffic model)
  * collective bytes— operand bytes per all-gather / all-reduce /
                      reduce-scatter / all-to-all / collective-permute

All three multiply through the while-loop nest (trip counts recovered from
each loop condition's comparison constant).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\](?:\{[^}]*\})?")
_OPLINE_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_CALLED_RE = re.compile(r"(?:condition|body|calls|to_apply|branch_computations)=\{?%?([\w\.\-]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_TRIVIAL = {"get-tuple-element", "tuple", "bitcast", "parameter", "constant",
            "iota", "after-all", "partition-id", "replica-id"}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                    "collective-permute", "ragged-all-to-all")


def _shape_list(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _bytes_of(type_str: str) -> int:
    tot = 0
    for dt, dims in _shape_list(type_str):
        n = 1
        for d in dims:
            n *= d
        tot += n * _DTYPE_BYTES[dt]
    return tot


@dataclass
class Op:
    name: str
    kind: str
    result_type: str
    rest: str  # text after the opcode


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)  # op name -> result type


_HEADER_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
# opcode appears right before '(' in the defining expression
_KIND_RE = re.compile(r"([\w\-]+)\(")


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _HEADER_RE.match(line)
            if m:
                cur = Computation(m.group(1))
            continue
        if line.strip() == "}" or line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OPLINE_RE.match(line)
        if not m:
            continue
        name, expr = m.group(1), m.group(2)
        # split result type from op expression: type is everything up to the
        # opcode token; find opcode as the token immediately preceding '('.
        km = _KIND_RE.search(expr)
        if not km:
            continue
        kind = km.group(1)
        result_type = expr[: km.start()].strip()
        rest = expr[km.end() - 1:]
        cur.ops.append(Op(name, kind, result_type, rest))
        cur.symbols[name] = result_type
    return comps


def _entry_name(comps: Dict[str, Computation], text: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.M)
    if m:
        return m.group(1)
    # fallback: computation that is not referenced by any other
    referenced = set()
    for c in comps.values():
        for op in c.ops:
            for cm in _CALLED_RE.finditer(op.rest):
                referenced.add(cm.group(1))
    for name in comps:
        if name not in referenced:
            return name
    return next(iter(comps))


def _constant_value(comp: Computation, ref: str) -> Optional[int]:
    for op in comp.ops:
        if op.name == ref and op.kind == "constant":
            # op.rest holds the args after the opcode, e.g. "(12)"
            m = re.match(r"\((-?\d+)\)", op.rest.strip())
            if m:
                return int(m.group(1))
    return None


def trip_count(cond: Computation) -> int:
    """Recover the loop trip count from the condition computation."""
    for op in cond.ops:
        if op.kind == "compare":
            refs = _OPERAND_RE.findall(op.rest)
            for r in refs:
                v = _constant_value(cond, r)
                if v is not None and v > 0:
                    return v
    # fallback: the largest positive integer constant in the computation
    best = 1
    for op in cond.ops:
        if op.kind == "constant":
            m = re.match(r"\((\d+)\)", op.rest.strip())
            if m:
                best = max(best, int(m.group(1)))
    return best


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: Dict[str, float] = field(default_factory=dict)

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        for k, v in other.collectives.items():
            self.collectives[k] = self.collectives.get(k, 0.0) + v
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.bytes * f,
                    {k: v * f for k, v in self.collectives.items()})


def _dot_flops(op: Op, symbols: Dict[str, str]) -> float:
    res_elems = 0
    for dt, dims in _shape_list(op.result_type):
        n = 1
        for d in dims:
            n *= d
        res_elems += n
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    cdims = [int(x) for x in m.group(1).split(",")] if m and m.group(1) else []
    # lhs operand = first %ref inside the parens
    paren = op.rest[op.rest.find("("):]
    refs = _OPERAND_RE.findall(paren)
    k = 1
    if refs and cdims:
        lhs_type = symbols.get(refs[0], "")
        shapes = _shape_list(lhs_type)
        if shapes:
            dims = shapes[0][1]
            for c in cdims:
                if c < len(dims):
                    k *= dims[c]
    return 2.0 * res_elems * k


def _operand_refs(op: Op) -> List[str]:
    paren = op.rest[op.rest.find("("):]
    head = paren.split("metadata=")[0]
    for marker in (", kind=", ", calls=", ", condition=", ", channel_id="):
        head = head.split(marker)[0]
    return _OPERAND_RE.findall(head)


def _op_bytes(op: Op, symbols: Dict[str, str],
              comps: Optional[Dict[str, "Computation"]] = None) -> float:
    refs = _operand_refs(op)
    operand_bytes = [(r, float(_bytes_of(symbols.get(r, "")))) for r in refs]
    result_bytes = float(_bytes_of(op.result_type))

    if op.kind == "dynamic-update-slice":
        # in-place: read+write the updated slice only (operand 1)
        upd = operand_bytes[1][1] if len(operand_bytes) > 1 else 0.0
        return 2.0 * upd
    if op.kind == "dynamic-slice":
        return 2.0 * result_bytes  # read slice + write result

    if op.kind == "fusion" and comps is not None:
        cm = re.search(r"calls=%?([\w\.\-]+)", op.rest)
        fc = comps.get(cm.group(1)) if cm else None
        root = None
        if fc is not None:
            for o in fc.ops:
                root = o  # last op is ROOT in printed HLO
            if root is not None and root.kind == "dynamic-update-slice":
                # in-place updating fusion: reads = non-aliased operands,
                # writes = the updated slice.
                rrefs = _operand_refs(root)
                update_b = float(_bytes_of(fc.symbols.get(rrefs[1], ""))) if len(rrefs) > 1 else 0.0
                total = sum(b for _, b in operand_bytes) + update_b
                # subtract the aliased buffer operand (param index of root operand 0)
                pm = re.match(r"param_(\d+)", rrefs[0]) if rrefs else None
                if pm and int(pm.group(1)) < len(operand_bytes):
                    total -= operand_bytes[int(pm.group(1))][1]
                else:
                    for _, b in operand_bytes:
                        if b == result_bytes:
                            total -= b
                            break
                return max(total, 0.0)
        return result_bytes + sum(b for _, b in operand_bytes)

    return result_bytes + sum(b for _, b in operand_bytes)


def _collective_operand_bytes(op: Op, symbols: Dict[str, str]) -> float:
    paren = op.rest[op.rest.find("("):].split("metadata=")[0]
    refs = _OPERAND_RE.findall(paren.split("),")[0] + ")")
    tot = 0.0
    for r in refs:
        t = symbols.get(r)
        if t:
            tot += _bytes_of(t)
    if tot == 0.0:
        tot = float(_bytes_of(op.result_type))
    return tot


def comp_cost(comps: Dict[str, Computation], name: str,
              memo: Dict[str, Cost]) -> Cost:
    if name in memo:
        return memo[name]
    memo[name] = Cost()  # cycle guard
    c = comps.get(name)
    if c is None:
        return memo[name]
    total = Cost()
    for op in c.ops:
        if op.kind == "while":
            cm = re.search(r"condition=%?([\w\.\-]+)", op.rest)
            bm = re.search(r"body=%?([\w\.\-]+)", op.rest)
            if cm and bm:
                tm = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.rest)
                if tm:
                    n = int(tm.group(1))
                else:
                    n = trip_count(comps[cm.group(1)]) if cm.group(1) in comps else 1
                body = comp_cost(comps, bm.group(1), memo)
                total += body.scaled(max(n, 1))
                # while-carried buffer traffic is inside the body already
            continue
        if op.kind in ("call", "conditional", "async-start"):
            for cm in _CALLED_RE.finditer(op.rest):
                total += comp_cost(comps, cm.group(1), memo)
            continue
        base_kind = op.kind[:-6] if op.kind.endswith("-start") else op.kind
        if base_kind in COLLECTIVE_KINDS:
            b = _collective_operand_bytes(op, c.symbols)
            total += Cost(0.0, b, {base_kind: b})
            continue
        if op.kind == "fusion":
            # boundary traffic for the fusion + any dots fused INSIDE it
            # (XLA:CPU root-fuses small dots)
            dflops = 0.0
            cm = re.search(r"calls=%?([\w\.\-]+)", op.rest)
            fc = comps.get(cm.group(1)) if cm else None
            if fc is not None:
                for o in fc.ops:
                    if o.kind == "dot":
                        dflops += _dot_flops(o, fc.symbols)
            total += Cost(dflops, _op_bytes(op, c.symbols, comps), {})
            continue
        if op.kind == "dot":
            total += Cost(_dot_flops(op, c.symbols), _op_bytes(op, c.symbols, comps), {})
            continue
        if op.kind in _TRIVIAL:
            continue
        # other materialising ops (copy, reduce, dynamic-slice, DUS, ...)
        total += Cost(0.0, _op_bytes(op, c.symbols, comps), {})
    memo[name] = total
    return total


def top_ops(text: str, n: int = 15) -> List[Tuple[float, str, str, str]]:
    """Top byte-contributing ops with loop multipliers (debug/hillclimb aid)."""
    comps = parse_module(text)
    entry = _entry_name(comps, text)
    rows: List[Tuple[float, str, str, str]] = []

    def walk(name: str, mult: float):
        c = comps.get(name)
        if c is None:
            return
        for op in c.ops:
            if op.kind == "while":
                tm = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.rest)
                k = int(tm.group(1)) if tm else 1
                bm = re.search(r"body=%?([\w\.\-]+)", op.rest)
                if bm:
                    walk(bm.group(1), mult * max(k, 1))
                continue
            if op.kind in ("call", "conditional"):
                for cm in _CALLED_RE.finditer(op.rest):
                    walk(cm.group(1), mult)
                continue
            if op.kind in _TRIVIAL:
                continue
            rows.append((_op_bytes(op, c.symbols, comps) * mult, name, op.kind,
                         f"{op.name} :: {op.result_type[:70]}"))

    walk(entry, 1.0)
    rows.sort(key=lambda r: -r[0])
    return rows[:n]


def attribute_bytes(text: str, patterns: Dict[str, str]) -> Dict[str, float]:
    """Loop-aware byte attribution: for each named regex, sum bytes of ops
    whose NAME or metadata op_name matches.  Used by §Perf to quantify
    (a) attention-score traffic the Pallas flash kernel removes on TPU and
    (b) dtype-convert traffic that is a CPU-backend artifact."""
    comps = parse_module(text)
    entry = _entry_name(comps, text)
    res = {name: 0.0 for name in patterns}
    regs = {name: re.compile(pat) for name, pat in patterns.items()}

    def walk(name: str, mult: float):
        c = comps.get(name)
        if c is None:
            return
        for op in c.ops:
            if op.kind == "while":
                tm = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.rest)
                k = int(tm.group(1)) if tm else 1
                bm = re.search(r"body=%?([\w\.\-]+)", op.rest)
                if bm:
                    walk(bm.group(1), mult * max(k, 1))
                continue
            if op.kind in ("call", "conditional"):
                for cm in _CALLED_RE.finditer(op.rest):
                    walk(cm.group(1), mult)
                continue
            if op.kind in _TRIVIAL:
                continue
            hay = op.name + " " + op.rest
            for pname, rg in regs.items():
                if rg.search(hay):
                    res[pname] += _op_bytes(op, c.symbols, comps) * mult
                    break

    walk(entry, 1.0)
    return res


# Patterns for the standard attributions (op names + jax op_name metadata).
ATTN_SCORE_PAT = (r"bhgqk|bqhgd|softmax|reduce_max|subtract_exponential|"
                  r"broadcast_divide|exponential")
CONVERT_PAT = r"convert"


def analyze(text: str) -> Dict[str, float]:
    comps = parse_module(text)
    entry = _entry_name(comps, text)
    cost = comp_cost(comps, entry, {})
    colls = {k: cost.collectives.get(k, 0.0) for k in COLLECTIVE_KINDS}
    attr = attribute_bytes(text, {"attention_score": ATTN_SCORE_PAT,
                                  "dtype_convert": CONVERT_PAT})
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "collective_bytes": sum(colls.values()),
        "collectives": colls,
        "attn_score_bytes": attr["attention_score"],
        "convert_bytes": attr["dtype_convert"],
        "entry": entry,
        "n_computations": len(comps),
    }
