"""Per-(arch, shape, mesh) sharding specs for the dry-run and launchers."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import MeshRules, param_pspecs
from repro.launch.mesh import data_axes

PyTree = Any


def _dp(mesh) -> Any:
    ax = data_axes(mesh)
    return ax if len(ax) > 1 else ax[0]


def _ns(mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def state_shardings(mesh: Mesh, cfg: ModelConfig, state_specs: PyTree) -> PyTree:
    """TrainState sharding: params follow the TP rules; optimizer states
    (m/v/ef) additionally shard over the data axes (ZeRO-1); step replicated."""
    from repro.distributed.sharding import zero1_pspecs

    axis_sizes = dict(mesh.shape)
    daxes = data_axes(mesh)
    out = {}
    for key, sub in state_specs.items():
        if key == "step":
            out[key] = _ns(mesh, P())
        elif key in ("m", "v", "ef"):
            pspecs = zero1_pspecs(sub, cfg.tie_embeddings, axis_sizes, daxes)
            out[key] = jax.tree.map(lambda s: _ns(mesh, s), pspecs,
                                    is_leaf=lambda s: isinstance(s, P))
        else:
            pspecs = param_pspecs(sub, tied=cfg.tie_embeddings, axis_sizes=axis_sizes)
            out[key] = jax.tree.map(lambda s: _ns(mesh, s), pspecs,
                                    is_leaf=lambda s: isinstance(s, P))
    return out


def batch_shardings(mesh: Mesh, cfg: ModelConfig, batch_specs: PyTree) -> PyTree:
    dp = _dp(mesh)

    def spec(name, sds):
        if sds.ndim == 2:
            return _ns(mesh, P(dp, None))
        return _ns(mesh, P(dp, None, None))  # patch_embeds / frames

    return {k: spec(k, v) for k, v in batch_specs.items()}


def cache_shardings(mesh: Mesh, cfg: ModelConfig, cache_specs: PyTree,
                    shape: ShapeConfig) -> PyTree:
    """Decode caches.

    KV caches shard the *sequence* dim on "model" (always divisible; XLA
    partitions the masked-softmax reduction into the flash-decode pattern:
    local partial scores + tiny stat all-reduces).  Batch shards on the data
    axes.  For B=1 (long_500k) the sequence spreads over EVERY mesh axis and
    SSM states shard their heads on "model".
    """
    dp = _dp(mesh)
    b = shape.global_batch
    single_seq = b == 1
    all_axes = tuple(mesh.axis_names)

    def spec(path, sds):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("k", "v", "ck", "cv"):
            # [L_or_apps, B, S, Hkv, Dh]
            if single_seq:
                return _ns(mesh, P(None, None, all_axes, None, None))
            return _ns(mesh, P(None, dp, "model", None, None))
        if name in ("k_s", "v_s"):  # int8-KV scales [L, B, S, Hkv]
            if single_seq:
                return _ns(mesh, P(None, None, all_axes, None))
            return _ns(mesh, P(None, dp, "model", None))
        if name == "state":  # [L, B, H, Pd, N]
            return _ns(mesh, P(None, None if single_seq else dp, "model", None, None))
        if name == "conv":  # [L, B, W-1, conv_dim]
            return _ns(mesh, P(None, None if single_seq else dp, None, "model"))
        return _ns(mesh, P())  # len

    return jax.tree_util.tree_map_with_path(spec, cache_specs)


def token_shardings(mesh: Mesh, shape: ShapeConfig) -> NamedSharding:
    dp = _dp(mesh)
    return _ns(mesh, P(dp) if shape.global_batch > 1 else P())


def make_rules(mesh: Mesh, cfg: ModelConfig) -> MeshRules:
    return MeshRules(mesh=mesh, data_axes=data_axes(mesh),
                     seq_parallel=cfg.seq_parallel)
