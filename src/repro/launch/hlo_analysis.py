"""Parse compiled/lowered HLO text for collective traffic + roofline terms.

``cost_analysis()`` gives FLOPs and HBM bytes but not collective bytes, so
we sum operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute in the (SPMD-partitioned, per-device) HLO.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

# TPU v5e hardware constants (targets; this container is CPU-only).
PEAK_FLOPS = 197e12       # bf16 FLOP/s per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link (~per-chip collective bandwidth)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z]?[a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _line_collective(line: str) -> Optional[str]:
    # match '= bf16[..] all-reduce(' / 'all-gather-start(' etc.
    for c in _COLLECTIVES:
        if re.search(rf"\b{c}(-start)?\(", line):
            return c
    return None


def parse_collectives(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective kind (per-device, per-step)."""
    out: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        kind = _line_collective(line)
        if kind is None:
            continue
        paren = line.find("(")
        # operand shapes appear inline in the argument list
        args = line[paren:]
        shapes = _SHAPE_RE.findall(args)
        n = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        if n == 0:  # fall back to result type(s), before '='
            head = line[:paren]
            shapes = _SHAPE_RE.findall(head.split("=")[-1])
            n = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        out[kind] += n
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


@dataclass
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    chips: int
    model_flops_total: float = 0.0  # 6*N*D (train) / 2*N*D (inference)

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total_hlo = self.flops_per_device * self.chips
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    @property
    def step_time(self) -> float:
        """Roofline-model step time: max of the three terms (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilisation at the roofline-model step time."""
        if self.step_time == 0:
            return 0.0
        return (self.model_flops_total / self.chips / self.step_time) / PEAK_FLOPS

    def to_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "chips": self.chips,
            "model_flops_total": self.model_flops_total,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_step_time_s": self.step_time,
            "mfu_at_roofline": self.mfu,
        }


def essential_bytes(cfg, shape, chips: int, model_shards: int = 16) -> float:
    """Analytic LOWER BOUND on per-device HBM traffic per step.

    Counts only unavoidable traffic: parameter/optimizer IO, KV/state cache
    read+write (decode), and one residual-stream read+write per layer.
    The HLO-derived number sits above this; the gap is softmax/score
    materialisation, dtype-convert and layout-copy artifacts (CPU backend),
    and remat recompute traffic.
    """
    p = cfg.param_count()
    p_dev = p / model_shards
    d = cfg.d_model
    layers = cfg.n_layers + (cfg.enc_layers or 0)
    if shape.kind == "train":
        # fwd read (bf16 cast) + grad write + adam m/v read+write + param rw (fp32)
        params_io = p_dev * (2 + 4 + 4 * 4 + 4 * 2)
        tokens_dev = shape.global_batch * shape.seq_len / (chips / model_shards)
        act_io = layers * tokens_dev * d * 2 * 2 * 3  # resid in/out, fwd+bwd+remat
        return params_io + act_io
    if shape.kind == "prefill":
        params_io = p_dev * 2
        tokens_dev = shape.global_batch * shape.seq_len / (chips / model_shards)
        act_io = layers * tokens_dev * d * 2 * 2
        kv_write = 2 * layers * tokens_dev * cfg.n_kv_heads * cfg.head_dim * 2
        return params_io + act_io + kv_write
    # decode: params + full cache read + new-token write
    params_io = p_dev * 2
    if cfg.family in ("ssm",):
        cache = cfg.n_layers * shape.global_batch * cfg.ssm_heads * cfg.ssm_headdim * cfg.ssm_state * 4
    elif cfg.family == "hybrid":
        napps = cfg.n_layers // cfg.shared_every
        cache = (cfg.n_layers * shape.global_batch * cfg.ssm_heads * cfg.ssm_headdim * cfg.ssm_state * 4
                 + napps * shape.global_batch * shape.seq_len * cfg.n_kv_heads * cfg.head_dim * 2 * 2)
    else:
        cache = 2 * layers * shape.global_batch * shape.seq_len * cfg.n_kv_heads * cfg.head_dim * 2
    # cache is sharded across all chips: read once per step; the one-token
    # write is negligible next to the read.
    return params_io + cache / chips


def model_flops(cfg, shape) -> float:
    """Useful model FLOPs for the cell: 6*N*D train, 2*N*D prefill,
    2*N*B decode (+ attention KV-read term for decode handled in memory)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: one token per seq
