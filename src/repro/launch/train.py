"""Production training launcher.

Single-host CPU example (runs today):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On a pod the same entry point runs under the production mesh (--mesh pod)
with the dry-run's shardings; jax.distributed.initialize() is called when
the scheduler environment provides coordinator addresses.

Fault-tolerance drill:
  * SIGTERM mid-run -> checkpoint + exit code 42 (scheduler restarts with
    --resume and training continues bit-exactly: data stream is a pure
    function of the step counter).
  * --kill-at N simulates a preemption at step N (used by tests).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint import CheckpointManager, latest_step, restore
from repro.configs import get_config
from repro.data import DataConfig, Prefetcher, SyntheticLMData
from repro.distributed.ft import PreemptionHandler, StepTimer, elastic_mesh
from repro.distributed.sharding import activation_rules, param_shardings
from repro.models import get_model
from repro.optim import OptConfig, init_train_state, make_train_step

EXIT_PREEMPTED = 42


def build(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg)
    ocfg = OptConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1),
                     total_steps=args.steps,
                     compression="int8_ef" if args.compress_grads else "none")
    data = SyntheticLMData(DataConfig(batch=args.batch, seq=args.seq,
                                      vocab=min(cfg.vocab, 256), seed=args.seed),
                           model_cfg=cfg)
    return cfg, model, ocfg, data


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-interval", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--mesh", default="none", choices=["none", "elastic"])
    ap.add_argument("--model-dim", type=int, default=1)
    ap.add_argument("--kill-at", type=int, default=-1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg, model, ocfg, data = build(args)
    train_step = make_train_step(model, ocfg)

    mesh = None
    shardings = None
    if args.mesh == "elastic":
        mesh = elastic_mesh(model_dim=args.model_dim)
        print(f"[train] elastic mesh: {dict(mesh.shape)}")

    rng = jax.random.PRNGKey(args.seed)
    state_abs = jax.eval_shape(lambda: init_train_state(model.init(rng), ocfg))
    if mesh is not None:
        shardings = {
            k: (param_shardings(mesh, v, cfg.tie_embeddings)
                if k != "step" else jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec()))
            for k, v in state_abs.items()}

    start = 0
    if args.resume and args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        state = restore(args.ckpt_dir, state_abs, shardings=shardings)
        start = int(np.asarray(jax.device_get(state["step"])))
        print(f"[train] resumed from step {start}")
    else:
        state = init_train_state(model.init(rng), ocfg)
        if shardings is not None:
            state = jax.device_put(state, shardings)

    jit_kwargs = {"donate_argnums": (0,)}
    if shardings is not None:
        jit_kwargs.update(in_shardings=(shardings, None),
                          out_shardings=(shardings, None))
    step_fn = jax.jit(train_step, **jit_kwargs)

    ckpt = CheckpointManager(args.ckpt_dir, interval=args.ckpt_interval) \
        if args.ckpt_dir else None
    preempt = PreemptionHandler().install()
    timer = StepTimer()
    prefetch = Prefetcher(data.iterate(start_step=start))
    tokens_per_step = args.batch * args.seq

    try:
        for step in range(start, args.steps):
            if args.kill_at == step:
                preempt.trigger()
            if preempt.preempted:
                if ckpt:
                    ckpt.maybe_save(state, step, force=True)
                    ckpt.wait()
                print(f"[train] preempted at step {step}; checkpointed, exit {EXIT_PREEMPTED}")
                return EXIT_PREEMPTED
            batch = prefetch.get()
            timer.start()
            with activation_rules(None):
                state, metrics = step_fn(state, batch)
            loss = float(jax.device_get(metrics["loss"]))
            dt = timer.stop(step)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(jax.device_get(metrics['grad_norm'])):.3f} "
                      f"{tokens_per_step / max(dt, 1e-9):,.0f} tok/s "
                      f"{dt * 1e3:.0f} ms")
            if ckpt:
                ckpt.maybe_save(state, step + 1)
        if ckpt:
            ckpt.maybe_save(state, args.steps, force=True)
            ckpt.wait()
        if timer.stragglers:
            print(f"[train] straggler steps: {timer.stragglers}")
        print(f"[train] done: {args.steps} steps, final loss {loss:.4f}")
        return 0
    finally:
        prefetch.close()


if __name__ == "__main__":
    sys.exit(main())
