"""Cost model for indicator-based multi-cache access (paper Sec. II).

Scalar/numpy implementations used by the trace simulator and the policies;
``repro.core.batched`` holds the vectorised JAX twin used by the serving
router.  Equation numbers reference the paper.

Note: Algorithm 2 line 6 of the paper prints h = (q - FN)/(1 - FP - FN);
inverting Eq. (1) actually gives h = (q - FP)/(1 - FP - FN), which is what
we implement (the printed form is a typo — it does not invert Eq. (1)).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from itertools import combinations
from typing import Iterable, List, Sequence, Tuple

EPS = 1e-12


def clamp01(x: float) -> float:
    return min(1.0, max(0.0, x))


def positive_indication_ratio(h: float, fp: float, fn: float) -> float:
    """Eq. (1):  q = h (1-FN) + (1-h) FP."""
    return h * (1.0 - fn) + (1.0 - h) * fp


def hit_ratio_from_q(q: float, fp: float, fn: float) -> float:
    """Inverse of Eq. (1):  h = (q - FP) / (1 - FP - FN), clamped to [0,1]."""
    denom = 1.0 - fp - fn
    if abs(denom) < EPS:
        return clamp01(q)
    return clamp01((q - fp) / denom)


def exclusion_probabilities(h: float, fp: float, fn: float) -> Tuple[float, float]:
    """Eqs. (2)-(3): positive/negative exclusion probabilities (pi, nu).

    pi = Pr(x not in S | I(x)=1) = FP (1-h) / q
    nu = Pr(x not in S | I(x)=0) = (1-FP)(1-h) / (1-q)
    """
    q = positive_indication_ratio(h, fp, fn)
    pi = clamp01(fp * (1.0 - h) / q) if q > EPS else 1.0
    nu = clamp01((1.0 - fp) * (1.0 - h) / (1.0 - q)) if (1.0 - q) > EPS else 0.0
    return pi, nu


def is_sufficiently_accurate(fp: float, fn: float) -> bool:
    """Sec. II: FP + FN < 1."""
    return fp + fn < 1.0


def service_cost(costs: Sequence[float], rhos: Sequence[float], miss_penalty: float,
                 selected: Iterable[int]) -> float:
    """Eq. (10): phi(D) = sum_{j in D} c_j + M * prod_{j in D} rho_j."""
    sel = list(selected)
    c = sum(costs[j] for j in sel)
    p = miss_penalty
    for j in sel:
        p *= rhos[j]
    return c + p


def phi_hat(r0: int, r1: int, nu: float, pi: float, miss_penalty: float) -> float:
    """Eq. (5), fully-homogeneous objective."""
    return r0 + r1 + miss_penalty * (nu ** r0) * (pi ** r1)


@dataclass
class CacheView:
    """Client-side view of one cache (inputs to the CS policies)."""
    cost: float
    fp: float
    fn: float
    q: float  # estimated positive-indication ratio (EWMA, Eq. 9)

    @property
    def h(self) -> float:
        return hit_ratio_from_q(self.q, self.fp, self.fn)

    def exclusions(self) -> Tuple[float, float]:
        return exclusion_probabilities(self.h, self.fp, self.fn)
