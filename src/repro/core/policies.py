"""Cache-selection policies.

* :func:`hocs_fna`       — Algorithm 1 (optimal, fully-homogeneous; Thm. 4)
* :func:`ds_pgm`         — the FNO subroutine of [14] (prefix evaluation in
                           potential-gain order; log(M)-approx for the
                           restricted CS problem)
* :func:`exhaustive`     — exact minimiser of Eq. (10) (small n)
* :func:`cs_fna`         — Algorithm 2: false-negative AWARE selection via
                           the Theorem-7 reduction (negative-indication
                           caches participate with rho = nu)
* :func:`cs_fno`         — false-negative OBLIVIOUS baseline: positive
                           indications only, rho = pi (nu treated as 1)
* :func:`perfect_information` — the PI lower-bound strategy
"""
from __future__ import annotations

import math
from typing import Callable, List, Sequence, Tuple

from repro.core.model import (
    EPS,
    CacheView,
    exclusion_probabilities,
    phi_hat,
    service_cost,
)

Selection = List[int]
RestrictedAlg = Callable[[Sequence[float], Sequence[float], float], Selection]


# ---------------------------------------------------------------------------
# Algorithm 1: fully homogeneous
# ---------------------------------------------------------------------------

def _argmin_geometric(m_eff: float, rho: float, r_max: int) -> int:
    """argmin_{0<=r<=r_max} r + m_eff * rho^r  (strictly convex in r)."""
    if r_max <= 0:
        return 0
    if rho <= EPS:
        return 1 if m_eff > 1.0 else 0
    if rho >= 1.0 - EPS:
        return 0
    # continuous optimum: r* = ln(m_eff * ln(1/rho)) / ln(1/rho)
    l = math.log(1.0 / rho)
    r_cont = math.log(max(m_eff * l, EPS)) / l
    best_r, best_v = 0, m_eff
    # ascending candidate order + strict-improvement test: on a tie (within
    # EPS) the smaller r is kept, as documented
    for r in sorted({0, 1, int(math.floor(r_cont)), int(math.ceil(r_cont)), r_max}):
        if 0 <= r <= r_max:
            v = r + m_eff * rho ** r
            if v < best_v - EPS:
                best_r, best_v = r, v
    return best_r


def hocs_fna(n_x: int, n: int, pi: float, nu: float, miss_penalty: float
             ) -> Tuple[int, int]:
    """Algorithm 1: returns (r0*, r1*) = #negative / #positive accesses."""
    r1 = _argmin_geometric(miss_penalty, pi, n_x)
    r0 = 0
    residual = miss_penalty * (pi ** r1)
    if residual > 1.0:
        r0 = _argmin_geometric(residual, nu, n - n_x)
    return r0, r1


# ---------------------------------------------------------------------------
# Heterogeneous subroutines (restricted CS problem of [14])
# ---------------------------------------------------------------------------

def ds_pgm(costs: Sequence[float], rhos: Sequence[float], miss_penalty: float
           ) -> Selection:
    """Potential-gain order + prefix evaluation (DS_PGM of [14]).

    Sort caches by c_j / -ln(rho_j) (cost per unit of log-miss reduction;
    the optimal insertion order by an exchange argument), then return the
    best prefix of that order under Eq. (10) — including the empty prefix.
    """
    n = len(costs)

    def key(j: int) -> float:
        r = min(max(rhos[j], EPS), 1.0 - EPS)
        return costs[j] / -math.log(r)

    order = sorted(range(n), key=key)
    best_sel: Selection = []
    best_cost = miss_penalty  # empty prefix
    run_cost, run_prod = 0.0, 1.0
    for i, j in enumerate(order):
        run_cost += costs[j]
        run_prod *= rhos[j]
        v = run_cost + miss_penalty * run_prod
        if v < best_cost - EPS:
            best_cost = v
            best_sel = order[: i + 1]
    return sorted(best_sel)


def ds_pgm_mask(costs: Sequence[float], rhos: Sequence[float],
                miss_penalty: float) -> int:
    """:func:`ds_pgm` returning the selection as a bitmask.

    Decision-identical to ``ds_pgm`` (same key values, same stable sort,
    same EPS dead-band on the prefix scan) with the per-call overhead
    stripped — this is the scalar inner call of the calibrated fast
    engine's bridge/table paths, where it runs tens of thousands of times
    per replay.
    """
    n = len(costs)
    keys = [costs[j] / -math.log(min(max(rhos[j], EPS), 1.0 - EPS))
            for j in range(n)]
    order = sorted(range(n), key=keys.__getitem__)
    best_mask = 0
    best_cost = miss_penalty
    run_mask = 0
    run_cost, run_prod = 0.0, 1.0
    for j in order:
        run_cost += costs[j]
        run_prod *= rhos[j]
        run_mask |= 1 << j
        v = run_cost + miss_penalty * run_prod
        if v < best_cost - EPS:
            best_cost = v
            best_mask = run_mask
    return best_mask


def exhaustive(costs: Sequence[float], rhos: Sequence[float], miss_penalty: float
               ) -> Selection:
    """Exact minimiser of Eq. (10) over all 2^n subsets (n <= 20)."""
    n = len(costs)
    if n > 20:
        raise ValueError("exhaustive() limited to n <= 20")
    best_sel: Selection = []
    best_cost = miss_penalty
    for mask in range(1, 1 << n):
        c, p = 0.0, miss_penalty
        for j in range(n):
            if mask >> j & 1:
                c += costs[j]
                p *= rhos[j]
                if c >= best_cost:  # prune
                    break
        else:
            v = c + p
            if v < best_cost - EPS:
                best_cost = v
                best_sel = [j for j in range(n) if mask >> j & 1]
    return best_sel


def exhaustive_mask(costs: Sequence[float], rhos: Sequence[float],
                    miss_penalty: float) -> int:
    """:func:`exhaustive` returning the selection as a bitmask.

    Decision-identical to ``exhaustive`` (same ascending-mask enumeration,
    same pruning, same EPS dead-band) with the per-call overhead stripped —
    the scalar inner call of the calibrated fast engine's bridge/table
    paths when the exhaustive subroutine is configured.
    """
    n = len(costs)
    if n > 20:
        raise ValueError("exhaustive_mask() limited to n <= 20")
    best_mask = 0
    best_cost = miss_penalty
    for mask in range(1, 1 << n):
        c, p = 0.0, miss_penalty
        for j in range(n):
            if mask >> j & 1:
                c += costs[j]
                p *= rhos[j]
                if c >= best_cost:  # prune
                    break
        else:
            v = c + p
            if v < best_cost - EPS:
                best_cost = v
                best_mask = mask
    return best_mask


# ---------------------------------------------------------------------------
# Algorithm 2: CS_FNA / CS_FNO
# ---------------------------------------------------------------------------

def rho_vector(views: Sequence[CacheView], indications: Sequence[int]) -> List[float]:
    """rho_j = pi_j if I_j(x)=1 else nu_j  (lines 5-10 of Algorithm 2)."""
    rhos = []
    for v, ind in zip(views, indications):
        pi, nu = v.exclusions()
        rhos.append(pi if ind else nu)
    return rhos


def cs_fna(views: Sequence[CacheView], indications: Sequence[int],
           miss_penalty: float, alg: RestrictedAlg = ds_pgm) -> Selection:
    """Algorithm 2: all caches are candidates; negative indications carry
    rho = nu (Theorem-7 reduction to the restricted CS problem)."""
    costs = [v.cost for v in views]
    rhos = rho_vector(views, indications)
    return alg(costs, rhos, miss_penalty)


def cs_fno(views: Sequence[CacheView], indications: Sequence[int],
           miss_penalty: float, alg: RestrictedAlg = ds_pgm) -> Selection:
    """FNO baseline: only positive-indication caches may be accessed
    (equivalently nu_j = 1 for all j)."""
    pos = [j for j, ind in enumerate(indications) if ind]
    if not pos:
        return []
    costs = [views[j].cost for j in pos]
    rhos = [views[j].exclusions()[0] for j in pos]
    sel = alg(costs, rhos, miss_penalty)
    return sorted(pos[i] for i in sel)


def perfect_information(costs: Sequence[float], contains: Sequence[bool]) -> Selection:
    """PI strategy: access the cheapest cache that truly holds x, else none."""
    best, best_c = None, None
    for j, has in enumerate(contains):
        if has and (best_c is None or costs[j] < best_c):
            best, best_c = j, costs[j]
    return [] if best is None else [best]


def expected_cost(views: Sequence[CacheView], indications: Sequence[int],
                  selection: Selection, miss_penalty: float) -> float:
    """Model-expected phi(D) for a selection (Eq. 4/10 with estimated rho)."""
    costs = [v.cost for v in views]
    rhos = rho_vector(views, indications)
    return service_cost(costs, rhos, miss_penalty, selection)
