"""Vectorised JAX twin of the paper's math — the production router path.

Everything operates on a BATCH of requests at once so the serving router
can make thousands of FNA cache-selection decisions per step on-device,
fed directly by the Pallas Bloom-probe kernel (kernels/bloom).

Shapes: B = batch of requests, N = caches.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

EPS = 1e-12

# the batched exhaustive table build enumerates 2^n subsets per pattern
# row (4^n work per version): past this the scalar/reference loop wins.
# Single source of truth for the fast engine's exhaustive dispatch.
MAX_EXHAUSTIVE_TABLE_CACHES = 8


def exclusions(h, fp, fn) -> Tuple[jax.Array, jax.Array]:
    """Eqs. (1)-(3), elementwise."""
    q = h * (1.0 - fn) + (1.0 - h) * fp
    pi = jnp.clip(fp * (1.0 - h) / jnp.maximum(q, EPS), 0.0, 1.0)
    nu = jnp.clip((1.0 - fp) * (1.0 - h) / jnp.maximum(1.0 - q, EPS), 0.0, 1.0)
    return pi, nu


def hit_from_q(q, fp, fn):
    denom = 1.0 - fp - fn
    return jnp.clip((q - fp) / jnp.where(jnp.abs(denom) < EPS, 1.0, denom), 0.0, 1.0)


def rho_matrix(indications, q, fp, fn) -> jax.Array:
    """[B,N] rho_j per request: pi_j on positive, nu_j on negative."""
    h = hit_from_q(q, fp, fn)
    pi, nu = exclusions(h, fp, fn)
    return jnp.where(indications > 0, pi[None, :], nu[None, :])


def ds_pgm_batched(costs, rhos, miss_penalty, *, fno_mask=None) -> jax.Array:
    """Batched DS_PGM prefix evaluation.

    costs: [N]; rhos: [B,N]; optional fno_mask [B,N] (1 = cache may be
    accessed; CS_FNO passes the positive-indication mask, CS_FNA all-ones).
    Returns a selection mask [B,N] (bool).
    """
    b, n = rhos.shape
    r = jnp.clip(rhos, EPS, 1.0 - EPS)
    key = costs[None, :] / -jnp.log(r)                      # [B,N]
    if fno_mask is not None:
        key = jnp.where(fno_mask > 0, key, jnp.inf)         # excluded -> last
    order = jnp.argsort(key, axis=1)                        # ascending
    c_sorted = jnp.take_along_axis(jnp.broadcast_to(costs[None], (b, n)), order, 1)
    r_sorted = jnp.take_along_axis(r, order, 1)
    if fno_mask is not None:
        allowed = jnp.take_along_axis(fno_mask > 0, order, 1)
        c_sorted = jnp.where(allowed, c_sorted, jnp.inf)    # never pick excluded
        r_sorted = jnp.where(allowed, r_sorted, 1.0)
    csum = jnp.cumsum(c_sorted, axis=1)
    lprod = jnp.cumsum(jnp.log(r_sorted), axis=1)
    # prefix costs phi(P_i), i = 0..n (0 = empty set)
    phi = jnp.concatenate(
        [jnp.full((b, 1), miss_penalty, csum.dtype),
         csum + miss_penalty * jnp.exp(lprod)], axis=1)     # [B, N+1]
    best = jnp.argmin(phi, axis=1)                          # prefix length
    pick_sorted = jnp.arange(n)[None, :] < best[:, None]    # [B,N] in sorted order
    # scatter back to cache order
    mask = jnp.take_along_axis(
        pick_sorted, jnp.argsort(order, axis=1), axis=1)
    return mask


def selection_tables(costs, pi, nu, miss_penalty, *, fno: bool = False) -> np.ndarray:
    """[V, 2^n, n] DS_PGM decision tables over ALL indication patterns for
    a whole batch of V view versions at once.

    ``pi``/``nu`` are [V, n] (or [n], treated as V=1) exclusion
    probabilities; row (v, p) holds the selection mask of view version v
    for the indication pattern whose bit j is ``(p >> j) & 1``.
    ``fno=True`` restricts candidates to positive-indication caches
    (CS_FNO).  Evaluated in float64 (x64) to match the scalar
    :func:`repro.core.ds_pgm` path — the simulator fast engine batches
    its entire version history into one call here.  Parity with the
    scalar path is exact unless two prefix costs coincide to within the
    scalar EPS dead-band (~1e-12): this path evaluates the Eq. (10)
    product as exp(cumsum(log .)) and takes a plain argmin; see the
    parity caveat in ``repro.cachesim.fastpath``.
    """
    pi = np.atleast_2d(np.asarray(pi, np.float64))
    nu = np.atleast_2d(np.asarray(nu, np.float64))
    v, n = pi.shape
    k = 1 << n
    pat_bits = (np.arange(k)[:, None] >> np.arange(n)[None, :]) & 1   # [K,n]
    rhos = np.where(pat_bits[None, :, :] > 0,
                    pi[:, None, :], nu[:, None, :]).reshape(v * k, n)
    with enable_x64():
        mask = ds_pgm_batched(
            jnp.asarray(np.asarray(costs, np.float64)),
            jnp.asarray(rhos), float(miss_penalty),
            fno_mask=jnp.asarray(np.tile(pat_bits, (v, 1))) if fno else None)
        out = np.asarray(mask)
    return out.reshape(v, k, n)


def rho_selection_tables(costs, rhos, miss_penalty) -> np.ndarray:
    """[B, n] float64 DS_PGM masks for an arbitrary per-request rho matrix.

    The pattern-grid :func:`selection_tables` covers policies whose rho is
    a pure (version, indication-pattern) function; the calibrated policy's
    rho rows are instead keyed on its evolving calibration state (EWMA
    values, probe counts, epsilon exploration), one row per request.  This
    is the verification half of the ``fna_cal`` fast engine's
    speculate-and-commit loop (``repro.cachesim.fna_cal_fast``): it runs
    per speculation segment, so it is evaluated as a NumPy float64 mirror
    of :func:`ds_pgm_batched` — same stable potential-gain argsort, same
    ``exp(cumsum(log .))`` prefix evaluation, no per-segment dispatch
    overhead.  Agreement with the scalar ``ds_pgm`` carries the same
    ~1e-12 near-tie caveat documented on :func:`selection_tables`.
    """
    rhos = np.asarray(rhos, np.float64)
    b, n = rhos.shape
    costs = np.asarray(costs, np.float64)
    M = float(miss_penalty)
    logr = np.log(np.clip(rhos, EPS, 1.0 - EPS))
    order = np.argsort(costs[None, :] / -logr, axis=1, kind="stable")
    flat = order + (np.arange(b) * n)[:, None]      # row-flattened gather
    csum = np.cumsum(costs[order], axis=1)
    lprod = np.cumsum(logr.reshape(-1)[flat], axis=1)
    phi = csum + M * np.exp(lprod)                  # prefix costs, i = 1..n
    best = np.argmin(phi, axis=1)
    # the empty prefix (cost M) wins ties, exactly like argmin over [M, phi]
    take = np.where(phi[np.arange(b), best] < M, best + 1, 0)
    pick_sorted = np.arange(n)[None, :] < take[:, None]
    mask = np.empty((b, n), dtype=bool)
    mask.reshape(-1)[flat] = pick_sorted
    return mask


def _subset_dp(costs, rhos, miss_penalty):
    """[B, 2^n] Eq. (10) value of EVERY subset, bit-exact with the scalar
    :func:`repro.core.exhaustive` enumeration.

    The scalar loop accumulates a subset's cost and its exclusion product
    by ascending cache index, so ``phi[b, m]`` must reproduce exactly that
    IEEE operation order.  A DP that extends each mask by its HIGHEST set
    bit does: ``m`` strips to ``m ^ (1 << hb)``, whose own value was built
    in the same ascending order, and appends the one multiply/add the
    scalar loop performs last.
    """
    rhos = np.asarray(rhos, np.float64)
    b, n = rhos.shape
    k = 1 << n
    costs = np.asarray(costs, np.float64)
    cost_m = np.zeros(k, np.float64)
    prod_m = np.empty((b, k), np.float64)
    prod_m[:, 0] = float(miss_penalty)
    for m in range(1, k):
        hb = m.bit_length() - 1
        rest = m ^ (1 << hb)
        cost_m[m] = cost_m[rest] + costs[hb]
        np.multiply(prod_m[:, rest], rhos[:, hb], out=prod_m[:, m])
    return cost_m[None, :] + prod_m


def rho_exhaustive_tables(costs, rhos, miss_penalty, *, allowed=None
                          ) -> np.ndarray:
    """[B, n] bool masks: the exact Eq. (10) minimiser over all 2^n
    subsets for an arbitrary per-request rho matrix (n <= 16).

    The batched twin of the scalar :func:`repro.core.exhaustive` — the
    exhaustive counterpart of :func:`rho_selection_tables`, and the
    verification half of the calibrated fast engine when the exhaustive
    subroutine is configured.  ``allowed`` (int64 [B], optional) restricts
    row b to subsets of ``allowed[b]`` (the CS_FNO candidate set; the empty
    set is always allowed).  Subset values reproduce the scalar loop's IEEE
    operation order exactly (see ``_subset_dp``); the argmin takes the
    LOWEST qualifying mask, matching the scalar ascending enumeration, with
    the same ~1e-12 near-tie caveat documented on
    :func:`rho_selection_tables`.
    """
    rhos = np.asarray(rhos, np.float64)
    b, n = rhos.shape
    if n > 16:
        raise ValueError("rho_exhaustive_tables() limited to n <= 16")
    k = 1 << n
    phi = _subset_dp(costs, rhos, miss_penalty)
    if allowed is not None:
        bad = (np.arange(k)[None, :] & ~np.asarray(allowed, np.int64)[:, None]) != 0
        phi[bad] = np.inf
    # np.argmin returns the FIRST minimal subset in ascending-mask order;
    # the scalar loop keeps the earlier mask unless a later one improves by
    # more than EPS — identical away from ~1e-12 near-ties
    best = np.argmin(phi, axis=1)
    return ((best[:, None] >> np.arange(n)[None, :]) & 1).astype(bool)


def exhaustive_tables(costs, pi, nu, miss_penalty, *, fno: bool = False,
                      chunk: int = 1 << 13) -> np.ndarray:
    """[V, 2^n] int64 selection bitmasks over ALL indication patterns for a
    batch of V view versions, with the EXHAUSTIVE subroutine (n <= 8).

    The exhaustive counterpart of :func:`selection_tables`: row (v, p)
    holds the Eq. (10)-optimal subset under view version v for indication
    pattern p; ``fno=True`` restricts candidates to positive-indication
    caches.  Evaluated chunk-wise so the [rows, 2^n] subset matrix stays
    bounded; the simulator fast engine feeds its whole version history
    here when ``alg="exhaustive"``.
    """
    pi = np.atleast_2d(np.asarray(pi, np.float64))
    nu = np.atleast_2d(np.asarray(nu, np.float64))
    v, n = pi.shape
    if n > MAX_EXHAUSTIVE_TABLE_CACHES:
        raise ValueError(
            f"exhaustive_tables() limited to n <= {MAX_EXHAUSTIVE_TABLE_CACHES}")
    k = 1 << n
    pat_bits = (np.arange(k)[:, None] >> np.arange(n)[None, :]) & 1   # [K,n]
    rhos = np.where(pat_bits[None, :, :] > 0,
                    pi[:, None, :], nu[:, None, :]).reshape(v * k, n)
    allowed = np.tile(np.arange(k, dtype=np.int64), v) if fno else None
    pow2 = (1 << np.arange(n)).astype(np.int64)
    out = np.empty(v * k, np.int64)
    for lo in range(0, v * k, chunk):
        hi = min(lo + chunk, v * k)
        mask = rho_exhaustive_tables(
            costs, rhos[lo:hi], miss_penalty,
            allowed=None if allowed is None else allowed[lo:hi])
        out[lo:hi] = mask @ pow2
    return out.reshape(v, k)


def cs_fna_batched(indications, costs, q, fp, fn, miss_penalty) -> jax.Array:
    """Algorithm 2, batched: all caches candidates, rho by indication."""
    rhos = rho_matrix(indications, q, fp, fn)
    return ds_pgm_batched(costs, rhos, miss_penalty)


def cs_fno_batched(indications, costs, q, fp, fn, miss_penalty) -> jax.Array:
    """FNO baseline, batched: positive-indication caches only."""
    rhos = rho_matrix(indications, q, fp, fn)
    return ds_pgm_batched(costs, rhos, miss_penalty, fno_mask=indications)


def hocs_fna_batched(n_x, n, pi, nu, miss_penalty) -> Tuple[jax.Array, jax.Array]:
    """Algorithm 1, batched over requests (homogeneous parameters).

    n_x: [B] positive-indication counts.  Returns (r0, r1) int32 [B].
    """
    def argmin_geo(m_eff, rho, r_max):
        rho_c = jnp.clip(rho, EPS, 1.0 - EPS)
        l = jnp.log(1.0 / rho_c)
        r_cont = jnp.log(jnp.maximum(m_eff * l, EPS)) / l
        cands = jnp.stack([
            jnp.zeros_like(r_cont), jnp.ones_like(r_cont),
            jnp.floor(r_cont), jnp.ceil(r_cont),
            r_max.astype(r_cont.dtype)], axis=-1)
        cands = jnp.clip(cands, 0, r_max[..., None].astype(r_cont.dtype))
        vals = cands + m_eff[..., None] * rho_c[..., None] ** cands
        take = jnp.argmin(vals, axis=-1)
        return jnp.take_along_axis(cands, take[..., None], -1)[..., 0].astype(jnp.int32)

    b = n_x.shape[0]
    m_arr = jnp.full((b,), miss_penalty, jnp.float32)
    r1 = argmin_geo(m_arr, jnp.full((b,), pi, jnp.float32), n_x)
    residual = miss_penalty * jnp.float32(pi) ** r1
    r0 = jnp.where(
        residual > 1.0,
        argmin_geo(residual, jnp.full((b,), nu, jnp.float32), n - n_x),
        0)
    return r0.astype(jnp.int32), r1