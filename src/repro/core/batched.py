"""Vectorised JAX twin of the paper's math — the production router path.

Everything operates on a BATCH of requests at once so the serving router
can make thousands of FNA cache-selection decisions per step on-device,
fed directly by the Pallas Bloom-probe kernel (kernels/bloom).

Shapes: B = batch of requests, N = caches.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

EPS = 1e-12

# the batched exhaustive table build enumerates 2^n subsets per pattern
# row (4^n work per version): past this the scalar/reference loop wins.
# Single source of truth for the fast engine's exhaustive dispatch.
MAX_EXHAUSTIVE_TABLE_CACHES = 8


def exclusions(h, fp, fn) -> Tuple[jax.Array, jax.Array]:
    """Eqs. (1)-(3), elementwise."""
    q = h * (1.0 - fn) + (1.0 - h) * fp
    pi = jnp.clip(fp * (1.0 - h) / jnp.maximum(q, EPS), 0.0, 1.0)
    nu = jnp.clip((1.0 - fp) * (1.0 - h) / jnp.maximum(1.0 - q, EPS), 0.0, 1.0)
    return pi, nu


def hit_from_q(q, fp, fn):
    denom = 1.0 - fp - fn
    return jnp.clip((q - fp) / jnp.where(jnp.abs(denom) < EPS, 1.0, denom), 0.0, 1.0)


def rho_matrix(indications, q, fp, fn) -> jax.Array:
    """[B,N] rho_j per request: pi_j on positive, nu_j on negative."""
    h = hit_from_q(q, fp, fn)
    pi, nu = exclusions(h, fp, fn)
    return jnp.where(indications > 0, pi[None, :], nu[None, :])


def ds_pgm_batched(costs, rhos, miss_penalty, *, fno_mask=None) -> jax.Array:
    """Batched DS_PGM prefix evaluation.

    costs: [N] shared, or [B,N] per row (a stacked batch of decision
    cells); rhos: [B,N]; miss_penalty: scalar, or [B] per row; optional
    fno_mask [B,N] (1 = cache may be accessed; CS_FNO passes the
    positive-indication mask, CS_FNA all-ones).  Every operation is
    row-local, so a row's mask is independent of what else shares the
    batch — the decision-plan engine relies on this to stack whole sweep
    cells into one call.  Returns a selection mask [B,N] (bool).
    """
    b, n = rhos.shape
    r = jnp.clip(rhos, EPS, 1.0 - EPS)
    costs = jnp.asarray(costs)
    costs_b = jnp.broadcast_to(costs, (b, n)) if costs.ndim == 1 else costs
    m = jnp.asarray(miss_penalty)
    m_b = jnp.broadcast_to(m, (b,)) if m.ndim == 0 else m
    key = costs_b / -jnp.log(r)                             # [B,N]
    if fno_mask is not None:
        key = jnp.where(fno_mask > 0, key, jnp.inf)         # excluded -> last
    order = jnp.argsort(key, axis=1)                        # ascending
    c_sorted = jnp.take_along_axis(costs_b, order, 1)
    r_sorted = jnp.take_along_axis(r, order, 1)
    if fno_mask is not None:
        allowed = jnp.take_along_axis(fno_mask > 0, order, 1)
        c_sorted = jnp.where(allowed, c_sorted, jnp.inf)    # never pick excluded
        r_sorted = jnp.where(allowed, r_sorted, 1.0)
    csum = jnp.cumsum(c_sorted, axis=1)
    lprod = jnp.cumsum(jnp.log(r_sorted), axis=1)
    # prefix costs phi(P_i), i = 0..n (0 = empty set)
    phi = jnp.concatenate(
        [m_b[:, None].astype(csum.dtype),
         csum + m_b[:, None] * jnp.exp(lprod)], axis=1)     # [B, N+1]
    best = jnp.argmin(phi, axis=1)                          # prefix length
    pick_sorted = jnp.arange(n)[None, :] < best[:, None]    # [B,N] in sorted order
    # scatter back to cache order
    mask = jnp.take_along_axis(
        pick_sorted, jnp.argsort(order, axis=1), axis=1)
    return mask


def selection_tables(costs, pi, nu, miss_penalty, *, fno: bool = False,
                     backend: str = "jax") -> np.ndarray:
    """[V, 2^n, n] DS_PGM decision tables over ALL indication patterns for
    a whole batch of V view versions at once.

    ``pi``/``nu`` are [V, n] (or [n], treated as V=1) exclusion
    probabilities; row (v, p) holds the selection mask of view version v
    for the indication pattern whose bit j is ``(p >> j) & 1``.
    ``fno=True`` restricts candidates to positive-indication caches
    (CS_FNO).  Evaluated in float64 (x64) to match the scalar
    :func:`repro.core.ds_pgm` path — the simulator fast engine batches
    its entire version history into one call here.  Parity with the
    scalar path is exact unless two prefix costs coincide to within the
    scalar EPS dead-band (~1e-12): this path evaluates the Eq. (10)
    product as exp(cumsum(log .)) and takes a plain argmin; see the
    parity caveat in ``repro.cachesim.fastpath``.

    ``backend="numpy"`` routes through :func:`rho_selection_tables` — the
    float64 NumPy mirror of :func:`ds_pgm_batched` — which skips the JAX
    dispatch overhead entirely; the calibrated fast engine uses it for
    its many small per-segment table builds.  (No CS_FNO support there:
    the segmented replay never needs it.)
    """
    pi = np.atleast_2d(np.asarray(pi, np.float64))
    nu = np.atleast_2d(np.asarray(nu, np.float64))
    v, n = pi.shape
    k = 1 << n
    pat_bits = (np.arange(k)[:, None] >> np.arange(n)[None, :]) & 1   # [K,n]
    rhos = np.where(pat_bits[None, :, :] > 0,
                    pi[:, None, :], nu[:, None, :]).reshape(v * k, n)
    if backend == "numpy":
        if fno:
            raise ValueError("backend='numpy' does not support fno=True")
        return rho_selection_tables(costs, rhos, miss_penalty).reshape(v, k, n)
    with enable_x64():
        mask = ds_pgm_batched(
            jnp.asarray(np.asarray(costs, np.float64)),
            jnp.asarray(rhos), float(miss_penalty),
            fno_mask=jnp.asarray(np.tile(pat_bits, (v, 1))) if fno else None)
        out = np.asarray(mask)
    return out.reshape(v, k, n)


def selection_tables_cells(costs_cells, pi, nu, penalties, fno_cells,
                           *, max_rows: int = 1 << 20) -> np.ndarray:
    """[C, V, 2^n, n] DS_PGM decision tables for SEVERAL decision cells
    against ONE shared view history, in as few batched calls as memory
    allows.

    A decision-side sweep axis (miss penalty, access-cost vector, policy)
    leaves the system evolution — and with it the whole [V, n] (pi, nu)
    view history — untouched, so the only thing that varies across its
    cells is the (costs, miss_penalty, CS_FNO) triple each row is
    evaluated under.  This stacks all C cells' (version x pattern) grids
    into one ``ds_pgm_batched`` evaluation with per-row costs/penalties
    (chunked to ``max_rows`` rows so the [rows, n] matrices stay
    bounded).  Rows are evaluated independently, so cell c's slice is
    bit-identical to a per-cell :func:`selection_tables` call.

    ``costs_cells``: [C, n]; ``penalties``: [C]; ``fno_cells``: [C] bool.
    """
    pi = np.atleast_2d(np.asarray(pi, np.float64))
    nu = np.atleast_2d(np.asarray(nu, np.float64))
    v, n = pi.shape
    k = 1 << n
    costs_cells = np.asarray(costs_cells, np.float64)
    penalties = np.asarray(penalties, np.float64)
    fno_cells = np.asarray(fno_cells, bool)
    c = costs_cells.shape[0]
    pat_bits = (np.arange(k)[:, None] >> np.arange(n)[None, :]) & 1   # [K,n]
    rhos = np.where(pat_bits[None, :, :] > 0,
                    pi[:, None, :], nu[:, None, :]).reshape(v * k, n)
    pat_tiled = np.tile(pat_bits, (v, 1))                             # [V*K,n]
    ones = np.ones_like(pat_tiled)
    out = np.empty((c, v * k, n), dtype=bool)
    per_call = max(1, max_rows // (v * k))        # whole cells per chunk
    with enable_x64():
        for lo in range(0, c, per_call):
            hi = min(lo + per_call, c)
            cc = hi - lo
            rows = np.tile(rhos, (cc, 1))
            costs_rows = np.repeat(costs_cells[lo:hi], v * k, axis=0)
            m_rows = np.repeat(penalties[lo:hi], v * k)
            fno_rows = np.concatenate(
                [pat_tiled if f else ones for f in fno_cells[lo:hi]])
            mask = ds_pgm_batched(
                jnp.asarray(costs_rows), jnp.asarray(rows),
                jnp.asarray(m_rows), fno_mask=jnp.asarray(fno_rows))
            out[lo:hi] = np.asarray(mask).reshape(cc, v * k, n)
    return out.reshape(c, v, k, n)


def rho_selection_tables(costs, rhos, miss_penalty) -> np.ndarray:
    """[B, n] float64 DS_PGM masks for an arbitrary per-request rho matrix.

    The pattern-grid :func:`selection_tables` covers policies whose rho is
    a pure (version, indication-pattern) function; the calibrated policy's
    rho rows are instead keyed on its evolving calibration state (EWMA
    values, probe counts, epsilon exploration), one row per request.  This
    is the verification half of the ``fna_cal`` fast engine's
    speculate-and-commit loop (``repro.cachesim.fna_cal_fast``): it runs
    per speculation segment, so it is evaluated as a NumPy float64 mirror
    of :func:`ds_pgm_batched` — same stable potential-gain argsort, same
    ``exp(cumsum(log .))`` prefix evaluation, no per-segment dispatch
    overhead.  Agreement with the scalar ``ds_pgm`` carries the same
    ~1e-12 near-tie caveat documented on :func:`selection_tables`.
    """
    rhos = np.asarray(rhos, np.float64)
    b, n = rhos.shape
    costs = np.asarray(costs, np.float64)
    M = float(miss_penalty)
    logr = np.log(np.clip(rhos, EPS, 1.0 - EPS))
    order = np.argsort(costs[None, :] / -logr, axis=1, kind="stable")
    flat = order + (np.arange(b) * n)[:, None]      # row-flattened gather
    csum = np.cumsum(costs[order], axis=1)
    lprod = np.cumsum(logr.reshape(-1)[flat], axis=1)
    phi = csum + M * np.exp(lprod)                  # prefix costs, i = 1..n
    best = np.argmin(phi, axis=1)
    # the empty prefix (cost M) wins ties, exactly like argmin over [M, phi]
    take = np.where(phi[np.arange(b), best] < M, best + 1, 0)
    pick_sorted = np.arange(n)[None, :] < take[:, None]
    mask = np.empty((b, n), dtype=bool)
    mask.reshape(-1)[flat] = pick_sorted
    return mask


def _subset_dp(costs, rhos, miss_penalty):
    """[B, 2^n] Eq. (10) value of EVERY subset, bit-exact with the scalar
    :func:`repro.core.exhaustive` enumeration.

    The scalar loop accumulates a subset's cost and its exclusion product
    by ascending cache index, so ``phi[b, m]`` must reproduce exactly that
    IEEE operation order.  A DP that extends each mask by its HIGHEST set
    bit does: ``m`` strips to ``m ^ (1 << hb)``, whose own value was built
    in the same ascending order, and appends the one multiply/add the
    scalar loop performs last.
    """
    rhos = np.asarray(rhos, np.float64)
    b, n = rhos.shape
    k = 1 << n
    costs = np.asarray(costs, np.float64)
    cost_m = np.zeros(k, np.float64)
    prod_m = np.empty((b, k), np.float64)
    prod_m[:, 0] = float(miss_penalty)
    for m in range(1, k):
        hb = m.bit_length() - 1
        rest = m ^ (1 << hb)
        cost_m[m] = cost_m[rest] + costs[hb]
        np.multiply(prod_m[:, rest], rhos[:, hb], out=prod_m[:, m])
    return cost_m[None, :] + prod_m


def rho_exhaustive_tables(costs, rhos, miss_penalty, *, allowed=None
                          ) -> np.ndarray:
    """[B, n] bool masks: the exact Eq. (10) minimiser over all 2^n
    subsets for an arbitrary per-request rho matrix (n <= 16).

    The batched twin of the scalar :func:`repro.core.exhaustive` — the
    exhaustive counterpart of :func:`rho_selection_tables`, and the
    verification half of the calibrated fast engine when the exhaustive
    subroutine is configured.  ``allowed`` (int64 [B], optional) restricts
    row b to subsets of ``allowed[b]`` (the CS_FNO candidate set; the empty
    set is always allowed).  Subset values reproduce the scalar loop's IEEE
    operation order exactly (see ``_subset_dp``); the argmin takes the
    LOWEST qualifying mask, matching the scalar ascending enumeration, with
    the same ~1e-12 near-tie caveat documented on
    :func:`rho_selection_tables`.
    """
    rhos = np.asarray(rhos, np.float64)
    b, n = rhos.shape
    if n > 16:
        raise ValueError("rho_exhaustive_tables() limited to n <= 16")
    k = 1 << n
    phi = _subset_dp(costs, rhos, miss_penalty)
    if allowed is not None:
        bad = (np.arange(k)[None, :] & ~np.asarray(allowed, np.int64)[:, None]) != 0
        phi[bad] = np.inf
    # np.argmin returns the FIRST minimal subset in ascending-mask order;
    # the scalar loop keeps the earlier mask unless a later one improves by
    # more than EPS — identical away from ~1e-12 near-ties
    best = np.argmin(phi, axis=1)
    return ((best[:, None] >> np.arange(n)[None, :]) & 1).astype(bool)


def exhaustive_tables(costs, pi, nu, miss_penalty, *, fno: bool = False,
                      chunk: int = 1 << 13) -> np.ndarray:
    """[V, 2^n] int64 selection bitmasks over ALL indication patterns for a
    batch of V view versions, with the EXHAUSTIVE subroutine (n <= 8).

    The exhaustive counterpart of :func:`selection_tables`: row (v, p)
    holds the Eq. (10)-optimal subset under view version v for indication
    pattern p; ``fno=True`` restricts candidates to positive-indication
    caches.  Evaluated chunk-wise so the [rows, 2^n] subset matrix stays
    bounded; the simulator fast engine feeds its whole version history
    here when ``alg="exhaustive"``.
    """
    pi = np.atleast_2d(np.asarray(pi, np.float64))
    nu = np.atleast_2d(np.asarray(nu, np.float64))
    v, n = pi.shape
    if n > MAX_EXHAUSTIVE_TABLE_CACHES:
        raise ValueError(
            f"exhaustive_tables() limited to n <= {MAX_EXHAUSTIVE_TABLE_CACHES}")
    k = 1 << n
    pat_bits = (np.arange(k)[:, None] >> np.arange(n)[None, :]) & 1   # [K,n]
    rhos = np.where(pat_bits[None, :, :] > 0,
                    pi[:, None, :], nu[:, None, :]).reshape(v * k, n)
    allowed = np.tile(np.arange(k, dtype=np.int64), v) if fno else None
    pow2 = (1 << np.arange(n)).astype(np.int64)
    out = np.empty(v * k, np.int64)
    for lo in range(0, v * k, chunk):
        hi = min(lo + chunk, v * k)
        mask = rho_exhaustive_tables(
            costs, rhos[lo:hi], miss_penalty,
            allowed=None if allowed is None else allowed[lo:hi])
        out[lo:hi] = mask @ pow2
    return out.reshape(v, k)


def cs_fna_batched(indications, costs, q, fp, fn, miss_penalty) -> jax.Array:
    """Algorithm 2, batched: all caches candidates, rho by indication."""
    rhos = rho_matrix(indications, q, fp, fn)
    return ds_pgm_batched(costs, rhos, miss_penalty)


def cs_fno_batched(indications, costs, q, fp, fn, miss_penalty) -> jax.Array:
    """FNO baseline, batched: positive-indication caches only."""
    rhos = rho_matrix(indications, q, fp, fn)
    return ds_pgm_batched(costs, rhos, miss_penalty, fno_mask=indications)


def _argmin_geometric_batched(m_eff, rho, r_max) -> np.ndarray:
    """Vectorised float64 mirror of the scalar
    :func:`repro.core.policies._argmin_geometric`: same edge-case
    branches, same {0, 1, floor(r*), ceil(r*), r_max} candidate
    shortlist scanned in ascending order with the same EPS
    strict-improvement dead-band.  All inputs broadcast to [B]."""
    m_eff, rho, r_max = np.broadcast_arrays(
        np.asarray(m_eff, np.float64), np.asarray(rho, np.float64),
        np.asarray(r_max, np.int64))
    out = np.zeros(m_eff.shape, np.int64)
    pos = r_max > 0
    tiny = pos & (rho <= EPS)
    out[tiny & (m_eff > 1.0)] = 1
    mid = pos & (rho > EPS) & (rho < 1.0 - EPS)
    if not mid.any():
        return out
    m = m_eff[mid]
    r = rho[mid]
    rmax = r_max[mid]
    # continuous optimum: r* = ln(m_eff * ln(1/rho)) / ln(1/rho)
    l = np.log(1.0 / r)
    r_cont = np.log(np.maximum(m * l, EPS)) / l
    cand = np.stack([np.zeros_like(r_cont), np.ones_like(r_cont),
                     np.floor(r_cont), np.ceil(r_cont),
                     rmax.astype(np.float64)], axis=1)
    cand = np.sort(cand, axis=1)          # the scalar's ascending scan
    ok = (cand >= 0.0) & (cand <= rmax[:, None].astype(np.float64))
    val = cand + m[:, None] * r[:, None] ** cand
    best_r = np.zeros(m.shape, np.float64)
    best_v = m.copy()                     # r = 0 baseline
    for s in range(cand.shape[1]):        # duplicates can't strictly improve
        imp = ok[:, s] & (val[:, s] < best_v - EPS)
        best_r = np.where(imp, cand[:, s], best_r)
        best_v = np.where(imp, val[:, s], best_v)
    out[mid] = best_r.astype(np.int64)
    return out


def hocs_fna_batched(n_x, n, pi, nu, miss_penalty
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Algorithm 1, batched over requests (homogeneous parameters).

    The float64 NumPy mirror of the scalar :func:`repro.core.hocs_fna` —
    same candidate shortlist and EPS dead-band via
    :func:`_argmin_geometric_batched` — so the simulator fast engine can
    evaluate a whole (view version x positive-count) grid in one call
    and stay bit-exact with the reference loop (the same near-tie caveat
    as :func:`selection_tables`: a candidate shortlist can only differ
    when the continuous optimum sits within ~1 ulp of an integer).

    ``n_x``: [B] positive-indication counts; ``pi``/``nu``/
    ``miss_penalty``: scalars or [B].  Returns (r0, r1) int64 [B].
    """
    n_x = np.asarray(n_x, np.int64)
    pi, nu, m, n_x = np.broadcast_arrays(
        np.asarray(pi, np.float64), np.asarray(nu, np.float64),
        np.asarray(miss_penalty, np.float64), n_x)
    r1 = _argmin_geometric_batched(m, pi, n_x)
    residual = m * pi ** r1
    r0 = np.where(residual > 1.0,
                  _argmin_geometric_batched(residual, nu, n - n_x), 0)
    return r0.astype(np.int64), r1


def hocs_selection_tables(pi_v, nu_v, miss_penalty) -> np.ndarray:
    """[V, 2^n] int64 HOCS selection bitmasks over ALL indication
    patterns for a batch of V view versions.

    Mirrors the reference loop exactly: per-version pooled estimates are
    LEFT-TO-RIGHT sums over caches (np.sum pairwise-accumulates for
    n >= 8, which can differ in the last ulp), the (r0*, r1*) grid is one
    :func:`hocs_fna_batched` call over every (version, popcount) pair,
    and row (v, p) accesses the r1* cheapest positive-indication caches
    plus the r0* cheapest negative ones (ascending cache index — the
    homogeneous setting has no cost order).
    """
    pi_v = np.atleast_2d(np.asarray(pi_v, np.float64))
    nu_v = np.atleast_2d(np.asarray(nu_v, np.float64))
    v, n = pi_v.shape
    k = 1 << n
    acc_pi = np.zeros(v, np.float64)
    acc_nu = np.zeros(v, np.float64)
    for j in range(n):                    # left-to-right, like sum(list)
        acc_pi = acc_pi + pi_v[:, j]
        acc_nu = acc_nu + nu_v[:, j]
    pi_h = acc_pi / n
    nu_h = acc_nu / n
    # (r0*, r1*) depends on the pattern only through its popcount
    nx = np.arange(n + 1, dtype=np.int64)
    r0g, r1g = hocs_fna_batched(
        np.tile(nx, v), n, np.repeat(pi_h, n + 1), np.repeat(nu_h, n + 1),
        float(miss_penalty))
    r0g = r0g.reshape(v, n + 1)
    r1g = r1g.reshape(v, n + 1)
    bits = ((np.arange(k)[:, None] >> np.arange(n)[None, :]) & 1
            ).astype(np.int64)                                    # [K, n]
    pow2 = (1 << np.arange(n)).astype(np.int64)
    rank_pos = np.cumsum(bits, axis=1)      # 1-based rank among set bits
    rank_neg = np.cumsum(1 - bits, axis=1)
    # low_set[p, r] = mask of the r lowest-index positive caches of p
    low_set = np.stack([(bits * (rank_pos <= r)) @ pow2
                        for r in range(n + 1)], axis=1)           # [K, n+1]
    low_clr = np.stack([((1 - bits) * (rank_neg <= r)) @ pow2
                        for r in range(n + 1)], axis=1)
    popc = bits.sum(axis=1)                                       # [K]
    rows = np.arange(k)[None, :]
    sel = low_set[rows, r1g[:, popc]] | low_clr[rows, r0g[:, popc]]
    return sel.astype(np.int64)