"""Vectorised JAX twin of the paper's math — the production router path.

Everything operates on a BATCH of requests at once so the serving router
can make thousands of FNA cache-selection decisions per step on-device,
fed directly by the Pallas Bloom-probe kernel (kernels/bloom).

Shapes: B = batch of requests, N = caches.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

EPS = 1e-12

# The exhaustive dispatch tiers (single source of truth for the fast
# engine):
#   * n <= MAX_EXHAUSTIVE_TABLE_CACHES: the batched table build
#     (``exhaustive_tables``) — chunked so the [rows, 2^n] subset matrix
#     never exceeds ~EXHAUSTIVE_CHUNK_ELEMS float64 elements, which makes
#     the full engine budget (``engine.MAX_TABLE_CACHES`` = 12) memory-
#     safe; beyond 12 the [V * 2^n] table itself outgrows the replay.
#   * n <= 16: the per-row enumeration (``rho_exhaustive_tables``) for
#     callers that chunk their own rows (the calibrated engine verifies
#     <= 256-row segments at a time).
#   * n > 16: nowhere — 2^n subset values per row stop being representable
#     work; the simulator falls back to the reference loop.
MAX_EXHAUSTIVE_TABLE_CACHES = 12
#: float64 elements per exhaustive DP chunk (rows * 2^n); ~32 MB
EXHAUSTIVE_CHUNK_ELEMS = 1 << 22


def exclusions(h, fp, fn) -> Tuple[jax.Array, jax.Array]:
    """Eqs. (1)-(3), elementwise."""
    q = h * (1.0 - fn) + (1.0 - h) * fp
    pi = jnp.clip(fp * (1.0 - h) / jnp.maximum(q, EPS), 0.0, 1.0)
    nu = jnp.clip((1.0 - fp) * (1.0 - h) / jnp.maximum(1.0 - q, EPS), 0.0, 1.0)
    return pi, nu


def hit_from_q(q, fp, fn):
    denom = 1.0 - fp - fn
    return jnp.clip((q - fp) / jnp.where(jnp.abs(denom) < EPS, 1.0, denom), 0.0, 1.0)


def rho_matrix(indications, q, fp, fn) -> jax.Array:
    """[B,N] rho_j per request: pi_j on positive, nu_j on negative."""
    h = hit_from_q(q, fp, fn)
    pi, nu = exclusions(h, fp, fn)
    return jnp.where(indications > 0, pi[None, :], nu[None, :])


def ds_pgm_batched(costs, rhos, miss_penalty, *, fno_mask=None) -> jax.Array:
    """Batched DS_PGM prefix evaluation.

    costs: [N] shared, or [B,N] per row (a stacked batch of decision
    cells); rhos: [B,N]; miss_penalty: scalar, or [B] per row; optional
    fno_mask [B,N] (1 = cache may be accessed; CS_FNO passes the
    positive-indication mask, CS_FNA all-ones).  Every operation is
    row-local, so a row's mask is independent of what else shares the
    batch — the decision-plan engine relies on this to stack whole sweep
    cells into one call.  Returns a selection mask [B,N] (bool).
    """
    b, n = rhos.shape
    r = jnp.clip(rhos, EPS, 1.0 - EPS)
    costs = jnp.asarray(costs)
    costs_b = jnp.broadcast_to(costs, (b, n)) if costs.ndim == 1 else costs
    m = jnp.asarray(miss_penalty)
    m_b = jnp.broadcast_to(m, (b,)) if m.ndim == 0 else m
    key = costs_b / -jnp.log(r)                             # [B,N]
    if fno_mask is not None:
        key = jnp.where(fno_mask > 0, key, jnp.inf)         # excluded -> last
    order = jnp.argsort(key, axis=1)                        # ascending
    c_sorted = jnp.take_along_axis(costs_b, order, 1)
    r_sorted = jnp.take_along_axis(r, order, 1)
    if fno_mask is not None:
        allowed = jnp.take_along_axis(fno_mask > 0, order, 1)
        c_sorted = jnp.where(allowed, c_sorted, jnp.inf)    # never pick excluded
        r_sorted = jnp.where(allowed, r_sorted, 1.0)
    csum = jnp.cumsum(c_sorted, axis=1)
    lprod = jnp.cumsum(jnp.log(r_sorted), axis=1)
    # prefix costs phi(P_i), i = 0..n (0 = empty set)
    phi = jnp.concatenate(
        [m_b[:, None].astype(csum.dtype),
         csum + m_b[:, None] * jnp.exp(lprod)], axis=1)     # [B, N+1]
    best = jnp.argmin(phi, axis=1)                          # prefix length
    pick_sorted = jnp.arange(n)[None, :] < best[:, None]    # [B,N] in sorted order
    # scatter back to cache order
    mask = jnp.take_along_axis(
        pick_sorted, jnp.argsort(order, axis=1), axis=1)
    return mask


def selection_tables(costs, pi, nu, miss_penalty, *, fno: bool = False,
                     backend: str = "jax") -> np.ndarray:
    """[V, 2^n, n] DS_PGM decision tables over ALL indication patterns for
    a whole batch of V view versions at once.

    ``pi``/``nu`` are [V, n] (or [n], treated as V=1) exclusion
    probabilities; row (v, p) holds the selection mask of view version v
    for the indication pattern whose bit j is ``(p >> j) & 1``.
    ``fno=True`` restricts candidates to positive-indication caches
    (CS_FNO).  Evaluated in float64 (x64) to match the scalar
    :func:`repro.core.ds_pgm` path — the simulator fast engine batches
    its entire version history into one call here.  Parity with the
    scalar path is exact unless two prefix costs coincide to within the
    scalar EPS dead-band (~1e-12): this path evaluates the Eq. (10)
    product as exp(cumsum(log .)) and takes a plain argmin; see the
    parity caveat in ``repro.cachesim.fastpath``.

    ``backend="numpy"`` routes through :func:`rho_selection_tables` — the
    float64 NumPy mirror of :func:`ds_pgm_batched` — which skips the JAX
    dispatch overhead entirely; the calibrated fast engine uses it for
    its many small per-segment table builds.  CS_FNO is expressed there
    as the per-row ``allowed`` candidate mask.
    """
    pi = np.atleast_2d(np.asarray(pi, np.float64))
    nu = np.atleast_2d(np.asarray(nu, np.float64))
    v, n = pi.shape
    k = 1 << n
    pat_bits = (np.arange(k)[:, None] >> np.arange(n)[None, :]) & 1   # [K,n]
    rhos = np.where(pat_bits[None, :, :] > 0,
                    pi[:, None, :], nu[:, None, :]).reshape(v * k, n)
    if backend == "numpy":
        allowed = np.tile(pat_bits.astype(bool), (v, 1)) if fno else None
        return rho_selection_tables(
            costs, rhos, miss_penalty, allowed=allowed).reshape(v, k, n)
    with enable_x64():
        mask = ds_pgm_batched(
            jnp.asarray(np.asarray(costs, np.float64)),
            jnp.asarray(rhos), float(miss_penalty),
            fno_mask=jnp.asarray(np.tile(pat_bits, (v, 1))) if fno else None)
        out = np.asarray(mask)
    return out.reshape(v, k, n)


def selection_tables_cells(costs_cells, pi, nu, penalties, fno_cells,
                           *, max_rows: int = 1 << 20) -> np.ndarray:
    """[C, V, 2^n, n] DS_PGM decision tables for SEVERAL decision cells
    against ONE shared view history, in as few batched calls as memory
    allows.

    A decision-side sweep axis (miss penalty, access-cost vector, policy)
    leaves the system evolution — and with it the whole [V, n] (pi, nu)
    view history — untouched, so the only thing that varies across its
    cells is the (costs, miss_penalty, CS_FNO) triple each row is
    evaluated under.  This stacks all C cells' (version x pattern) grids
    into one ``ds_pgm_batched`` evaluation with per-row costs/penalties
    (chunked to ``max_rows`` rows so the [rows, n] matrices stay
    bounded).  Rows are evaluated independently, so cell c's slice is
    bit-identical to a per-cell :func:`selection_tables` call.

    ``costs_cells``: [C, n]; ``penalties``: [C]; ``fno_cells``: [C] bool.
    """
    pi = np.atleast_2d(np.asarray(pi, np.float64))
    nu = np.atleast_2d(np.asarray(nu, np.float64))
    v, n = pi.shape
    k = 1 << n
    costs_cells = np.asarray(costs_cells, np.float64)
    penalties = np.asarray(penalties, np.float64)
    fno_cells = np.asarray(fno_cells, bool)
    c = costs_cells.shape[0]
    pat_bits = (np.arange(k)[:, None] >> np.arange(n)[None, :]) & 1   # [K,n]
    rhos = np.where(pat_bits[None, :, :] > 0,
                    pi[:, None, :], nu[:, None, :]).reshape(v * k, n)
    pat_tiled = np.tile(pat_bits, (v, 1))                             # [V*K,n]
    ones = np.ones_like(pat_tiled)
    out = np.empty((c, v * k, n), dtype=bool)
    per_call = max(1, max_rows // (v * k))        # whole cells per chunk
    with enable_x64():
        for lo in range(0, c, per_call):
            hi = min(lo + per_call, c)
            cc = hi - lo
            rows = np.tile(rhos, (cc, 1))
            costs_rows = np.repeat(costs_cells[lo:hi], v * k, axis=0)
            m_rows = np.repeat(penalties[lo:hi], v * k)
            fno_rows = np.concatenate(
                [pat_tiled if f else ones for f in fno_cells[lo:hi]])
            mask = ds_pgm_batched(
                jnp.asarray(costs_rows), jnp.asarray(rows),
                jnp.asarray(m_rows), fno_mask=jnp.asarray(fno_rows))
            out[lo:hi] = np.asarray(mask).reshape(cc, v * k, n)
    return out.reshape(c, v, k, n)


@jax.jit
def _cells_tables_kernel(costs_u, fno_u, group_idx, penalties, pi, nu):
    """[C, V*2^n, n] bool masks: the grouped two-stage evaluation of
    :func:`ds_pgm_batched` over C decision cells against one shared
    [V, n] (pi, nu) view history.

    The DS_PGM potential-gain order ``c_j / -log(rho_j)`` does not
    depend on the miss penalty — on a penalty-axis grid (the paper's
    Fig. 3) every cell with the same (costs, CS_FNO) pair shares one
    sort.  Stage 1 therefore sorts only the G UNIQUE (costs, fno)
    groups (``costs_u`` [G, n], ``fno_u`` [G]); stage 2 gathers each
    cell's group (``group_idx`` [C]) and finishes with its own penalty
    (prefix costs, argmin, scatter back to cache order).  Both stages
    replicate :func:`ds_pgm_batched`'s operation chain exactly — the
    one deviation is inverting the sort permutation by scatter instead
    of a second argsort, which is the same bijection computed exactly.

    The pattern grid / rho stack is rebuilt ON DEVICE from the
    replicated (pi, nu) pair, so only [G, .] / [C, .] cell parameters
    travel along the sharded cell axis.  ``fno_u`` selects per group
    between the CS_FNO pattern mask and all-ones; an all-ones mask is
    an exact identity in the chain (``where(True, x, .)``).
    """
    v, n = pi.shape
    k = 1 << n
    pats = ((jnp.arange(k, dtype=jnp.int32)[:, None]
             >> jnp.arange(n, dtype=jnp.int32)[None, :]) & 1)     # [K, n]
    rhos = jnp.where(pats[None, :, :] > 0,
                     pi[:, None, :], nu[:, None, :]).reshape(v * k, n)
    r = jnp.clip(rhos, EPS, 1.0 - EPS)
    pat_rows = jnp.tile(pats, (v, 1))                             # [V*K, n]
    ones = jnp.ones_like(pat_rows)
    rows = v * k

    def sort_group(costs, fno):
        # ds_pgm_batched's sort-dependent half, penalty-free
        costs_b = jnp.broadcast_to(costs, (rows, n))
        allowed_rows = jnp.where(fno, pat_rows, ones) > 0
        key = jnp.where(allowed_rows, costs_b / -jnp.log(r), jnp.inf)
        order = jnp.argsort(key, axis=1)
        c_sorted = jnp.take_along_axis(costs_b, order, 1)
        r_sorted = jnp.take_along_axis(r, order, 1)
        allowed = jnp.take_along_axis(allowed_rows, order, 1)
        c_sorted = jnp.where(allowed, c_sorted, jnp.inf)
        r_sorted = jnp.where(allowed, r_sorted, 1.0)
        return (order, jnp.cumsum(c_sorted, axis=1),
                jnp.cumsum(jnp.log(r_sorted), axis=1))

    order_g, csum_g, lprod_g = jax.vmap(sort_group)(costs_u, fno_u)

    def finish_cell(gi, m):
        order, csum, lprod = order_g[gi], csum_g[gi], lprod_g[gi]
        phi = jnp.concatenate(
            [jnp.full((rows, 1), m, csum.dtype),
             csum + m * jnp.exp(lprod)], axis=1)                  # [rows, n+1]
        best = jnp.argmin(phi, axis=1)
        pick_sorted = jnp.arange(n)[None, :] < best[:, None]
        # back to cache order: cache j is picked iff its sorted slot is
        # (one-hot contraction — the inverse permutation, exactly, and
        # vectorizable where an XLA:CPU scatter would scalar-loop)
        onehot = order[:, :, None] == jnp.arange(n)[None, None, :]
        return jnp.any(pick_sorted[:, :, None] & onehot, axis=1)

    return jax.vmap(finish_cell)(group_idx, penalties)


def selection_tables_cells_jax(costs_cells, pi, nu, penalties, fno_cells,
                               *, mesh=None) -> np.ndarray:
    """[C, V, 2^n, n] decision tables for C cells — the jitted (and
    optionally device-sharded) twin of :func:`selection_tables_cells`.

    One compiled computation evaluates every (cell x version x pattern)
    row.  The potential-gain sort does not depend on the miss penalty,
    so cells are deduplicated host-side into unique (costs, fno) groups:
    the sort/prefix stage runs once per group and each cell finishes
    with its own penalty — on a penalty-axis grid (the paper's Fig. 3)
    that is one sort for all eight penalty cells per CS_FNO flag.  With
    a ``mesh`` (see ``repro.launch.mesh.make_sweep_mesh``) both the
    group and cell axes are padded to a multiple of the mesh size and
    row-sharded across devices while the shared (pi, nu) history is
    replicated, so a whole sweep grid's table phase runs as one SPMD
    computation.  Rows are evaluated independently, so cell c's slice
    equals a per-cell :func:`selection_tables` call up to the jit
    scheduling caveat below.

    Parity note: inside the jitted computation XLA may contract the
    ``csum + m * exp(lprod)`` prefix-cost pair into an FMA (one rounding
    instead of two), shifting a prefix cost by ~1 ulp relative to the
    eager/NumPy evaluation.  A mask can therefore flip ONLY where two
    prefix costs tie to within that ulp — inside the same ~1e-12
    near-tie dead-band already documented on :func:`selection_tables`;
    the differential tests gate exact mask agreement away from it.
    """
    pi = np.atleast_2d(np.asarray(pi, np.float64))
    nu = np.atleast_2d(np.asarray(nu, np.float64))
    v, n = pi.shape
    k = 1 << n
    costs_cells = np.atleast_2d(np.asarray(costs_cells, np.float64))
    penalties = np.asarray(penalties, np.float64)
    fno_cells = np.asarray(fno_cells, bool)
    c = costs_cells.shape[0]
    if c == 0:
        return np.empty((0, v, k, n), dtype=bool)
    # dedupe the penalty-independent sort stage: one group per unique
    # (costs, fno) pair, each cell pointing at its group
    keys = [(cc.tobytes(), bool(f))
            for cc, f in zip(costs_cells, fno_cells)]
    uniq: dict = {}
    group_idx = np.empty(c, np.int64)
    for i, key in enumerate(keys):
        group_idx[i] = uniq.setdefault(key, len(uniq))
    g = len(uniq)
    first = np.empty(g, np.int64)
    for i in range(c - 1, -1, -1):
        first[group_idx[i]] = i
    costs_u = costs_cells[first]
    fno_u = fno_cells[first]
    with enable_x64():
        if mesh is not None and mesh.size > 1:
            from repro.distributed.sharding import (
                replicate_to_mesh, shard_cells)
            (cu, fu), _ = shard_cells([costs_u, fno_u], mesh)
            (gi, pp), _ = shard_cells([group_idx, penalties], mesh)
            pi_d = replicate_to_mesh(pi, mesh)
            nu_d = replicate_to_mesh(nu, mesh)
        else:
            cu = jnp.asarray(costs_u)
            fu = jnp.asarray(fno_u)
            gi = jnp.asarray(group_idx)
            pp = jnp.asarray(penalties)
            pi_d = jnp.asarray(pi)
            nu_d = jnp.asarray(nu)
        out = np.asarray(_cells_tables_kernel(cu, fu, gi, pp, pi_d, nu_d))
    return out[:c].reshape(c, v, k, n)


def rho_selection_tables(costs, rhos, miss_penalty, *, allowed=None
                         ) -> np.ndarray:
    """[B, n] float64 DS_PGM masks for an arbitrary per-request rho matrix.

    The pattern-grid :func:`selection_tables` covers policies whose rho is
    a pure (version, indication-pattern) function; the calibrated policy's
    rho rows are instead keyed on its evolving calibration state (EWMA
    values, probe counts, epsilon exploration), one row per request.  This
    is the verification half of the ``fna_cal`` fast engine's
    speculate-and-commit loop (``repro.cachesim.fna_cal_fast``): it runs
    per speculation segment, so it is evaluated as a NumPy float64 mirror
    of :func:`ds_pgm_batched` — same stable potential-gain argsort, same
    ``exp(cumsum(log .))`` prefix evaluation, no per-segment dispatch
    overhead.  Agreement with the scalar ``ds_pgm`` carries the same
    ~1e-12 near-tie caveat documented on :func:`selection_tables`.

    ``allowed`` (bool [B, n], optional) restricts row b's candidates to
    ``allowed[b]`` — the CS_FNO restriction, handled exactly like
    ``ds_pgm_batched``'s ``fno_mask``: excluded caches sort last (key =
    inf), can never be picked (cost = inf kills every prefix containing
    one), and drop out of the exclusion product.
    """
    rhos = np.asarray(rhos, np.float64)
    b, n = rhos.shape
    costs = np.asarray(costs, np.float64)
    M = float(miss_penalty)
    logr = np.log(np.clip(rhos, EPS, 1.0 - EPS))
    key = costs[None, :] / -logr
    if allowed is not None:
        allowed = np.asarray(allowed, bool)
        key = np.where(allowed, key, np.inf)        # excluded -> last
        logr = np.where(allowed, logr, 0.0)         # drop from the product
    order = np.argsort(key, axis=1, kind="stable")
    flat = order + (np.arange(b) * n)[:, None]      # row-flattened gather
    if allowed is None:
        csum = np.cumsum(costs[order], axis=1)
    else:
        costs_b = np.where(allowed, np.broadcast_to(costs, (b, n)), np.inf)
        csum = np.cumsum(np.take_along_axis(costs_b, order, 1), axis=1)
    lprod = np.cumsum(logr.reshape(-1)[flat], axis=1)
    phi = csum + M * np.exp(lprod)                  # prefix costs, i = 1..n
    best = np.argmin(phi, axis=1)
    # the empty prefix (cost M) wins ties, exactly like argmin over [M, phi]
    take = np.where(phi[np.arange(b), best] < M, best + 1, 0)
    pick_sorted = np.arange(n)[None, :] < take[:, None]
    mask = np.empty((b, n), dtype=bool)
    mask.reshape(-1)[flat] = pick_sorted
    return mask


def _subset_dp(costs, rhos, miss_penalty):
    """[B, 2^n] Eq. (10) value of EVERY subset, bit-exact with the scalar
    :func:`repro.core.exhaustive` enumeration.

    The scalar loop accumulates a subset's cost and its exclusion product
    by ascending cache index, so ``phi[b, m]`` must reproduce exactly that
    IEEE operation order.  A DP that extends each mask by its HIGHEST set
    bit does: ``m`` strips to ``m ^ (1 << hb)``, whose own value was built
    in the same ascending order, and appends the one multiply/add the
    scalar loop performs last.

    ``miss_penalty`` is a scalar or a [B] per-row array (the stacked
    cross-cell build feeds one penalty per row) — the seeded product is
    the only place it enters, so per-row values keep every row's IEEE
    operation order identical to its scalar-penalty evaluation.
    """
    rhos = np.asarray(rhos, np.float64)
    b, n = rhos.shape
    k = 1 << n
    costs = np.asarray(costs, np.float64)
    cost_m = np.zeros(k, np.float64)
    prod_m = np.empty((b, k), np.float64)
    prod_m[:, 0] = np.asarray(miss_penalty, np.float64)
    for m in range(1, k):
        hb = m.bit_length() - 1
        rest = m ^ (1 << hb)
        cost_m[m] = cost_m[rest] + costs[hb]
        np.multiply(prod_m[:, rest], rhos[:, hb], out=prod_m[:, m])
    return cost_m[None, :] + prod_m


def rho_exhaustive_tables(costs, rhos, miss_penalty, *, allowed=None,
                          backend: str = "numpy") -> np.ndarray:
    """[B, n] bool masks: the exact Eq. (10) minimiser over all 2^n
    subsets for an arbitrary per-request rho matrix (n <= 16).

    The batched twin of the scalar :func:`repro.core.exhaustive` — the
    exhaustive counterpart of :func:`rho_selection_tables`, and the
    verification half of the calibrated fast engine when the exhaustive
    subroutine is configured.  ``allowed`` (int64 [B], optional) restricts
    row b to subsets of ``allowed[b]`` (the CS_FNO candidate set; the empty
    set is always allowed).  Subset values reproduce the scalar loop's IEEE
    operation order exactly (see ``_subset_dp``); the argmin takes the
    LOWEST qualifying mask, matching the scalar ascending enumeration, with
    the same ~1e-12 near-tie caveat documented on
    :func:`rho_selection_tables`.

    ``backend`` selects the subset-DP evaluator: ``"numpy"`` (this module's
    :func:`_subset_dp`, the golden oracle), ``"jax"`` or ``"pallas"``
    (``repro.kernels.subsetdp`` — bit-exact with the oracle by
    construction; the argmin reduction then runs on device so the
    [B, 2^n] value matrix never comes back to the host).
    """
    rhos = np.asarray(rhos, np.float64)
    b, n = rhos.shape
    if n > 16:
        raise ValueError("rho_exhaustive_tables() limited to n <= 16")
    k = 1 << n
    if backend != "numpy":
        if np.ndim(miss_penalty):
            raise ValueError(
                "per-row miss_penalty requires backend='numpy'")
        from repro.kernels.subsetdp import subset_argmin
        best = subset_argmin(costs, rhos, miss_penalty,
                             allowed=allowed, backend=backend)
        return ((best[:, None] >> np.arange(n)[None, :]) & 1).astype(bool)
    phi = _subset_dp(costs, rhos, miss_penalty)
    if allowed is not None:
        bad = (np.arange(k)[None, :] & ~np.asarray(allowed, np.int64)[:, None]) != 0
        phi[bad] = np.inf
    # np.argmin returns the FIRST minimal subset in ascending-mask order;
    # the scalar loop keeps the earlier mask unless a later one improves by
    # more than EPS — identical away from ~1e-12 near-ties
    best = np.argmin(phi, axis=1)
    return ((best[:, None] >> np.arange(n)[None, :]) & 1).astype(bool)


def exhaustive_tables(costs, pi, nu, miss_penalty, *, fno: bool = False,
                      chunk: int = None, backend: str = "numpy"
                      ) -> np.ndarray:
    """[V, 2^n] int64 selection bitmasks over ALL indication patterns for a
    batch of V view versions, with the EXHAUSTIVE subroutine
    (n <= ``MAX_EXHAUSTIVE_TABLE_CACHES``).

    The exhaustive counterpart of :func:`selection_tables`: row (v, p)
    holds the Eq. (10)-optimal subset under view version v for indication
    pattern p; ``fno=True`` restricts candidates to positive-indication
    caches.  Evaluated chunk-wise so the [rows, 2^n] subset matrix stays
    bounded — ``chunk=None`` sizes chunks to ~``EXHAUSTIVE_CHUNK_ELEMS``
    float64 elements, which keeps the peak working set near ~32 MB however
    large n grows within the cap; the simulator fast engine feeds its
    whole version history here when ``alg="exhaustive"``.  ``backend``
    selects the subset-DP evaluator (see :func:`rho_exhaustive_tables`).
    """
    pi = np.atleast_2d(np.asarray(pi, np.float64))
    nu = np.atleast_2d(np.asarray(nu, np.float64))
    v, n = pi.shape
    if n > MAX_EXHAUSTIVE_TABLE_CACHES:
        raise ValueError(
            f"exhaustive_tables() limited to n <= {MAX_EXHAUSTIVE_TABLE_CACHES}")
    k = 1 << n
    if chunk is None:
        chunk = max(1, EXHAUSTIVE_CHUNK_ELEMS // k)
    pat_bits = (np.arange(k)[:, None] >> np.arange(n)[None, :]) & 1   # [K,n]
    rhos = np.where(pat_bits[None, :, :] > 0,
                    pi[:, None, :], nu[:, None, :]).reshape(v * k, n)
    allowed = np.tile(np.arange(k, dtype=np.int64), v) if fno else None
    pow2 = (1 << np.arange(n)).astype(np.int64)
    out = np.empty(v * k, np.int64)
    for lo in range(0, v * k, chunk):
        hi = min(lo + chunk, v * k)
        mask = rho_exhaustive_tables(
            costs, rhos[lo:hi], miss_penalty,
            allowed=None if allowed is None else allowed[lo:hi],
            backend=backend)
        out[lo:hi] = mask @ pow2
    return out.reshape(v, k)


def exhaustive_tables_cells(costs, pi, nu, penalties, *, fno: bool = False,
                            chunk: int = None) -> np.ndarray:
    """[C, V, 2^n] stacked exhaustive tables for C decision cells sharing
    one (costs, fno) but differing in miss penalty — the cross-cell
    prefetch of a penalty-axis sweep (``repro.cachesim.engine``).

    One chunked subset-DP pass covers every (cell, version, pattern) row:
    the rho matrix is penalty-independent, so it is materialised once and
    fancy-indexed per chunk, with the per-row penalty entering only as
    the seeded product of :func:`_subset_dp`.  Each cell's slice is
    bit-identical to the per-cell :func:`exhaustive_tables` call it
    replaces (rows are evaluated independently; chunk boundaries don't
    enter the arithmetic), and the peak working set stays at the same
    ~``EXHAUSTIVE_CHUNK_ELEMS`` bound however many cells stack.
    """
    pi = np.atleast_2d(np.asarray(pi, np.float64))
    nu = np.atleast_2d(np.asarray(nu, np.float64))
    v, n = pi.shape
    if n > MAX_EXHAUSTIVE_TABLE_CACHES:
        raise ValueError(
            f"exhaustive_tables_cells() limited to "
            f"n <= {MAX_EXHAUSTIVE_TABLE_CACHES}")
    penalties = np.asarray(penalties, np.float64)
    c = penalties.shape[0]
    k = 1 << n
    if chunk is None:
        chunk = max(1, EXHAUSTIVE_CHUNK_ELEMS // k)
    pat_bits = (np.arange(k)[:, None] >> np.arange(n)[None, :]) & 1   # [K,n]
    rhos = np.where(pat_bits[None, :, :] > 0,
                    pi[:, None, :], nu[:, None, :]).reshape(v * k, n)
    allowed = np.tile(np.arange(k, dtype=np.int64), v) if fno else None
    pow2 = (1 << np.arange(n)).astype(np.int64)
    total = c * v * k
    out = np.empty(total, np.int64)
    for lo in range(0, total, chunk):
        idx = np.arange(lo, min(lo + chunk, total))
        sub = idx % (v * k)             # the shared rho/allowed row
        mask = rho_exhaustive_tables(
            costs, rhos[sub], penalties[idx // (v * k)],
            allowed=None if allowed is None else allowed[sub])
        out[idx[0]:idx[-1] + 1] = mask @ pow2
    return out.reshape(c, v, k)


def cs_fna_batched(indications, costs, q, fp, fn, miss_penalty) -> jax.Array:
    """Algorithm 2, batched: all caches candidates, rho by indication."""
    rhos = rho_matrix(indications, q, fp, fn)
    return ds_pgm_batched(costs, rhos, miss_penalty)


def cs_fno_batched(indications, costs, q, fp, fn, miss_penalty) -> jax.Array:
    """FNO baseline, batched: positive-indication caches only."""
    rhos = rho_matrix(indications, q, fp, fn)
    return ds_pgm_batched(costs, rhos, miss_penalty, fno_mask=indications)


def _argmin_geometric_batched(m_eff, rho, r_max) -> np.ndarray:
    """Vectorised float64 mirror of the scalar
    :func:`repro.core.policies._argmin_geometric`: same edge-case
    branches, same {0, 1, floor(r*), ceil(r*), r_max} candidate
    shortlist scanned in ascending order with the same EPS
    strict-improvement dead-band.  All inputs broadcast to [B]."""
    m_eff, rho, r_max = np.broadcast_arrays(
        np.asarray(m_eff, np.float64), np.asarray(rho, np.float64),
        np.asarray(r_max, np.int64))
    out = np.zeros(m_eff.shape, np.int64)
    pos = r_max > 0
    tiny = pos & (rho <= EPS)
    out[tiny & (m_eff > 1.0)] = 1
    mid = pos & (rho > EPS) & (rho < 1.0 - EPS)
    if not mid.any():
        return out
    m = m_eff[mid]
    r = rho[mid]
    rmax = r_max[mid]
    # continuous optimum: r* = ln(m_eff * ln(1/rho)) / ln(1/rho)
    l = np.log(1.0 / r)
    r_cont = np.log(np.maximum(m * l, EPS)) / l
    cand = np.stack([np.zeros_like(r_cont), np.ones_like(r_cont),
                     np.floor(r_cont), np.ceil(r_cont),
                     rmax.astype(np.float64)], axis=1)
    cand = np.sort(cand, axis=1)          # the scalar's ascending scan
    ok = (cand >= 0.0) & (cand <= rmax[:, None].astype(np.float64))
    val = cand + m[:, None] * r[:, None] ** cand
    best_r = np.zeros(m.shape, np.float64)
    best_v = m.copy()                     # r = 0 baseline
    for s in range(cand.shape[1]):        # duplicates can't strictly improve
        imp = ok[:, s] & (val[:, s] < best_v - EPS)
        best_r = np.where(imp, cand[:, s], best_r)
        best_v = np.where(imp, val[:, s], best_v)
    out[mid] = best_r.astype(np.int64)
    return out


def _argmin_geometric_jax(m_eff, rho, r_max):
    """Branchless jnp mirror of :func:`_argmin_geometric_batched` — the
    same {0, 1, floor(r*), ceil(r*), r_max} shortlist scanned ascending
    with the same EPS strict-improvement dead-band, but expressed with
    ``where`` lanes instead of boolean fancy-indexing so it traces into
    one jitted grid evaluation.  Dead lanes (rho outside (EPS, 1-EPS))
    are fed a harmless rho = 0.5 to keep every intermediate finite."""
    m_eff = jnp.asarray(m_eff, jnp.float64)
    rho = jnp.asarray(rho, jnp.float64)
    r_max = jnp.asarray(r_max, jnp.int64)
    pos = r_max > 0
    tiny = pos & (rho <= EPS)
    mid = pos & (rho > EPS) & (rho < 1.0 - EPS)
    r = jnp.where(mid, rho, 0.5)
    l = jnp.log(1.0 / r)
    r_cont = jnp.log(jnp.maximum(m_eff * l, EPS)) / l
    rmax_f = r_max.astype(jnp.float64)
    cand = jnp.sort(jnp.stack(
        [jnp.zeros_like(r_cont), jnp.ones_like(r_cont),
         jnp.floor(r_cont), jnp.ceil(r_cont), rmax_f], axis=1), axis=1)
    ok = (cand >= 0.0) & (cand <= rmax_f[:, None])
    val = cand + m_eff[:, None] * r[:, None] ** cand
    best_r = jnp.zeros_like(r_cont)
    best_v = m_eff                        # r = 0 baseline
    for s in range(5):                    # static shortlist, ascending
        imp = ok[:, s] & (val[:, s] < best_v - EPS)
        best_r = jnp.where(imp, cand[:, s], best_r)
        best_v = jnp.where(imp, val[:, s], best_v)
    return jnp.where(mid, best_r.astype(jnp.int64),
                     jnp.where(tiny & (m_eff > 1.0), 1, 0))


@partial(jax.jit, static_argnames=("n",))
def _hocs_fna_jit(n_x, pi, nu, m, *, n):
    r1 = _argmin_geometric_jax(m, pi, n_x)
    residual = m * pi ** r1
    r0 = jnp.where(residual > 1.0,
                   _argmin_geometric_jax(residual, nu, n - n_x), 0)
    return r0.astype(jnp.int64), r1


def hocs_fna_batched(n_x, n, pi, nu, miss_penalty, *, backend: str = "numpy"
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Algorithm 1, batched over requests (homogeneous parameters).

    The float64 NumPy mirror of the scalar :func:`repro.core.hocs_fna` —
    same candidate shortlist and EPS dead-band via
    :func:`_argmin_geometric_batched` — so the simulator fast engine can
    evaluate a whole (view version x positive-count) grid in one call
    and stay bit-exact with the reference loop (the same near-tie caveat
    as :func:`selection_tables`: a candidate shortlist can only differ
    when the continuous optimum sits within ~1 ulp of an integer).

    ``n_x``: [B] positive-indication counts; ``pi``/``nu``/
    ``miss_penalty``: scalars or [B].  Returns (r0, r1) int64 [B].

    ``backend="jax"`` evaluates the same shortlist scan as one jitted
    x64 computation (:func:`_argmin_geometric_jax`).  Its integer
    (r0, r1) output matches the NumPy mirror except where a shortlist
    value ``r + m_eff * rho**r`` sits within ~1 ulp of the EPS
    strict-improvement margin (XLA may contract that mul-into-add pair
    into an FMA) — the same near-tie dead-band as everywhere else in the
    fast engine; the property tests pin agreement away from it.
    """
    n_x = np.asarray(n_x, np.int64)
    pi, nu, m, n_x = np.broadcast_arrays(
        np.asarray(pi, np.float64), np.asarray(nu, np.float64),
        np.asarray(miss_penalty, np.float64), n_x)
    if backend == "jax":
        with enable_x64():
            r0, r1 = _hocs_fna_jit(
                jnp.asarray(n_x), jnp.asarray(pi), jnp.asarray(nu),
                jnp.asarray(m), n=int(n))
            return np.asarray(r0), np.asarray(r1)
    r1 = _argmin_geometric_batched(m, pi, n_x)
    residual = m * pi ** r1
    r0 = np.where(residual > 1.0,
                  _argmin_geometric_batched(residual, nu, n - n_x), 0)
    return r0.astype(np.int64), r1


def hocs_selection_tables_cells(pi_v, nu_v, penalties) -> np.ndarray:
    """[C, V, 2^n] int64 HOCS selection bitmasks for C decision cells
    (one miss penalty each) sharing one view history — the cross-cell
    prefetch of a penalty-axis sweep (``repro.cachesim.engine``).

    Mirrors the reference loop exactly: per-version pooled estimates are
    LEFT-TO-RIGHT sums over caches (np.sum pairwise-accumulates for
    n >= 8, which can differ in the last ulp), computed ONCE (they are
    penalty-independent); the (r0*, r1*) grid is one
    :func:`hocs_fna_batched` call over every (cell, version, popcount)
    triple; and row (c, v, p) accesses the r1* cheapest positive-
    indication caches plus the r0* cheapest negative ones (ascending
    cache index — the homogeneous setting has no cost order).  The
    shortlist scan is elementwise per row, so each cell's slice is
    bit-identical to a per-cell call.
    """
    pi_v = np.atleast_2d(np.asarray(pi_v, np.float64))
    nu_v = np.atleast_2d(np.asarray(nu_v, np.float64))
    penalties = np.asarray(penalties, np.float64)
    c = penalties.shape[0]
    v, n = pi_v.shape
    k = 1 << n
    acc_pi = np.zeros(v, np.float64)
    acc_nu = np.zeros(v, np.float64)
    for j in range(n):                    # left-to-right, like sum(list)
        acc_pi = acc_pi + pi_v[:, j]
        acc_nu = acc_nu + nu_v[:, j]
    pi_h = acc_pi / n
    nu_h = acc_nu / n
    # (r0*, r1*) depends on the pattern only through its popcount
    nx = np.arange(n + 1, dtype=np.int64)
    r0g, r1g = hocs_fna_batched(
        np.tile(nx, c * v), n,
        np.tile(np.repeat(pi_h, n + 1), c),
        np.tile(np.repeat(nu_h, n + 1), c),
        np.repeat(penalties, v * (n + 1)))
    r0g = r0g.reshape(c * v, n + 1)
    r1g = r1g.reshape(c * v, n + 1)
    bits = ((np.arange(k)[:, None] >> np.arange(n)[None, :]) & 1
            ).astype(np.int64)                                    # [K, n]
    pow2 = (1 << np.arange(n)).astype(np.int64)
    rank_pos = np.cumsum(bits, axis=1)      # 1-based rank among set bits
    rank_neg = np.cumsum(1 - bits, axis=1)
    # low_set[p, r] = mask of the r lowest-index positive caches of p
    low_set = np.stack([(bits * (rank_pos <= r)) @ pow2
                        for r in range(n + 1)], axis=1)           # [K, n+1]
    low_clr = np.stack([((1 - bits) * (rank_neg <= r)) @ pow2
                        for r in range(n + 1)], axis=1)
    popc = bits.sum(axis=1)                                       # [K]
    rows = np.arange(k)[None, :]
    sel = low_set[rows, r1g[:, popc]] | low_clr[rows, r0g[:, popc]]
    return sel.astype(np.int64).reshape(c, v, k)


def hocs_selection_tables(pi_v, nu_v, miss_penalty) -> np.ndarray:
    """[V, 2^n] int64 HOCS selection bitmasks over ALL indication
    patterns for a batch of V view versions — the single-cell view of
    :func:`hocs_selection_tables_cells` (same code path, so the stacked
    prefetch and the per-cell provider build cannot drift apart)."""
    return hocs_selection_tables_cells(
        pi_v, nu_v, [float(miss_penalty)])[0]