"""The paper's primary contribution: false-negative-aware cache selection
with stale indicators (Cohen, Einziger, Scalosub, 2021)."""
from repro.core.model import (
    CacheView,
    exclusion_probabilities,
    hit_ratio_from_q,
    is_sufficiently_accurate,
    phi_hat,
    positive_indication_ratio,
    service_cost,
)
from repro.core.policies import (
    cs_fna,
    cs_fno,
    ds_pgm,
    exhaustive,
    exhaustive_mask,
    expected_cost,
    hocs_fna,
    perfect_information,
    rho_vector,
)
from repro.core.indicator import (
    CountingBloomFilter,
    StaleIndicatorPair,
    hash_indices,
    optimal_k,
    theoretical_fp,
)
from repro.core.estimator import QEstimator, WindowedRatio

__all__ = [
    "CacheView", "exclusion_probabilities", "hit_ratio_from_q",
    "is_sufficiently_accurate", "phi_hat", "positive_indication_ratio",
    "service_cost", "cs_fna", "cs_fno", "ds_pgm", "exhaustive",
    "exhaustive_mask", "expected_cost", "hocs_fna", "perfect_information", "rho_vector",
    "CountingBloomFilter", "StaleIndicatorPair", "hash_indices", "optimal_k",
    "theoretical_fp", "QEstimator", "WindowedRatio",
]
