"""Bloom-filter indicators with staleness (paper Sec. IV-A/IV-B).

The cache keeps a Counting Bloom Filter for bookkeeping (supports
eviction), compresses it to a plain bitmap for advertisement, and keeps the
last advertised ("stale") bitmap to estimate the staleness-induced
false-negative / false-positive ratios via Eqs. (7)-(8):

  FN_t = 1 - [ (B1 - D1) / B1 ]^k                       (7)
  FP_t = [ (B1 - D1 + D0) / m ]^k                       (8)

where B1 = #set bits in the updated filter, D1 = bits set in the updated
filter but clear in the stale one, D0 = the converse.

Hashing: k indexes via double hashing of two splitmix64 streams — fast,
vectorisable (numpy), and identical in the JAX/Pallas kernels.
"""
from __future__ import annotations

import math
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

MASK64 = (1 << 64) - 1


def splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorised splitmix64 finaliser (uint64 in/out)."""
    z = (x + np.uint64(0x9E3779B97F4A7C15)) & np.uint64(MASK64)
    z = ((z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) & np.uint64(MASK64)
    z = ((z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) & np.uint64(MASK64)
    return z ^ (z >> np.uint64(31))


def optimal_k(bpe: float) -> int:
    """k minimising the false-positive ratio: k = ln2 * bpe (>= 1)."""
    return max(1, round(math.log(2.0) * bpe))


def hash_indices(keys: np.ndarray, k: int, m: int, seed: int = 0) -> np.ndarray:
    """[len(keys), k] bit indices via double hashing."""
    keys = np.asarray(keys, dtype=np.uint64)
    h1 = splitmix64(keys ^ np.uint64(seed * 0x9E3779B97F4A7C15 & MASK64))
    h2 = splitmix64(keys ^ np.uint64(0xDEADBEEFCAFEBABE)) | np.uint64(1)
    i = np.arange(k, dtype=np.uint64)[None, :]
    return ((h1[:, None] + i * h2[:, None]) % np.uint64(m)).astype(np.int64)


class CountingBloomFilter:
    """CBF with small counters; compressible to a plain bitmap."""

    def __init__(self, m: int, k: int, seed: int = 0):
        self.m = int(m)
        self.k = int(k)
        self.seed = seed
        self.counters = np.zeros(self.m, dtype=np.uint8)

    def _idx(self, key: int) -> np.ndarray:
        return hash_indices(np.asarray([key]), self.k, self.m, self.seed)[0]

    def add(self, key: int) -> None:
        idx = self._idx(key)
        # saturating add (3-bit counters saturate at 7 in the paper; uint8
        # here — overflow is equally impossible for our cache sizes)
        self.counters[idx] = np.minimum(self.counters[idx].astype(np.int32) + 1, 255)

    def remove(self, key: int) -> None:
        idx = self._idx(key)
        c = self.counters[idx].astype(np.int32) - 1
        self.counters[idx] = np.maximum(c, 0)

    def query(self, key: int) -> bool:
        return bool(np.all(self.counters[self._idx(key)] > 0))

    def to_bitmap(self) -> np.ndarray:
        """Advertised 1-bit indicator: bit set iff counter > 0."""
        return self.counters > 0


class StaleIndicatorPair:
    """Cache-side state: updated CBF + last-advertised (stale) bitmap.

    Exposes Eq. (7)/(8) estimation and client-visible stale queries.
    """

    def __init__(self, m: int, k: int, seed: int = 0):
        self.cbf = CountingBloomFilter(m, k, seed)
        self.stale = np.zeros(m, dtype=bool)
        self.fn_est = 0.0
        self.fp_est = 0.0

    # --- cache side -------------------------------------------------------
    def advertise(self) -> np.ndarray:
        """Publish a fresh bitmap (the client replaces its replica)."""
        self.stale = self.cbf.to_bitmap().copy()
        return self.stale

    def estimate_rates(self) -> Tuple[float, float]:
        """Eqs. (7)-(8) from the (updated, stale) pair."""
        updated = self.cbf.to_bitmap()
        b1 = int(np.count_nonzero(updated))
        d1 = int(np.count_nonzero(updated & ~self.stale))
        d0 = int(np.count_nonzero(~updated & self.stale))
        k, m = self.cbf.k, self.cbf.m
        if b1 > 0:
            self.fn_est = 1.0 - ((b1 - d1) / b1) ** k
        else:
            self.fn_est = 0.0
        self.fp_est = ((b1 - d1 + d0) / m) ** k
        return self.fp_est, self.fn_est

    # --- client side ------------------------------------------------------
    def stale_query(self, key: int) -> bool:
        idx = hash_indices(np.asarray([key]), self.cbf.k, self.cbf.m, self.cbf.seed)[0]
        return bool(np.all(self.stale[idx]))

    def fresh_query(self, key: int) -> bool:
        return self.cbf.query(key)


def theoretical_fp(bpe: float, k: Optional[int] = None) -> float:
    """Designed false-positive ratio of a filter with ``k`` hash functions
    (``k=None`` picks the optimal count; an explicit ``k=0`` means no
    hashing at all and yields a degenerate always-positive filter)."""
    if k is None:
        k = optimal_k(bpe)
    return (1.0 - math.exp(-k / bpe)) ** k
