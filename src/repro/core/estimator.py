"""Client-side estimation of the positive-indication ratio q_j (Eq. 9).

Epochs of T requests; within epoch i the estimate is frozen at the value
computed at the end of epoch i-1; at each epoch boundary:

    q <- delta * (a / T) + (1 - delta) * q          (Eq. 9)

where ``a`` counts positive indications observed during the epoch.  Only
the client can do this — it sees every request, not just accessed caches.
"""
from __future__ import annotations

import numpy as np


class QEstimator:
    def __init__(self, horizon: int = 100, delta: float = 0.25, q0: float = 0.5):
        if int(horizon) < 1:
            # horizon <= 0 would make observe() close an epoch on a zero
            # count (ZeroDivisionError) and observe_batch() loop forever
            raise ValueError(
                f"QEstimator horizon must be a positive epoch length, "
                f"got {horizon!r}")
        self.horizon = int(horizon)
        self.delta = float(delta)
        self.q = float(q0)
        self.version = 0  # bumped at every epoch boundary (cache invalidation)
        self._count = 0
        self._positives = 0
        self._bootstrapped = False

    def _close_epoch(self) -> None:
        frac = self._positives / self._count
        if not self._bootstrapped:
            # first epoch: raw average (q_{j,t} = a(0,t)/t for t <= T)
            self.q = frac
            self._bootstrapped = True
        else:
            self.q = self.delta * frac + (1.0 - self.delta) * self.q
        self.version += 1
        self._count = 0
        self._positives = 0

    def observe(self, indication: bool) -> None:
        self._count += 1
        self._positives += int(indication)
        if self._count >= self.horizon:
            self._close_epoch()

    def observe_batch(self, indications: np.ndarray) -> int:
        """Consume a slice of indications at once (simulator fast engine).

        Bit-exact with calling :meth:`observe` per element: the positive
        counter is an integer, so within-epoch summation order is
        irrelevant, and each completed epoch applies exactly the Eq. (9)
        update the scalar path would.  Returns the number of epoch
        boundaries crossed (each also bumped :attr:`version`).
        """
        a = np.asarray(indications, dtype=bool)
        crossed, i, total = 0, 0, int(a.shape[0])
        while i < total:
            take = min(self.horizon - self._count, total - i)
            self._positives += int(np.count_nonzero(a[i:i + take]))
            self._count += take
            i += take
            if self._count >= self.horizon:
                self._close_epoch()
                crossed += 1
        return crossed

    @property
    def value(self) -> float:
        return self.q


def ewma_path(e0: float, outcomes: np.ndarray, gamma: float) -> np.ndarray:
    """Exact trajectory of the probe-feedback EWMA ``e <- (1-g)e + g a``.

    ``outcomes`` are the {0, 1} probe results in arrival order; returns the
    value AFTER each update, as float64.  The recurrence is applied one
    scalar IEEE multiply-add at a time — i.e. it IS the reference loop's
    update, so the returned path is bit-identical to updating per probe
    (unlike an ``exp/cumsum`` closed form, whose rounding differs).  The
    simulator's calibrated fast engine uses this to advance a whole
    speculation segment's EWMA state in one call per (cache, branch).
    """
    a = np.asarray(outcomes, dtype=np.float64)
    out = np.empty(a.shape[0], dtype=np.float64)
    e = float(e0)
    g = float(gamma)
    for t, av in enumerate(a.tolist()):
        e = (1.0 - g) * e + g * av
        out[t] = e
    return out


class WindowedRatio:
    """Plain windowed ratio (used for measured FN/hit-rate reporting)."""

    def __init__(self):
        self.num = 0
        self.den = 0

    def observe(self, hit: bool) -> None:
        self.num += int(hit)
        self.den += 1

    @property
    def value(self) -> float:
        return self.num / self.den if self.den else 0.0
