"""Synthetic trace generators standing in for the paper's workloads.

The paper evaluates on Wiki / Gradle / Scarab / F2 traces that are not
redistributable offline, so we generate seeded synthetic traces matching
their qualitative structure (Sec. V-B of the paper characterises what
matters for FNA behaviour):

  * ``wiki``   — frequency-biased: bounded Zipf(0.99) over a large catalog;
                 popular items stay popular, few compulsory misses.
  * ``gradle`` — recency-biased: a stream of NEW objects each re-requested
                 shortly after first appearance (build artifacts), i.e.
                 high stack-locality and a constantly-moving working set.
                 This is the regime where staleness hurts FNO the most.
  * ``scarab`` — mixture of a Zipf head with a churning recency tail.
  * ``f2``     — financial transactions: looping scans over a block of
                 records plus a hot set.

Each generator is deterministic given (n, seed).
"""
from __future__ import annotations

import numpy as np

TRACES = ("wiki", "gradle", "scarab", "f2")


def _bounded_zipf_cdf(catalog: int, alpha: float) -> np.ndarray:
    ranks = np.arange(1, catalog + 1, dtype=np.float64)
    w = ranks ** -alpha
    return np.cumsum(w) / w.sum()


def zipf_trace(n: int, catalog: int = 400_000, alpha: float = 0.99,
               seed: int = 0, drift: float = 0.01) -> np.ndarray:
    """Zipf with slow popularity DRIFT: the rank->item mapping slides by one
    position every 1/drift requests, so trending items continuously enter
    the popular head (real Wikipedia traffic is non-stationary; a perfectly
    stationary Zipf would make staleness-induced false negatives vanishingly
    rare, which no measured wiki workload shows — cf. paper Fig. 1)."""
    rng = np.random.default_rng(seed)
    cdf = _bounded_zipf_cdf(catalog, alpha)
    u = rng.random(n)
    ranks = np.searchsorted(cdf, u)
    shift = (np.arange(n) * drift).astype(np.int64)
    ids = (ranks + shift) % catalog
    # shuffle rank->id so popularity isn't correlated with id value
    perm = rng.permutation(catalog)
    return perm[ids].astype(np.int64)


def _recency_trace_ref(n: int, p_new: float = 0.25, window: int = 4096,
                       alpha: float = 1.2, seed: int = 0) -> np.ndarray:
    """Per-request reference loop for :func:`recency_trace` (kept as the
    bit-exactness oracle for the vectorised generator)."""
    rng = np.random.default_rng(seed)
    cdf = _bounded_zipf_cdf(window, alpha)
    out = np.empty(n, dtype=np.int64)
    hist = np.empty(n + window, dtype=np.int64)
    next_id = 0
    # seed the window
    for i in range(window):
        hist[i] = next_id = next_id + 1
    hlen = window
    us = rng.random(n)
    ds = np.searchsorted(cdf, rng.random(n)) + 1
    for i in range(n):
        if us[i] < p_new:
            next_id += 1
            x = next_id
        else:
            x = hist[hlen - int(ds[i])]
        out[i] = x
        hist[hlen] = x
        hlen += 1
    return out


def recency_trace(n: int, p_new: float = 0.25, window: int = 4096,
                  alpha: float = 1.2, seed: int = 0) -> np.ndarray:
    """Gradle-like: new ids arrive constantly; re-references target recent
    history with a Zipf-distributed stack distance.

    Vectorised via pointer doubling, bit-identical to the per-request
    loop (``_recency_trace_ref``) for every (n, seed): a re-reference at
    position i copies stream position ``i - d_i`` — a seed-window slot
    (value known in closed form) or an earlier output — so each request
    is a chain of strictly-decreasing pointers ending at a new id or a
    seed slot.  New ids are a cumulative count; chains collapse in
    O(log chain) vectorised pointer-jumping passes instead of n scalar
    steps (this generator dominates 1M+-request sweep setup otherwise).
    """
    rng = np.random.default_rng(seed)
    cdf = _bounded_zipf_cdf(window, alpha)
    us = rng.random(n)
    ds = np.searchsorted(cdf, rng.random(n)) + 1        # stack distances
    is_new = us < p_new
    out = np.where(is_new, window + np.cumsum(is_new), 0)
    ptr = np.arange(n, dtype=np.int64) - ds             # back-reference
    seed_ref = ~is_new & (ptr < 0)                      # into the seed window
    out[seed_ref] = window + ptr[seed_ref] + 1          # hist[j] = j + 1
    resolved = is_new | seed_ref
    unres = np.flatnonzero(~resolved)
    while unres.size:
        tgt = ptr[unres]
        done = resolved[tgt]
        hit = unres[done]
        out[hit] = out[tgt[done]]
        resolved[hit] = True
        rest = unres[~done]
        # target unresolved => value[target] = value[ptr[target]]: jump
        ptr[rest] = ptr[ptr[rest]]
        unres = rest
    return out


def mixed_trace(n: int, seed: int = 0) -> np.ndarray:
    """Scarab-like: 60% Zipf head / 40% recency churn (disjoint id spaces)."""
    rng = np.random.default_rng(seed)
    z = zipf_trace(n, catalog=100_000, alpha=0.9, seed=seed + 1)
    r = recency_trace(n, p_new=0.35, window=2048, seed=seed + 2) + 10_000_000
    pick = rng.random(n) < 0.6
    return np.where(pick, z, r)


def loop_scan_trace(n: int, block: int = 30_000, hot: int = 2_000,
                    p_hot: float = 0.3, seed: int = 0) -> np.ndarray:
    """F2-like: sequential scans over a records block + a hot set."""
    rng = np.random.default_rng(seed)
    scan = (np.arange(n, dtype=np.int64) % block) + 1_000_000
    hot_ids = rng.integers(0, hot, n)
    pick = rng.random(n) < p_hot
    return np.where(pick, hot_ids, scan)


def get_trace(name: str, n: int, seed: int = 0, **kwargs) -> np.ndarray:
    """Generate or load a named trace.

    ``name`` is a synthetic generator (``wiki``/``gradle``/``scarab``/
    ``f2``; ``kwargs`` pass through as catalog / skew / churn knobs — the
    scenario registry uses this for heavier-than-paper regimes, and the
    no-kwargs call stays bit-identical per (name, n, seed)), OR a
    file-backed trace (``repro.cachesim.tracefiles``): the literal
    ``file:<path>`` spelling or an alias registered via
    ``tracefiles.register_trace_file``.  For file traces ``n`` bounds the
    returned length (head subsample), ``kwargs`` are loader knobs
    (``fmt``/``key_column``/``head``/``stride``/...), and ``seed`` is
    ignored — log replay is deterministic by nature.
    """
    if name == "wiki":
        return zipf_trace(n, seed=seed, **kwargs)
    if name == "gradle":
        return recency_trace(n, seed=seed, **kwargs)
    if name == "scarab":
        return mixed_trace(n, seed=seed, **kwargs)
    if name == "f2":
        return loop_scan_trace(n, seed=seed, **kwargs)
    from repro.cachesim import tracefiles
    if tracefiles.is_trace_file(name):
        return tracefiles.get_file_trace(name, n, **kwargs)
    raise KeyError(
        f"unknown trace {name!r}; known generators: {TRACES}, registered "
        f"trace files: {sorted(tracefiles.TRACE_FILES)} (or 'file:<path>')")
