"""Declarative scenario registry: named experiment configurations.

The paper's evaluation (Figs. 1, 3-7) is a family of policy grids — each
figure fixes a system configuration, sweeps one axis (update interval,
indicator budget, cache size, cache count, miss penalty) over a set of
workloads, and compares every policy per cell.  A :class:`Scenario`
captures exactly that, declaratively:

  * ``traces``       — workload names (``repro.cachesim.traces``), plus
                       optional per-trace generator knobs (catalog size,
                       skew, churn) via ``trace_kwargs``;
  * ``base``         — the common ``SimConfig`` fields (costs, sizes, bpe,
                       intervals, miss penalty, subroutine).  Per-cache
                       fields accept sequences (heterogeneous tiers);
  * ``axis/values``  — the swept field and its grid.  A value is a
                       scalar, a per-cache tuple, or a mapping of coupled
                       overrides (see ``repro.cachesim.sweep``);
  * ``policies``     — the policy panel of the figure;
  * golden fields    — the small, fixed sub-grid pinned by the golden
                       differential suite (``tests/golden/``; regenerate
                       with ``python tools/regen_golden.py`` — see
                       ``docs/scenarios.md``).

The registry covers the paper's Fig. 4-7 setups (homogeneous caches, one
cost vector) AND heterogeneous regimes the journal version (arXiv:
2203.09119) and the bandwidth-constrained follow-up (arXiv:2104.01386)
emphasise: cheap-small/expensive-large cache tiers, per-cache staggered
advertisement cadences, and delayed-view clients whose view of one cache
is persistently stale.

:func:`run_scenario` executes any scenario end-to-end through the
shared-SystemTrace grid runner and returns flat records;
``benchmarks/paper_figs.py`` turns those into per-figure JSON/CSV
artifacts and cost curves.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.cachesim import tracefiles
from repro.cachesim.simulator import SimConfig
from repro.cachesim.sweep import (
    axis_column,
    cell_label,
    cell_overrides,
    hashable_label,
    run_grid,
    sweep_records,
)
from repro.cachesim.traces import get_trace

#: the full policy panel of the heterogeneous figures
PANEL = ("fna", "fna_cal", "fno", "pi")
#: the homogeneous panel (Algorithm 1 requires identical costs)
PANEL_HOM = ("fna", "fna_cal", "fno", "hocs", "pi")


@dataclass(frozen=True)
class Scenario:
    """One named experiment configuration (see module docstring)."""
    name: str
    figure: str                      # paper figure, or "beyond" (new regime)
    description: str
    traces: Tuple[str, ...]
    axis: str                        # swept SimConfig field (the x-axis)
    values: tuple                    # scalars, per-cache tuples, or mappings
    base: Mapping = field(default_factory=dict)   # common SimConfig kwargs
    policies: Tuple[str, ...] = PANEL
    n_requests: int = 60_000         # reduced scale (CI / laptop)
    n_requests_full: int = 1_000_000 # paper scale
    seed: int = 1
    trace_kwargs: Mapping = field(default_factory=dict)  # per-trace knobs
    # --- golden differential sub-grid (reference-engine pinned).  Kept
    # small (a few thousand requests) but NON-degenerate: golden cells
    # must fire advertisements and estimate updates within the short run,
    # so their values/overrides may differ from the display grid ---------
    golden_values: Optional[tuple] = None   # default: first two axis values
    golden_traces: Optional[tuple] = None   # default: first trace
    golden_base: Mapping = field(default_factory=dict)   # extra overrides
    golden_n_requests: int = 5_000
    # --- hierarchical scenarios (repro.cachesim.topology): TopoConfig
    # kwargs beyond ``base`` (kind, depth, fanout, per-tier mappings,
    # origin knobs).  None/empty -> the flat single-hop system ----------
    topology: Optional[Mapping] = None

    def config(self, **overrides):
        """The cell-independent base config (+ ad-hoc SimConfig
        overrides): a ``SimConfig``, or — for hierarchical scenarios —
        a ``TopoConfig`` wrapping it (``run_grid`` dispatches on the
        type)."""
        kw = dict(self.base)
        kw.update(overrides)
        kw.setdefault("seed", self.seed)
        cfg = SimConfig(**kw)
        if not self.topology:
            return cfg
        from repro.cachesim.topology import TopoConfig
        return TopoConfig(base=cfg, **self.topology)

    def make_traces(self, n_requests: int,
                    names: Optional[Sequence[str]] = None) -> Dict:
        """Generate/load the scenario's workloads at ``n_requests``.
        Names resolve through :func:`~repro.cachesim.traces.get_trace`,
        so a trace is a synthetic generator OR a file-backed trace
        (registered alias / ``file:<path>``); ``trace_kwargs`` carries
        per-trace generator knobs or loader kwargs respectively."""
        names = tuple(names if names is not None else self.traces)
        return {t: get_trace(t, n_requests, seed=self.seed,
                             **self.trace_kwargs.get(t, {}))
                for t in names}

    def file_trace_infos(self, n_requests: int,
                         names: Optional[Sequence[str]] = None) -> Dict:
        """``{name: TraceInfo dict}`` for the scenario's FILE-backed
        traces at the given subsample length (empty for generator-only
        scenarios) — the figure pipeline records these in its JSON
        artifacts so measured-workload runs stay self-describing."""
        names = tuple(names if names is not None else self.traces)
        out: Dict[str, dict] = {}
        for t in names:
            if tracefiles.is_trace_file(t):
                _, info = tracefiles.get_file_trace(
                    t, n_requests, with_info=True,
                    **self.trace_kwargs.get(t, {}))
                out[t] = info.to_dict()
        return out

    # -- golden sub-grid ---------------------------------------------------

    def golden_trace_names(self) -> Tuple[str, ...]:
        """The workloads of the pinned golden sub-grid (also the smoke
        grid's — keep every consumer on this one selection rule)."""
        return tuple(self.golden_traces or self.traces[:1])

    def golden_grid(self) -> Tuple[Dict, tuple]:
        """(traces, values) of the pinned golden sub-grid."""
        values = self.golden_values if self.golden_values is not None \
            else self.values[:2]
        traces = self.make_traces(self.golden_n_requests,
                                  names=self.golden_trace_names())
        return traces, values


SCENARIOS: Dict[str, Scenario] = {}


def _scenario(**kw) -> Scenario:
    sc = Scenario(**kw)
    if sc.name in SCENARIOS:
        raise ValueError(f"duplicate scenario {sc.name!r}")
    SCENARIOS[sc.name] = sc
    return sc


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; known: "
                       f"{sorted(SCENARIOS)}") from None


def list_scenarios(figure: Optional[str] = None) -> List[Scenario]:
    out = [sc for sc in SCENARIOS.values()
           if figure is None or sc.figure == figure]
    return sorted(out, key=lambda sc: sc.name)


def run_scenario(sc: Scenario, n_requests: Optional[int] = None,
                 engine: str = "fast", share_system: bool = True,
                 policies: Optional[Sequence[str]] = None,
                 golden: bool = False, store=None,
                 workers: int = 0,
                 chunk_size: Optional[int] = None) -> List[dict]:
    """Execute a scenario through the shared-SystemTrace grid runner and
    return one flat record per (trace, cell, policy) — the pipeline input
    of ``benchmarks/paper_figs.py``.

    ``store``/``workers``/``chunk_size`` pass straight to
    :func:`~repro.cachesim.sweep.run_grid`: a content-addressed artifact
    store for sweep/table reuse across runs, a phase-1 process pool over
    independent system-key groups, and streaming phase-1 sweeps over
    fixed-size trace slices (each bit-identical to the serial one-shot
    path).

    ``golden=True`` runs the pinned golden sub-grid (golden traces,
    values, base overrides and request count) instead of the display
    grid — the sub-grid is chosen to stay NON-degenerate at its short
    length, so it is also the right shape for smoke runs."""
    if golden:
        n_req = n_requests if n_requests is not None else sc.golden_n_requests
        traces, values = sc.golden_grid()
        if n_req != sc.golden_n_requests:
            traces = sc.make_traces(n_req, names=tuple(traces))
        base = sc.config(engine=engine, **sc.golden_base)
    else:
        n_req = n_requests if n_requests is not None else sc.n_requests
        traces, values = sc.make_traces(n_req), sc.values
        base = sc.config(engine=engine)
    grid = run_grid(traces, base, sc.axis, values,
                    policies=tuple(policies or sc.policies),
                    share_system=share_system, store=store,
                    workers=workers, chunk_size=chunk_size)
    records = sweep_records(grid, axis=sc.axis)
    # mapping cells carry coupled overrides beyond the axis label (Fig. 6
    # moves update_interval with cache_size): put them on the records so
    # artifacts stay self-describing
    extra = {cell_label(sc.axis, v): cell_overrides(sc.axis, v)
             for v in values if isinstance(v, Mapping)}
    col = axis_column(sc.axis)
    for rec in records:
        rec["scenario"] = sc.name
        for k, v in extra.get(hashable_label(rec[col]), {}).items():
            rec.setdefault(k, v)
    return records


# ===========================================================================
# Paper figures (reduced-scale grids; --full rescales in paper_figs)
# ===========================================================================

_scenario(
    name="fig1_staleness",
    figure="fig1",
    description="FN/FP ratio of the advertised indicator vs update "
                "interval (paper Fig. 1: staleness manufactures false "
                "negatives; >10% beyond 1K insertions).",
    traces=("wiki", "gradle"),
    axis="update_interval",
    values=(16, 64, 256, 1024, 2048),
    base=dict(cache_size=2_000, bpe=14.0),
    policies=("fno",),
)

_scenario(
    name="fig1_staleness_tight",
    figure="fig1",
    description="Fig. 1 with a tight 4-bits-per-entry indicator: the FP "
                "floor rises, the staleness-driven FN growth stays.",
    traces=("wiki", "gradle"),
    axis="update_interval",
    values=(16, 64, 256, 1024, 2048),
    base=dict(cache_size=2_000, bpe=4.0),
    policies=("fno",),
)

_scenario(
    name="fig3_penalty",
    figure="fig3",
    description="Normalised cost vs miss penalty across all four "
                "workloads (paper Fig. 3).",
    traces=("wiki", "gradle", "scarab", "f2"),
    axis="miss_penalty",
    values=(50.0, 100.0, 500.0),
    base=dict(cache_size=2_000, update_interval=200),
    golden_traces=("gradle", "f2"),
    golden_values=(50.0, 500.0),
)

_scenario(
    name="fig3_penalty_shared",
    figure="fig3",
    description="Fig. 3's miss-penalty axis as a DECISION-SIDE grid "
                "(8 cells x all four workloads): every cell leaves "
                "SystemTrace.system_key unchanged, so the sweep runner "
                "computes ONE system sweep per trace and replays all "
                "penalty cells against it, ds_pgm tables stacked into a "
                "single batched call.",
    traces=("wiki", "gradle", "scarab", "f2"),
    axis="miss_penalty",
    values=(25.0, 50.0, 75.0, 100.0, 150.0, 250.0, 500.0, 1000.0),
    base=dict(cache_size=2_000, update_interval=200),
    golden_traces=("wiki", "scarab"),
    golden_values=(25.0, 1000.0),
)

_scenario(
    name="fig4_gradle",
    figure="fig4",
    description="Normalised cost vs update interval on the recency-biased "
                "gradle workload (paper Fig. 4's headline regime: "
                "staleness hurts FNO most where the working set moves).",
    traces=("gradle",),
    axis="update_interval",
    values=(16, 128, 512, 2048, 8192),
    base=dict(cache_size=2_000),
    golden_values=(64, 512),
)

_scenario(
    name="fig4_wiki",
    figure="fig4",
    description="Normalised cost vs update interval on the "
                "frequency-biased wiki workload (paper Fig. 4).",
    traces=("wiki",),
    axis="update_interval",
    values=(16, 128, 512, 2048, 8192),
    base=dict(cache_size=2_000),
    golden_values=(64, 512),
)

_scenario(
    name="fig5_indicator_size",
    figure="fig5",
    description="Normalised cost vs indicator budget (bits per entry) at "
                "the STALE advertisement cadence (paper Fig. 5, incl. the "
                "FNO anomaly: a LARGER indicator can hurt FNO; "
                "``fig5_indicator_size_fresh`` covers the short cadence).",
    traces=("wiki", "gradle"),
    axis="bpe",
    values=(2.0, 4.0, 8.0, 14.0, 22.0),
    base=dict(cache_size=2_000, update_interval=800),
    golden_values=(4.0, 14.0),
)

_scenario(
    name="fig5_indicator_size_fresh",
    figure="fig5",
    description="Fig. 5's second cadence: the same bits-per-entry sweep "
                "with 4x more frequent advertisements, so the FP budget "
                "rather than staleness dominates.",
    traces=("wiki", "gradle"),
    axis="bpe",
    values=(2.0, 4.0, 8.0, 14.0, 22.0),
    base=dict(cache_size=2_000, update_interval=200),
    golden_values=(4.0, 14.0),
)

_scenario(
    name="fig6_cache_size",
    figure="fig6",
    description="Actual mean cost vs cache size, update interval scaled "
                "with capacity (paper Fig. 6: FNA at a fraction of the "
                "capacity beats FNO at full size).",
    traces=("wiki",),
    axis="cache_size",
    values=tuple({"cache_size": s, "update_interval": max(s // 8, 16)}
                 for s in (500, 1_000, 2_000, 4_000)),
    base=dict(),
    seed=2,
    n_requests=80_000,
    n_requests_full=300_000,
    golden_values=tuple({"cache_size": s, "update_interval": max(s // 8, 16)}
                        for s in (500, 2_000)),
)

_scenario(
    name="fig7_num_caches",
    figure="fig7",
    description="Normalised cost vs number of (homogeneous, cost-2) "
                "caches (paper Fig. 7); includes Algorithm 1 (HOCS).",
    traces=("gradle",),
    axis="n_caches",
    values=tuple({"n_caches": n, "costs": (2.0,) * n} for n in (2, 3, 5, 7)),
    base=dict(cache_size=2_000, update_interval=800),
    policies=PANEL_HOM,
    golden_values=tuple({"n_caches": n, "costs": (2.0,) * n} for n in (2, 5)),
    golden_base=dict(update_interval=150),
)

# ===========================================================================
# Beyond-paper heterogeneous regimes (journal / follow-up emphasis)
# ===========================================================================

_scenario(
    name="hetero_tiers",
    figure="beyond",
    description="Cheap-small / expensive-large cache tiers: cost and "
                "capacity anti-correlated (1x/500 vs 4x/4000), so the "
                "selection trade-off is genuinely heterogeneous.",
    traces=("gradle", "scarab"),
    axis="update_interval",
    values=(64, 512, 2048),
    base=dict(costs=(1.0, 2.0, 4.0), cache_size=(500, 1_500, 4_000)),
    golden_values=(64, 512),
)

_scenario(
    name="staggered_adverts",
    figure="beyond",
    description="Per-cache advertisement cadences (the bandwidth-"
                "constrained regime of arXiv:2104.01386): the same total "
                "advertisement budget concentrated on different caches.",
    traces=("gradle",),
    axis="update_interval",
    values=((600, 600, 600), (100, 400, 1_600),
            (1_600, 400, 100), (50, 250, 5_000)),
    base=dict(cache_size=2_000),
    golden_values=((150, 150, 150), (50, 150, 600)),
)

_scenario(
    name="delayed_view",
    figure="beyond",
    description="A delayed-view client: one cache's advertisements are "
                "an order of magnitude rarer, so its client view is "
                "persistently stale while the others stay fresh.",
    traces=("wiki",),
    axis="update_interval",
    values=((200, 200, 200), (200, 200, 2_000), (200, 200, 20_000)),
    base=dict(cache_size=2_000, est_interval=25),
    golden_values=((200, 200, 200), (200, 200, 2_000)),
)

_scenario(
    name="advert_budget",
    figure="beyond",
    description="Self-adjusting advertisement under a token-bucket "
                "bandwidth budget (arXiv:2104.01386): cost vs advert "
                "bandwidth (bytes per insertion).  Caches advertise on "
                "Eq. (7) predicted-FN drift when the bucket covers a "
                "full indicator; tight budgets starve advertisement and "
                "staleness costs surface, generous ones approach the "
                "fresh-indicator regime.",
    traces=("gradle",),
    axis="advert_bandwidth",
    values=(0.5, 2.0, 8.0, 32.0),
    base=dict(cache_size=2_000, advert_policy="self_adjusting",
              advert_threshold=0.05, est_interval=50),
    golden_values=(2.0, 32.0),
)

_scenario(
    name="advert_delta",
    figure="beyond",
    description="Delta advertisement (arXiv:2405.17801): the periodic "
                "cadence with measured changed-bit delta encoding on the "
                "wire instead of the full bitmap — identical system "
                "evolution, different bytes-on-wire (the advert_bytes "
                "column), shrinking as the cadence tightens.",
    traces=("gradle",),
    axis="update_interval",
    values=(64, 256, 1_024),
    base=dict(cache_size=2_000, advert_policy="delta"),
    golden_values=(64, 256),
)

_scenario(
    name="exhaustive_small",
    figure="beyond",
    description="The exact Eq. (10) subroutine (exhaustive 2^n "
                "enumeration) on a 4-cache heterogeneous system — "
                "pins the batched exhaustive fast path end to end.",
    traces=("gradle",),
    axis="update_interval",
    values=(100, 800),
    base=dict(n_caches=4, costs=(1.0, 2.0, 3.0, 1.5),
              cache_size=1_500, alg="exhaustive"),
    n_requests=30_000,
    golden_values=(100, 800),
)

_scenario(
    name="heavy_skew",
    figure="beyond",
    description="Wiki-like workload at a much heavier skew and smaller "
                "catalog (alpha 1.2, 100K items): hits concentrate, "
                "false positives dominate the indicator error budget.",
    traces=("wiki",),
    axis="update_interval",
    values=(64, 512, 2_048),
    base=dict(cache_size=2_000),
    trace_kwargs={"wiki": dict(alpha=1.2, catalog=100_000)},
    golden_values=(64, 512),
)

# ===========================================================================
# Hierarchical topologies (repro.cachesim.topology; ROADMAP item 3)
# ===========================================================================

_scenario(
    name="topo_path",
    figure="beyond",
    description="A PATH hierarchy on the recency-biased gradle workload: "
                "edge / regional / origin-side tiers with growing caches, "
                "slowing advertisement cadences, per-hop forward "
                "penalties, an admission queue at the middle tier and "
                "per-tier service latencies — normalised cost, mean "
                "latency and rejection rate vs hierarchy depth (depth 1 "
                "is the flat paper system).",
    traces=("gradle",),
    axis="depth",
    values=(1, 2, 3),
    base=dict(),
    topology=dict(
        kind="path", depth=3,
        tiers=(
            dict(cache_size=800, update_interval=150, tier_latency=1.0,
                 hop_penalty=5.0),
            dict(cache_size=2_000, update_interval=300, tier_latency=4.0,
                 hop_penalty=10.0, queue_capacity=36, queue_window=40),
            dict(cache_size=4_000, update_interval=600,
                 tier_latency=16.0),
        ),
        origin_latency=64.0),
    golden_values=(1, 3),
)

_scenario(
    name="topo_tree",
    figure="beyond",
    description="A 3-level TREE hierarchy (leaf edge caches fanning into "
                "regional parents into one root) on gradle: leaf "
                "admission queues reject a slice of arrivals, misses "
                "merge upward in trace order — cost/latency/rejection vs "
                "fan-out.",
    traces=("gradle",),
    axis="fanout",
    values=(2, 3),
    base=dict(),
    topology=dict(
        kind="tree", depth=3, fanout=2,
        tiers=(
            dict(cache_size=150, update_interval=40, tier_latency=1.0,
                 hop_penalty=5.0, queue_capacity=45, queue_window=50),
            dict(cache_size=400, update_interval=80, tier_latency=4.0,
                 hop_penalty=10.0),
            dict(cache_size=800, update_interval=150,
                 tier_latency=16.0),
        ),
        origin_latency=64.0),
    n_requests=40_000,
    golden_n_requests=4_000,
)

# ===========================================================================
# File-backed traces (repro.cachesim.tracefiles)
# ===========================================================================

#: committed redistributable sample logs (tools/make_trace_file.py
#: --samples; generated from the synthetic generators, so license-clean):
#: one recency-biased stream in the line-per-key shape, one Zipf-like
#: stream in the CSV shape — the wiki/CDN log shapes the paper family's
#: measured workloads arrive in.
_DATA_DIR = Path(__file__).resolve().parents[3] / "tests" / "data"

tracefiles.register_trace_file(
    "sample_recency", _DATA_DIR / "sample_recency.log.gz")
tracefiles.register_trace_file(
    "sample_zipf", _DATA_DIR / "sample_zipf.csv.gz", key_column="key")

_scenario(
    name="trace_file_smoke",
    figure="beyond",
    description="The full policy panel on FILE-BACKED traces: both "
                "committed sample logs (line-per-key recency stream + "
                "CSV Zipf stream) replayed through the trace-ingestion "
                "loader — pins the measured-workload path (parse, dense "
                "remap, npz cache, head subsample) end to end.",
    traces=("sample_recency", "sample_zipf"),
    axis="update_interval",
    values=(100, 400, 1_600),
    base=dict(cache_size=800),
    golden_traces=("sample_recency", "sample_zipf"),
    golden_values=(100, 400),
)

#: scenarios pinned by the golden differential suite — every policy of
#: each (including fna_cal everywhere, the exhaustive subroutine via
#: ``exhaustive_small``, and the trace-file ingestion path via
#: ``trace_file_smoke``) is asserted bit-exact fast-vs-reference
GOLDEN_SCENARIOS = (
    "fig3_penalty", "fig3_penalty_shared", "fig4_gradle", "fig4_wiki",
    "fig7_num_caches", "hetero_tiers", "staggered_adverts", "delayed_view",
    "advert_budget", "advert_delta",
    "exhaustive_small", "heavy_skew", "trace_file_smoke",
    "topo_path", "topo_tree",
)
