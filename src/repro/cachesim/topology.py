"""Hierarchical cache topologies: composable tier nodes (paper Sec. V
system, recursed icarus-style over PATH and TREE hierarchies).

The flat simulator models ONE hop: a client in front of n parallel
caches with indicators.  Real indicator deployments are hierarchies —
an edge tier's misses recurse to a parent tier with its own indicator
staleness and false-negative regime (ROADMAP item 3; networks-of-caches
per arXiv:1202.4880).  This module composes the UNCHANGED one-hop
engine into such hierarchies:

  * a :class:`TierSystem` is one hop — n caches + indicators + advert
    policies + a decision provider, i.e. exactly the system the flat
    engine simulates, plus the per-tier knobs of :class:`TierSpec`
    (hop penalty, service latency, admission queue);
  * a :class:`TopoConfig` arranges tier nodes into a PATH (depth d
    chains of single nodes) or a TREE (``fanout`` children per parent,
    leaves at level 0, root at level ``depth - 1``);
  * a miss at depth d re-enters the identical engine at depth d + 1:
    the parent's arrival stream is the merge (in trace order) of its
    children's residency-miss subsequences, and the parent node runs
    the same phase-1 sweep / decision plan / replay stack on it.

RESIDENCY-DRIVEN RECURSION.  Hash-designated placement means a key can
only reside in its designated cache, so "miss at tier d" — not resident
in the designated cache — is a property of the SYSTEM evolution, never
of the policy under test.  Consequently every tier's arrival stream,
and with it every tier's :class:`~repro.cachesim.systemstate.
SystemTrace`, is policy-independent: the fair-comparison property of
the flat engine survives composition, one sweep per tier node serves
the whole policy panel, and the per-tier sweeps are content-addressed
in the :class:`~repro.cachesim.store.ArtifactStore` (schema v3) under
(tier arrival stream digest, tier system key) — reusable across
topology cells and even across DEPTHS, because tier d's stream does not
depend on how many tiers sit behind it.

ACCOUNTING (identical code for both engines; the per-request
observables come from the fast sweep + decision plans or from the
recording reference loop):

  * cost   = sum of probe costs at every visited tier (admitted
    arrivals only) + ``hop_penalty[d]`` for every d -> d+1 forward +
    ``origin_penalty`` when no visited tier served the request.  A
    depth-1 path with zero hop knobs degenerates BIT-IDENTICALLY to the
    flat engine's ``probe + miss_penalty`` accounting (the empty
    selection costs exactly ``0.0`` and ``0.0 + M == M``; the scalar
    fold order is the flat engine's trace order).
  * latency = sum of ``tier_latency[d]`` over visited tiers +
    ``origin_latency`` when unserved (kept separate from cost — the
    mean-latency metric of the topo scenario family).
  * rejection: a deterministic admission window per tier
    (``queue_capacity`` admitted out of every ``queue_window``
    arrivals; 0 disables).  Rejected arrivals probe nothing and cannot
    be served by that tier, but the SYSTEM evolution and the forwarding
    stream stay residency-driven — the queue is a service-time overlay,
    so sweeps remain shareable and policy-independent.

Engine parity: the fast path replays each tier through
``DecisionPlan.selections`` and the reference path records the same
per-request observables from the oracle loop
(``Simulator._run_reference(record=...)``); tier-by-tier selection
parity is exactly the flat engines' bit-exactness, so topology results
are pinned fast == reference in the golden suite
(``topo_path`` / ``topo_tree`` scenarios).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.cachesim.simulator import SimConfig, SimResult, Simulator
from repro.cachesim.systemstate import SystemTrace

#: per-tier knob names a tier mapping may carry besides SimConfig fields
TIER_KEYS = ("hop_penalty", "tier_latency", "queue_capacity",
             "queue_window")

_QUALITY_KEYS = ("fn_events", "fn_opportunities", "fp_events",
                 "fp_opportunities", "resident")

#: multiplicative-hash constant for leaf assignment (golden ratio);
#: deliberately unrelated to the designated-cache ``key % n`` hash
_EDGE_HASH = np.uint64(0x9E3779B97F4A7C15)

_EMPTY_POS = np.empty(0, dtype=np.int64)


def edge_assignment(keys: np.ndarray, n_leaves: int) -> np.ndarray:
    """Deterministic leaf index per request key for TREE topologies —
    a multiplicative hash, independent of the in-tier designated-cache
    hash so leaf routing does not correlate with cache designation."""
    h = np.asarray(keys, np.uint64) * _EDGE_HASH
    return ((h >> np.uint64(33)) % np.uint64(n_leaves)).astype(np.int64)


@dataclass(frozen=True)
class TierSpec:
    """The per-tier knobs that live OUTSIDE the one-hop system: what a
    visit costs beyond the probes, and whether the tier admits the
    arrival at all."""
    hop_penalty: float = 0.0     # cost of forwarding from this tier to
    #                              the next (applied to residency misses
    #                              of every non-last tier)
    tier_latency: float = 0.0    # service latency per visit (latency
    #                              metric only — never enters cost)
    queue_capacity: int = 0      # arrivals admitted per window; 0 = off
    queue_window: int = 0        # admission window length; 0 = off

    def admitted(self, m: int) -> np.ndarray:
        """[m] bool admission mask over a tier's arrival sequence: the
        first ``queue_capacity`` of every ``queue_window`` consecutive
        arrivals are admitted — deterministic and policy-independent,
        so the overlay never splits sweep sharing."""
        if self.queue_capacity <= 0 or self.queue_window <= 0 or \
                self.queue_capacity >= self.queue_window:
            return np.ones(m, dtype=bool)
        return (np.arange(m, dtype=np.int64) % self.queue_window) \
            < self.queue_capacity


@dataclass(frozen=True)
class TopoConfig:
    """A PATH or TREE of tier nodes over one base :class:`SimConfig`.

    ``tiers`` holds one mapping per depth (missing / extra entries are
    fine — deeper-than-``depth`` specs are simply unused, so a depth
    axis can sweep below a fully specified tier list).  Each mapping
    mixes SimConfig overrides (per-tier cache sizes, advertisement
    cadences, ...) with the :data:`TIER_KEYS` knobs of
    :class:`TierSpec`.  ``origin_penalty`` defaults to the base config's
    ``miss_penalty`` — which is what makes depth 1 with zero hop knobs
    the flat engine, bit for bit."""
    base: SimConfig
    kind: str = "path"                   # path | tree
    depth: int = 1
    fanout: int = 2                      # children per parent (tree)
    tiers: Tuple[Mapping, ...] = ()      # per-depth overrides + knobs
    origin_penalty: Optional[float] = None   # None -> base.miss_penalty
    origin_latency: float = 0.0

    def __post_init__(self):
        if self.kind not in ("path", "tree"):
            raise ValueError(f"unknown topology kind {self.kind!r}")
        if not isinstance(self.depth, int) or self.depth < 1:
            raise ValueError(f"depth must be an int >= 1, got "
                             f"{self.depth!r}")
        if self.kind == "tree" and (not isinstance(self.fanout, int)
                                    or self.fanout < 1):
            raise ValueError(f"fanout must be an int >= 1, got "
                             f"{self.fanout!r}")
        object.__setattr__(self, "tiers",
                           tuple(dict(t) for t in self.tiers))
        sim_fields = set(SimConfig.__dataclass_fields__)
        for d, t in enumerate(self.tiers):
            bad = [k for k in t if k not in sim_fields
                   and k not in TIER_KEYS]
            if bad:
                raise ValueError(
                    f"tier {d} override {bad[0]!r} is neither a "
                    f"SimConfig field nor a tier knob {TIER_KEYS}")

    # -- composition geometry ---------------------------------------------

    @property
    def seed(self) -> int:
        """The base seed (``run_grid`` trace generation reads it)."""
        return self.base.seed

    def level_width(self, d: int) -> int:
        """Node count at depth ``d``: 1 on a path; ``fanout^(depth-1-d)``
        on a tree (leaves at 0, root at ``depth - 1``)."""
        if self.kind == "path":
            return 1
        return self.fanout ** (self.depth - 1 - d)

    def tier_mapping(self, d: int) -> Mapping:
        return self.tiers[d] if d < len(self.tiers) else {}

    def tier_spec(self, d: int) -> TierSpec:
        t = self.tier_mapping(d)
        return TierSpec(
            hop_penalty=float(t.get("hop_penalty", 0.0)),
            tier_latency=float(t.get("tier_latency", 0.0)),
            queue_capacity=int(t.get("queue_capacity", 0)),
            queue_window=int(t.get("queue_window", 0)))

    def node_config(self, d: int, i: int = 0) -> SimConfig:
        """The SimConfig of node ``i`` at depth ``d``: base + tier
        overrides + a node-unique seed offset (zero at the (0, 0) node,
        so a depth-1 path IS the flat system)."""
        over = {k: v for k, v in self.tier_mapping(d).items()
                if k not in TIER_KEYS}
        cfg = dataclasses.replace(self.base, **over) if over else self.base
        off = d * 1_000_003 + i * 7_919
        return dataclasses.replace(cfg, seed=cfg.seed + off) if off else cfg

    def origin_penalty_value(self) -> float:
        return float(self.base.miss_penalty
                     if self.origin_penalty is None
                     else self.origin_penalty)


#: axis-override keys routed to the TopoConfig itself (vs tiers / base)
_TOPO_FIELDS = frozenset(
    k for k in TopoConfig.__dataclass_fields__ if k != "base")


def topo_cell(base: TopoConfig, overrides: Mapping) -> TopoConfig:
    """Apply one grid cell's overrides to a topology config, routing
    each key by kind: TopoConfig fields (``depth``, ``fanout``,
    ``origin_penalty``, ...) replace on the topology; :data:`TIER_KEYS`
    broadcast into every tier mapping (a scalar) or distribute per tier
    (a sequence of length ``depth``); anything else is a SimConfig
    override on the shared base — propagating to every tier that does
    not itself override the same field."""
    topo_kw, tier_kw, sim_kw = {}, {}, {}
    for k, v in overrides.items():
        if k in _TOPO_FIELDS:
            topo_kw[k] = v
        elif k in TIER_KEYS:
            tier_kw[k] = v
        else:
            sim_kw[k] = v
    out = base
    if sim_kw:
        out = dataclasses.replace(
            out, base=dataclasses.replace(out.base, **sim_kw))
    if topo_kw:
        out = dataclasses.replace(out, **topo_kw)
    if tier_kw:
        depth = out.depth
        tiers = [dict(out.tier_mapping(d)) for d in range(depth)]
        for k, v in tier_kw.items():
            if isinstance(v, (list, tuple)):
                if len(v) != depth:
                    raise ValueError(
                        f"per-tier override {k}={v!r} has length "
                        f"{len(v)}, expected depth={depth}")
                for d in range(depth):
                    tiers[d][k] = v[d]
            else:
                for d in range(depth):
                    tiers[d][k] = v
        out = dataclasses.replace(out, tiers=tuple(tiers))
    return out


# ---------------------------------------------------------------------------
# One hop
# ---------------------------------------------------------------------------

class TierSystem:
    """One hop of a hierarchy: the flat engine's system (n caches +
    indicators + advert policies) plus its decision provider, behind the
    two calls composition needs — a policy-independent sweep of the
    tier's arrival stream, and per-policy selection bitmasks against it.
    A depth-1 path holds exactly one of these, configured identically to
    the flat simulator."""

    def __init__(self, cfg: SimConfig, spec: TierSpec,
                 depth: int = 0, index: int = 0):
        self.cfg = cfg
        self.spec = spec
        self.depth = depth
        self.index = index

    @property
    def costs(self) -> list:
        return [float(c) for c in self.cfg.costs]

    def sweep(self, keys: np.ndarray,
              chunk_size: Optional[int] = None) -> SystemTrace:
        """Phase 1 for this tier: the flat sweep over the tier's own
        arrival stream (callers normally go through :class:`SweepPool`
        for in-memory + store-backed reuse)."""
        return SystemTrace.compute(Simulator(self.cfg),
                                   np.asarray(keys, np.uint64),
                                   chunk_size=chunk_size)

    def selections(self, st: SystemTrace, policy: str) -> np.ndarray:
        """[m] committed per-arrival selection bitmasks for ``policy``
        at this tier (fast engine): the decision-plan registry of
        ``repro.cachesim.engine``, or — beyond every plan's table budget
        — the recording reference loop on the same stream."""
        from repro.cachesim.engine import plan_for
        pcfg = dataclasses.replace(self.cfg, policy=policy)
        plan = plan_for(pcfg)
        if plan is None:
            rec, _ = self.reference_run(st._trace, policy)
            return rec["selm"]
        return plan.selections(Simulator(pcfg), st)

    def reference_run(self, keys: np.ndarray,
                      policy: str) -> Tuple[dict, SimResult]:
        """The oracle loop on this tier's stream, recording the
        per-arrival observables the topology accounting consumes."""
        pcfg = dataclasses.replace(self.cfg, policy=policy,
                                   engine="reference")
        rec: dict = {}
        res = SimResult(policy=policy)
        Simulator(pcfg)._run_reference(np.asarray(keys, np.uint64), res,
                                       record=rec)
        return rec, res


class SweepPool:
    """Cross-cell reuse of per-tier sweeps AND per-(tier, policy)
    selections, keyed by (arrival-stream digest, system key) — the same
    content addressing as the :class:`~repro.cachesim.store.
    ArtifactStore`, which backs the pool when given.  One pool shared
    across a topology grid's cells realises the cross-tier sweep
    sharing: a depth axis recomputes nothing for the tiers it already
    visited at smaller depths, and decision-side topology axes (hop
    penalties, origin penalty, queues) reuse both sweeps and
    selections."""

    def __init__(self, store=None, chunk_size: Optional[int] = None):
        from repro.cachesim.store import as_store
        self.store = as_store(store)
        self.chunk_size = chunk_size
        self._sweeps: Dict[tuple, SystemTrace] = {}
        self._selm: Dict[tuple, np.ndarray] = {}

    def sweep(self, tier: TierSystem, keys: np.ndarray,
              ) -> Optional[SystemTrace]:
        """The tier's SystemTrace over ``keys`` (None for an empty
        stream): in-memory first, then the store, then computed (and
        persisted when a store is attached)."""
        from repro.cachesim.store import ArtifactStore
        keys = np.ascontiguousarray(keys, np.uint64)
        if keys.shape[0] == 0:
            return None
        digest = ArtifactStore.trace_digest(keys)
        k = (digest, SystemTrace.system_key(tier.cfg))
        st = self._sweeps.get(k)
        if st is None and self.store is not None:
            st = self.store.load_sweep(keys, k[1], trace_digest=digest)
        if st is None:
            st = tier.sweep(keys, chunk_size=self.chunk_size)
            if self.store is not None:
                self.store.save_sweep(st, trace_digest=digest)
        self._sweeps[k] = st
        return st

    def selections(self, tier: TierSystem, st: SystemTrace,
                   policy: str) -> np.ndarray:
        """Memoised :meth:`TierSystem.selections` — the decision-side
        key covers everything a plan's output depends on, so topology
        axes that only move hop/queue/origin knobs replay for free."""
        cfg = tier.cfg
        key = (id(st), policy, cfg.alg,
               tuple(float(c) for c in cfg.costs),
               float(cfg.miss_penalty), float(cfg.cal_gamma),
               int(cfg.cal_min_obs), float(cfg.cal_epsilon),
               int(cfg.seed))
        selm = self._selm.get(key)
        if selm is None:
            selm = tier.selections(st, policy)
            self._selm[key] = selm
        return selm


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------

@dataclass
class TopoResult:
    """Per-policy result of one topology run.  The first eleven fields
    mirror :class:`~repro.cachesim.simulator.SimResult` (and equal it
    bit-for-bit on a knob-free depth-1 path); the rest are the
    hierarchy metrics.  Per-level fields are plain lists so the golden
    JSON round-trip compares equal."""
    policy: str
    n_requests: int = 0
    total_cost: float = 0.0
    hits: int = 0
    pos_accesses: int = 0
    neg_accesses: int = 0
    fn_events: int = 0
    fn_opportunities: int = 0
    fp_events: int = 0
    fp_opportunities: int = 0
    resident: int = 0
    total_latency: float = 0.0
    rejected: int = 0
    origin_fetches: int = 0
    tier_arrivals: List[int] = field(default_factory=list)
    tier_hits: List[int] = field(default_factory=list)
    tier_rejected: List[int] = field(default_factory=list)

    @property
    def mean_cost(self) -> float:
        return self.total_cost / max(self.n_requests, 1)

    @property
    def hit_ratio(self) -> float:
        return self.hits / max(self.n_requests, 1)

    @property
    def fn_ratio(self) -> float:
        return self.fn_events / max(self.fn_opportunities, 1)

    @property
    def fp_ratio(self) -> float:
        return self.fp_events / max(self.fp_opportunities, 1)

    @property
    def mean_latency(self) -> float:
        return self.total_latency / max(self.n_requests, 1)

    @property
    def rejection_rate(self) -> float:
        """Fraction of tier arrivals the admission queues rejected."""
        return self.rejected / max(sum(self.tier_arrivals), 1)

    def to_dict(self) -> Dict:
        return {
            "policy": self.policy, "n": self.n_requests,
            "mean_cost": round(self.mean_cost, 4),
            "hit_ratio": round(self.hit_ratio, 4),
            "fn_ratio": round(self.fn_ratio, 5),
            "fp_ratio": round(self.fp_ratio, 5),
            "pos_accesses": self.pos_accesses,
            "neg_accesses": self.neg_accesses,
            "mean_latency": round(self.mean_latency, 4),
            "rejection_rate": round(self.rejection_rate, 5),
            "origin_fetches": self.origin_fetches,
        }


# ---------------------------------------------------------------------------
# Composition + accounting
# ---------------------------------------------------------------------------

def _edge_streams(topo: TopoConfig, trace: np.ndarray) -> List[np.ndarray]:
    """Level-0 arrival positions per node (trace positions)."""
    n0 = topo.level_width(0)
    if n0 == 1:
        return [np.arange(trace.shape[0], dtype=np.int64)]
    leaf = edge_assignment(trace, n0)
    return [np.flatnonzero(leaf == e).astype(np.int64) for e in range(n0)]


def _merge_to_parents(miss_pos: List[np.ndarray],
                      group: int) -> List[np.ndarray]:
    """Parent arrival positions: each parent receives the merge — in
    original trace order — of its ``group`` children's residency-miss
    subsequences."""
    out = []
    for p in range(len(miss_pos) // group):
        parts = miss_pos[p * group:(p + 1) * group]
        out.append(np.sort(np.concatenate(parts)) if group > 1
                   else parts[0])
    return out


def _advert_totals(st: SystemTrace) -> Tuple[int, float]:
    nodes = st.final_state["nodes"]
    return (sum(len(nd["adv_ins"]) for nd in nodes),
            sum(b for nd in nodes for b in nd["adv_bytes"]))


def _accumulate_topology(topo: TopoConfig, n_client: int, policy: str,
                         node_rows: List[dict]) -> TopoResult:
    """Fold per-tier observables into a :class:`TopoResult` — the ONE
    accounting implementation both engines share.  ``node_rows`` carry,
    per non-empty node: depth, trace positions, selection bitmasks,
    designated-cache residency/index, indication patterns, probe costs,
    sweep quality counters and advert totals."""
    res = TopoResult(policy=policy, n_requests=n_client)
    depth = topo.depth
    res.tier_arrivals = [0] * depth
    res.tier_hits = [0] * depth
    res.tier_rejected = [0] * depth
    cost = np.zeros(n_client, np.float64)
    lat = np.zeros(n_client, np.float64)
    served = np.zeros(n_client, dtype=bool)
    adv_events, adv_bytes = 0, 0.0
    for row in node_rows:
        d = row["depth"]
        spec = topo.tier_spec(d)
        pos = row["pos"]
        m = int(pos.shape[0])
        if m == 0:
            continue
        selm, in_dj, dj, pats = (row["selm"], row["in_dj"], row["dj"],
                                 row["pats"])
        costs = row["costs"]
        n = len(costs)
        k = 1 << n
        acc_by_mask = np.asarray(
            [sum(costs[j] for j in range(n) if (mk >> j) & 1)
             for mk in range(k)], np.float64)
        popcount = np.asarray([bin(mk).count("1") for mk in range(k)],
                              np.int64)
        admitted = spec.admitted(m)
        sel_eff = np.where(admitted, selm, np.int64(0))
        res.tier_arrivals[d] += m
        n_rej = m - int(np.count_nonzero(admitted))
        res.tier_rejected[d] += n_rej
        res.rejected += n_rej
        cost[pos] += acc_by_mask[sel_eff]
        if spec.tier_latency:
            lat[pos] += spec.tier_latency
        hit = admitted & in_dj & (((sel_eff >> dj) & 1) != 0)
        served[pos[hit]] = True
        nh = int(np.count_nonzero(hit))
        res.tier_hits[d] += nh
        res.hits += nh
        pos_acc = int(popcount[sel_eff & pats].sum())
        res.pos_accesses += pos_acc
        res.neg_accesses += int(popcount[sel_eff].sum()) - pos_acc
        if d + 1 < depth and spec.hop_penalty:
            cost[pos[~in_dj]] += spec.hop_penalty
        for q in _QUALITY_KEYS:
            setattr(res, q, getattr(res, q) + row["quality"][q])
        adv_events += row["advert"][0]
        adv_bytes += row["advert"][1]
    unserved = ~served
    res.origin_fetches = int(np.count_nonzero(unserved))
    cost[unserved] += topo.origin_penalty_value()
    if topo.origin_latency:
        lat[unserved] += topo.origin_latency
    # scalar folds in trace order: bit-exact across engines, and — on a
    # knob-free depth-1 path — identical to the flat engine's fold
    total = 0.0
    for c in cost.tolist():
        total += c
    res.total_cost = total
    total = 0.0
    for c in lat.tolist():
        total += c
    res.total_latency = total
    # advert totals ride as plain attributes, mirroring SimResult
    res.advert_events = adv_events
    res.advert_bytes = adv_bytes
    return res


def _grow_levels(topo: TopoConfig, trace: np.ndarray, pool: SweepPool):
    """Fast-engine composition: sweep every tier node level by level,
    deriving each parent stream from its children's (policy-independent)
    residency misses.  Returns ``[[(tier, pos, st or None)]]``."""
    levels = []
    cur = _edge_streams(topo, trace)
    for d in range(topo.depth):
        row = []
        for i, pos in enumerate(cur):
            tier = TierSystem(topo.node_config(d, i), topo.tier_spec(d),
                              depth=d, index=i)
            st = pool.sweep(tier, trace[pos]) if pos.shape[0] else None
            row.append((tier, pos, st))
        levels.append(row)
        if d + 1 < topo.depth:
            miss = [pos[st.forward_positions()] if st is not None
                    else _EMPTY_POS for _, pos, st in row]
            cur = _merge_to_parents(
                miss, topo.fanout if topo.kind == "tree" else 1)
    return levels


def run_topology(trace: np.ndarray, topo: TopoConfig,
                 policies: Sequence[str] = ("fna", "fna_cal", "fno", "pi"),
                 *, store=None, chunk_size: Optional[int] = None,
                 pool: Optional[SweepPool] = None,
                 ) -> Dict[str, TopoResult]:
    """Run a policy panel over one topology cell; returns
    ``{policy: TopoResult}``.

    ``topo.base.engine`` selects the per-tier engine: ``"fast"`` sweeps
    each tier once (via ``pool`` — pass one shared pool to amortise
    across cells, or let a fresh call-scoped pool back onto ``store``)
    and replays every policy through ``DecisionPlan.selections``;
    ``"reference"`` runs the recording oracle loop per (tier, policy).
    Both feed the same accounting, so results are bit-identical."""
    trace = np.ascontiguousarray(trace, np.uint64)
    N = int(trace.shape[0])
    engine = topo.base.engine
    if engine not in ("fast", "reference"):
        raise ValueError(f"unknown engine {engine!r}")
    out: Dict[str, TopoResult] = {}
    if engine == "fast":
        if pool is None:
            pool = SweepPool(store, chunk_size)
        levels = _grow_levels(topo, trace, pool)
        for policy in policies:
            rows = []
            for d, row in enumerate(levels):
                for tier, pos, st in row:
                    if st is None:
                        continue
                    rows.append({
                        "depth": d, "pos": pos,
                        "selm": pool.selections(tier, st, policy),
                        "in_dj": st.in_dj, "dj": st.dj_all,
                        "pats": st.pats, "costs": tier.costs,
                        "quality": st.quality,
                        "advert": _advert_totals(st)})
            out[policy] = _accumulate_topology(topo, N, policy, rows)
        return out
    for policy in policies:
        rows = []
        cur = _edge_streams(topo, trace)
        for d in range(topo.depth):
            miss = []
            for i, pos in enumerate(cur):
                if pos.shape[0] == 0:
                    miss.append(_EMPTY_POS)
                    continue
                tier = TierSystem(topo.node_config(d, i),
                                  topo.tier_spec(d), depth=d, index=i)
                rec, rres = tier.reference_run(trace[pos], policy)
                rows.append({
                    "depth": d, "pos": pos, "selm": rec["selm"],
                    "in_dj": rec["in_dj"], "dj": rec["dj"],
                    "pats": rec["pats"], "costs": tier.costs,
                    "quality": {q: getattr(rres, q)
                                for q in _QUALITY_KEYS},
                    "advert": (rres.advert_events, rres.advert_bytes)})
                miss.append(pos[~rec["in_dj"]])
            if d + 1 < topo.depth:
                cur = _merge_to_parents(
                    miss, topo.fanout if topo.kind == "tree" else 1)
        out[policy] = _accumulate_topology(topo, N, policy, rows)
    return out


def run_topo_grid(traces: Mapping[str, np.ndarray], base: TopoConfig,
                  axis: str, values: Sequence,
                  policies: Sequence[str] = ("fna", "fna_cal", "fno",
                                             "pi"),
                  share_system: bool = True, store=None,
                  chunk_size: Optional[int] = None,
                  ) -> Dict[tuple, Dict[str, TopoResult]]:
    """Topology grids for ``run_grid``: sweep a topology axis (``depth``,
    ``fanout``, per-tier ``hop_penalty``/``tier_latency``/queue knobs,
    ``origin_penalty``) or any SimConfig field (broadcast through the
    base into every tier), returning ``{(trace, label): {policy:
    TopoResult}}`` in the caller's cell order.

    ``share_system=True`` shares ONE :class:`SweepPool` (backed by
    ``store`` when given) per trace across all cells: tier sweeps — and,
    for decision-side topology axes, per-tier selections — are computed
    once per distinct (stream, system key) no matter how many cells or
    depths consume them.  ``share_system=False`` gives every cell a
    fresh, store-less pool (benchmarking the amortisation itself).  The
    reference engine always runs the full per-tier oracle loops."""
    from repro.cachesim.sweep import cell_label, cell_overrides
    out: Dict[tuple, Dict[str, TopoResult]] = {}
    for name, trace in traces.items():
        pool = (SweepPool(store, chunk_size)
                if share_system and base.base.engine == "fast" else None)
        for value in values:
            key = (name, cell_label(axis, value))
            if key in out:
                raise ValueError(
                    f"duplicate grid cell {key!r}: two axis values "
                    f"share the label {key[1]!r}")
            topo = topo_cell(base, cell_overrides(axis, value))
            out[key] = run_topology(
                trace, topo, policies,
                store=store if share_system else None,
                chunk_size=chunk_size, pool=pool)
    return out
