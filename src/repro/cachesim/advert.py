"""Advertisement-event subsystem: when a cache advertises, and what it
costs on the wire (ROADMAP item 2; arXiv:2104.01386 / arXiv:2405.17801).

The paper models advertisement as a fixed per-cache insertion cadence
(``update_interval``).  The follow-up papers make it a budgeted,
adaptive resource: a cache decides *when* to advertise (on measured
staleness drift, within a bandwidth budget) and *what* (full indicator
vs delta).  This module is the single shared implementation of those
decisions — both engines call the SAME functions at the SAME system
state, which is what makes the reference loop and the fast engine's
event walk bit-exact twins on every advert policy:

``periodic``
    The paper's fixed cadence, unchanged: advertise after
    ``update_interval`` insertions, transmitting the full ``m``-bit
    bitmap.  The pre-existing behaviour is a strict special case of the
    event subsystem (golden files reproduce byte-identically).

``delta``
    Same cadence, delta transmission: the wire cost is the measured
    changed-bit encoding (changed positions x ceil(log2 m) bits) capped
    at the full bitmap — the ``what`` axis of arXiv:2405.17801.  System
    evolution is identical to ``periodic``; only bytes-on-wire differ.

``self_adjusting``
    Drift-triggered advertisement under a token-bucket bandwidth budget
    (arXiv:2104.01386).  Every ``advert_check`` insertions the cache
    refills its bucket (``advert_bandwidth`` bytes per insertion, capped
    at ``advert_burst``) and advertises iff the Eq. (7) false-negative
    prediction from the live (updated, stale) bitmap pair has crossed
    ``advert_threshold`` AND the bucket covers a full advertisement.
    ``update_interval`` does not trigger adverts in this mode.

Every advertisement is recorded as an event ``(insertion ordinal,
bytes)`` on the cache node; :class:`~repro.cachesim.systemstate.
SystemTrace` snapshots the per-cache event streams so stored sweeps
carry them, and the sweep records expose the totals per run.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import numpy as np

#: the pluggable policy family (``SimConfig.advert_policy``)
ADVERT_POLICIES = ("periodic", "delta", "self_adjusting")


def full_advert_bytes(ind) -> float:
    """Wire cost of a full bitmap advertisement: ``m`` bits."""
    return ind.cbf.m / 8.0


def delta_advert_bytes(ind) -> float:
    """Measured delta-encoding cost of advertising NOW: the bits that
    changed since the last advertisement, each as a ceil(log2 m)-bit
    position, capped at the full bitmap (the receiver can always be sent
    the whole thing instead).  Must be called BEFORE ``advertise()`` —
    it reads the (updated, stale) pair."""
    updated = ind.cbf.to_bitmap()
    changed = int(np.count_nonzero(updated != ind.stale))
    pos_bits = max(1, math.ceil(math.log2(max(ind.cbf.m, 2))))
    return min(full_advert_bytes(ind), changed * pos_bits / 8.0)


def advert_cost(ind, policy: str) -> float:
    """Wire cost of the advertisement a ``periodic``/``delta`` cache is
    about to make (before ``advertise()``)."""
    if policy == "delta":
        return delta_advert_bytes(ind)
    return full_advert_bytes(ind)


def predicted_fn(ind) -> float:
    """Eq. (7) false-negative prediction from the live (updated, stale)
    bitmap pair, WITHOUT mutating ``fp_est``/``fn_est`` — the drift
    signal of the self-adjusting policy.  Identical arithmetic to
    ``StaleIndicatorPair.estimate_rates``."""
    updated = ind.cbf.to_bitmap()
    b1 = int(np.count_nonzero(updated))
    if b1 == 0:
        return 0.0
    d1 = int(np.count_nonzero(updated & ~ind.stale))
    return 1.0 - ((b1 - d1) / b1) ** ind.cbf.k


def refill(tokens: float, burst: float, bandwidth: float,
           elapsed: int) -> float:
    """Token-bucket refill after ``elapsed`` insertions (both engines
    refill in the same check-boundary jumps, so the float arithmetic —
    one multiply-add and one min per boundary — is identical)."""
    return min(burst, tokens + bandwidth * elapsed)


def self_adjusting_decision(ind, tokens: float,
                            threshold: float) -> Optional[float]:
    """The drift/budget gate: the cost of the advertisement to make now,
    or None to stay silent.  Advertise iff predicted FN drift crossed
    ``threshold`` and the bucket covers a full advertisement."""
    cost = full_advert_bytes(ind)
    if predicted_fn(ind) >= threshold and tokens >= cost:
        return cost
    return None


def resolve_advert(cfg) -> Tuple[tuple, ...]:
    """The canonical per-cache advert spec — one ``(policy, bandwidth,
    burst bytes, threshold, check interval)`` tuple per cache, defaults
    resolved (burst 0 -> one full advertisement; check 0 -> the cache's
    ``est_interval``).  This is the ``system_key`` component: a scalar
    and its broadcast sequence resolve identically, and knobs a policy
    does not read are zeroed so they cannot split sweep-sharing groups
    (a ``periodic`` cache's evolution ignores the budget fields)."""
    out = []
    pols = cfg.advert_policies
    bws, bursts = cfg.advert_bandwidths, cfg.advert_bursts
    ths, chks = cfg.advert_thresholds, cfg.advert_checks
    for j in range(cfg.n_caches):
        pol = pols[j]
        if pol == "self_adjusting":
            m = int(cfg.bpes[j] * cfg.cache_sizes[j])
            burst = bursts[j] if bursts[j] > 0 else m / 8.0
            chk = chks[j] if chks[j] > 0 else cfg.est_intervals[j]
            out.append((pol, float(bws[j]), float(burst),
                        float(ths[j]), int(chk)))
        else:
            out.append((pol, 0.0, 0.0, 0.0, 0))
    return tuple(out)
