"""Policy-independent system-state sweep (phase 1 of the fast engine).

The simulator's SYSTEM state — LRU contents, CBF counters, stale bitmaps,
FP/FN estimates (Eqs. 7-8), q-estimates (Eq. 9) — evolves independently of
any policy's access decisions: the controller places every missed request
in its hash-designated cache, so cache dynamics are identical across
policies by construction (paper Sec. V-A, the fair-comparison property).

:class:`SystemTrace` materialises one full sweep of that evolution for a
given (trace, system config) pair:

  * per-request arrays: the n-bit indication pattern of every request
    against the advertisement-frozen bitmaps (invariant I1), designated-
    cache membership, and the designated cache id;
  * the complete client-side view-version history — every (pi, nu) view
    the reference loop's ``_refresh_views`` would compute, PLUS the raw
    (fp, fn) estimates behind it (the calibrated policy's uninformative-
    indicator test reads those directly), with the first request index at
    which each version takes effect (invariant I2);
  * the designated-cache indicator-quality counters (Fig. 1 measurement);
  * a snapshot of the end-of-run system state, so a simulator that skips
    the sweep still finishes in exactly the state a full run would leave.

Because none of this depends on the policy, a policy x trace sweep pays
for ONE system sweep and reuses it for every policy: ``run_policies`` and
``repro.cachesim.sweep`` hand the artifact of the first fast run to every
subsequent simulator, which then only executes the cheap per-policy
table/replay phases (``repro.cachesim.fastpath``,
``repro.cachesim.fna_cal_fast``).

``SWEEPS_COMPUTED`` counts :meth:`SystemTrace.compute` calls — tests use
it to prove a multi-policy run performed exactly one sweep.
"""
from __future__ import annotations

import itertools
import os
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import hash_indices
from repro.cachesim.advert import (advert_cost, refill, resolve_advert,
                                   self_adjusting_decision)

# incremented on every full system sweep (amortisation observability)
SWEEPS_COMPUTED = 0

#: the fixed field order quality counters serialise under (store schema)
_QUALITY_KEYS = ("fn_events", "fn_opportunities", "fp_events",
                 "fp_opportunities", "resident")


def _dedup_rows(rows: np.ndarray) -> np.ndarray:
    """Unique indices per row, flattened.  The reference CBF update uses
    fancy-index assignment, so duplicate probe indices within one key must
    count once."""
    s = np.sort(rows, axis=1)
    keep = np.ones(s.shape, dtype=bool)
    keep[:, 1:] = s[:, 1:] != s[:, :-1]
    return s[keep]


def _lru_sweep(lru, trace: np.ndarray, pos: np.ndarray):
    """Advance one cache's LRU through its designated subsequence.

    Returns (membership-before-put per request, global positions of the
    requests that inserted, evicted keys, insert index of each eviction).
    Identical ops on the same OrderedDict as ``LRUCache.put`` would do.
    """
    d = lru._d
    cap = lru.capacity
    keys = trace[pos].tolist()
    mem: List[bool] = []
    ins_local: List[int] = []
    evict_keys: List[int] = []
    evict_iidx: List[int] = []
    mem_append = mem.append
    move_to_end = d.move_to_end
    popitem = d.popitem
    ins_append = ins_local.append
    for li, x in enumerate(keys):
        if x in d:
            move_to_end(x)
            mem_append(True)
        else:
            mem_append(False)
            if len(d) >= cap:
                ev, _ = popitem(False)
                evict_keys.append(ev)
                evict_iidx.append(len(ins_local))
            d[x] = None
            ins_append(li)
    ins_gpos = pos[np.asarray(ins_local, dtype=np.int64)] if ins_local \
        else np.empty(0, np.int64)
    return (np.asarray(mem, dtype=bool), ins_gpos, evict_keys,
            np.asarray(evict_iidx, dtype=np.int64))


def _cbf_event_walk(nd, j: int, idx_j: np.ndarray, ins_gpos: np.ndarray,
                    evict_keys, evict_iidx: np.ndarray,
                    ind_all: np.ndarray, est_events: List[Tuple], N: int,
                    *, base: int = 0, cnt=None, finalize: bool = True):
    """Jump from one estimate/advertise/drift-check boundary to the next
    (no per-request work): bulk-apply the window's CBF updates, fire the
    same ``estimate_rates``/``advertise``/token-bucket calls the reference
    ``insert`` would, fill this cache's indication column per
    advertisement segment, record (effective request index, fp, fn) for
    every version bump, and append the cache's advert events ``(absolute
    insertion ordinal, bytes)`` exactly as the reference loop does.

    Under ``periodic``/``delta`` advertisements fire on the fixed
    ``update_interval`` grid; under ``self_adjusting`` the cadence grid is
    the drift-check interval instead (``update_interval`` never fires) and
    an advertisement happens only when the shared
    :func:`~repro.cachesim.advert.self_adjusting_decision` gate opens —
    called at the identical system state and token balance as the
    reference loop, so the engines stay bit-exact twins.

    Chunked phase 1 calls this once per (chunk, cache) with LOCAL arrays:
    ``base`` is the chunk's global request offset (recorded-event indices
    are globalised), ``cnt`` carries the working int32 counter array from
    the previous chunk, and ``finalize=False`` defers the one uint8 clip
    to the trace end — exactly where the one-shot walk performs it.  The
    cadence/token carries (``nd._since_*``, ``nd.adv_tokens``,
    ``nd._n_ins``) are reconstructed at every call's end either way, so a
    chunk boundary is indistinguishable from a walk entry.  Returns the
    working counter array for the next chunk's carry."""
    cbf = nd.ind.cbf
    if cnt is None:
        cnt = cbf.counters.astype(np.int32)
    cbf.counters = cnt              # estimate/advertise read through cbf
    ins_rows = idx_j[ins_gpos]
    ev_rows = hash_indices(np.asarray(evict_keys, dtype=np.uint64),
                           cbf.k, cbf.m, cbf.seed) if evict_keys else None
    n_ins = int(ins_gpos.shape[0])
    seg_start = 0                   # indication segment start (request idx)
    cur = 0                         # inserts flushed so far
    ev_ptr = 0
    self_adj = nd.adv_policy == "self_adjusting"
    next_est = nd.est_interval - nd._since_est
    # the inactive cadence gets an out-of-range sentinel so it never fires
    next_adv = (nd.update_interval - nd._since_adv) if not self_adj \
        else n_ins + 1
    next_chk = (nd.check_interval - nd._since_chk) if self_adj \
        else n_ins + 1
    last_adv = -nd._since_adv       # self_adjusting staleness origin
    n_ins0 = nd._n_ins              # absolute ordinal of insert #0 here

    def flush(upto: int) -> None:
        nonlocal cur, ev_ptr
        if upto <= cur:
            return
        np.add.at(cnt, _dedup_rows(ins_rows[cur:upto]), 1)
        hi = int(np.searchsorted(evict_iidx, upto, side="left"))
        if hi > ev_ptr:
            np.subtract.at(cnt, _dedup_rows(ev_rows[ev_ptr:hi]), 1)
            ev_ptr = hi
        cur = upto

    while True:
        nxt = min(next_est, next_adv, next_chk)
        if nxt > n_ins:
            break
        flush(nxt)
        g = int(ins_gpos[nxt - 1])  # request whose insert fired the event
        bumps = 0
        if next_est == nxt:         # reference order: estimate first
            nd.ind.estimate_rates()
            bumps += 1
            next_est = nxt + nd.est_interval
        cost = None
        if next_adv == nxt:         # periodic/delta fixed cadence
            cost = advert_cost(nd.ind, nd.adv_policy)
        elif next_chk == nxt:       # self_adjusting drift check
            nd.adv_tokens = refill(nd.adv_tokens, nd.adv_burst,
                                   nd.adv_bandwidth, nd.check_interval)
            next_chk = nxt + nd.check_interval
            cost = self_adjusting_decision(nd.ind, nd.adv_tokens,
                                           nd.adv_threshold)
        if cost is not None:
            # indications in [seg_start, g] used the OLD stale bitmap
            np.all(nd.ind.stale[idx_j[seg_start:g + 1]], axis=1,
                   out=ind_all[seg_start:g + 1, j])
            nd.ind.advertise()
            # a fresh advertisement resets the staleness estimates
            nd.ind.estimate_rates()
            bumps += 1
            seg_start = g + 1
            next_est = nxt + nd.est_interval
            if self_adj:
                nd.adv_tokens -= cost
                last_adv = nxt
            else:
                next_adv = nxt + nd.update_interval
            nd.advert_events.append((n_ins0 + nxt, float(cost)))
        if bumps:                   # a silent drift check bumps nothing
            nd.version += bumps
            est_events.append((base + g + 1, 0, j,
                               nd.ind.fp_est, nd.ind.fn_est))
    flush(n_ins)
    np.all(nd.ind.stale[idx_j[seg_start:N]], axis=1,
           out=ind_all[seg_start:N, j])
    if finalize:
        cbf.counters = np.clip(cnt, 0, 255).astype(np.uint8)
    nd._since_est = nd.est_interval - (next_est - n_ins)
    if self_adj:
        nd._since_adv = n_ins - last_adv
        nd._since_chk = nd.check_interval - (next_chk - n_ins)
    else:
        nd._since_adv = nd.update_interval - (next_adv - n_ins)
    nd._n_ins = n_ins0 + n_ins
    return cnt


def _q_epoch_walk(q_est, ind_all: np.ndarray, N: int,
                  base: int = 0) -> List[Tuple]:
    """Advance the q-estimators through the whole trace, one batched
    ``_close_epoch`` per epoch boundary (bit-exact: positives are integer
    counts).  Returns (effective request index, q) events per cache.

    ``QEstimator.observe_batch`` is exactly split-invariant, so the
    chunked phase 1 calls this once per chunk with the chunk's local
    ``ind_all`` slice and its global offset as ``base`` (event indices
    are globalised) — the fold is bit-identical to one whole-trace
    call."""
    events: List[Tuple] = []
    horizon = q_est[0].horizon
    first = horizon - q_est[0]._count   # requests closing the first epoch
    bounds = range(first, N + 1, horizon)
    for j, qe in enumerate(q_est):
        col = ind_all[:, j]
        prev = 0
        for b in bounds:            # each slice closes exactly one epoch
            qe.observe_batch(col[prev:b])
            events.append((base + b - 1, 1, j, qe.q))
            prev = b
        qe.observe_batch(col[prev:N])   # partial tail
    return events


def _assemble_versions(n: int, fp0, fn0, q0, events, N: int):
    """Replay the recorded estimate/q events chronologically into the
    client view-version history — the same floats ``_refresh_views`` would
    produce at each decision, plus the raw (fp, fn) behind them (the
    calibrated blend reads those live).  Returns (pi_v, nu_v, fp_v, fn_v)
    as [V, n] float64 arrays and ``points`` where points[i] = (first
    request index using version i, version id)."""
    from repro.core.model import exclusion_probabilities, hit_ratio_from_q
    fp, fn, q = list(fp0), list(fn0), list(q0)
    pi = [0.0] * n
    nu = [0.0] * n

    def view(js) -> None:
        for j in js:
            h = hit_ratio_from_q(q[j], fp[j], fn[j])
            pi[j], nu[j] = exclusion_probabilities(h, fp[j], fn[j])

    view(range(n))
    versions = [(tuple(pi), tuple(nu), tuple(fp), tuple(fn))]
    points = [(0, 0)]
    events = sorted(events)
    i = 0
    while i < len(events):
        eff = events[i][0]
        touched = set()
        while i < len(events) and events[i][0] == eff:
            _, kind, j = events[i][:3]
            if kind == 0:
                fp[j], fn[j] = events[i][3], events[i][4]
            else:
                q[j] = events[i][3]
            touched.add(j)
            i += 1
        if eff >= N:        # bump on the last request: no decision left
            continue
        view(touched)
        v = (tuple(pi), tuple(nu), tuple(fp), tuple(fn))
        if versions[-1] != v:
            versions.append(v)
            points.append((eff, len(versions) - 1))
    pi_v = np.asarray([v[0] for v in versions], np.float64)
    nu_v = np.asarray([v[1] for v in versions], np.float64)
    fp_v = np.asarray([v[2] for v in versions], np.float64)
    fn_v = np.asarray([v[3] for v in versions], np.float64)
    return pi_v, nu_v, fp_v, fn_v, points


#: distinct spill-directory suffixes within one process (path uniqueness)
_SPILL_SEQ = itertools.count()


def _alloc_outputs(N: int, n: int, spill):
    """Allocate the five per-request output arrays of one sweep:
    ``(ind_all [N, n] bool, in_dj [N] bool, dj_all [N] int64,
    pats [N] int64, ver_per_req [N] int64)``.

    ``spill=None`` -> plain RAM.  Otherwise preallocated ``.npy``
    memmaps under the given directory (or under a fresh
    ``ArtifactStore.spill_dir()`` when passed a store), filled
    chunk-by-chunk by the caller — memmaps ARE ndarrays, so every
    downstream consumer (replay, ``to_arrays``, the store) works
    unchanged.  The caller owns the directory's lifetime; ``N == 0``
    falls back to RAM (zero-byte files cannot be mmapped)."""
    if spill is None or N == 0:
        return (np.empty((N, n), dtype=bool), np.empty(N, dtype=bool),
                np.empty(N, dtype=np.int64), np.empty(N, dtype=np.int64),
                np.empty(N, dtype=np.int64))
    from numpy.lib.format import open_memmap
    if hasattr(spill, "spill_dir"):     # an ArtifactStore
        d = spill.spill_dir()
    else:
        d = Path(spill) / f"sweep-{os.getpid()}-{next(_SPILL_SEQ)}"
    d.mkdir(parents=True, exist_ok=True)

    def mm(name, dtype, shape):
        return open_memmap(str(d / f"{name}.npy"), mode="w+",
                           dtype=dtype, shape=shape)

    return (mm("ind_all", bool, (N, n)), mm("in_dj", bool, (N,)),
            mm("dj_all", np.int64, (N,)), mm("pats", np.int64, (N,)),
            mm("ver_per_req", np.int64, (N,)))


def _is_fresh(sim) -> bool:
    return (all(nd.version == 0 and len(nd.lru) == 0 and
                nd._since_adv == 0 and nd._since_est == 0 and
                nd._since_chk == 0 and nd._n_ins == 0 and
                not nd.advert_events and nd.adv_tokens == nd.adv_burst
                for nd in sim.nodes) and
            all(qe.version == 0 and qe._count == 0 and not qe._bootstrapped
                for qe in sim.q_est))


@dataclass
class SystemTrace:
    """One materialised system sweep, reusable across policies.

    See the module docstring; produced by :meth:`compute` (which advances
    the donor simulator's nodes in place) and consumed either by the same
    simulator or — via :meth:`install` — by any other FRESH simulator with
    an identical system configuration and trace."""
    key: tuple
    n: int
    trace_len: int
    ind_all: np.ndarray         # [N, n] bool — indications vs stale bitmaps
    in_dj: np.ndarray           # [N] bool — designated-cache membership
    dj_all: np.ndarray          # [N] int64 — designated cache per request
    pats: np.ndarray            # [N] int64 — n-bit indication pattern
    ver_per_req: np.ndarray     # [N] int64 — view-version id per request
    pi_v: np.ndarray            # [V, n] float64 — per-version model views
    nu_v: np.ndarray
    fp_v: np.ndarray            # [V, n] float64 — raw estimates behind them
    fn_v: np.ndarray
    quality: Dict[str, int]     # designated-cache indicator-quality counters
    final_state: dict           # end-of-run system state snapshot
    from_fresh: bool
    _trace: np.ndarray          # held only for identity checks on install
    # decision tables memoised per decision-side configuration (costs,
    # miss penalty, CS_FNO flag) — written by the table plans of
    # ``repro.cachesim.engine`` and by the sweep runner's stacked
    # cross-cell prefetch, read back at replay time
    plan_cache: Dict[tuple, np.ndarray] = field(default_factory=dict)
    # forwarded-stream positions (see forward_positions); None = derive
    _fwd_pos: Optional[np.ndarray] = None

    # -- construction ------------------------------------------------------

    @staticmethod
    def system_key(cfg) -> tuple:
        """The SimConfig fields the system evolution depends on (policy,
        costs, miss penalty and calibration knobs are decision-side only).
        Per-cache fields enter as their normalised tuples, so a scalar and
        its broadcast sequence hash identically; the advert spec enters in
        its :func:`~repro.cachesim.advert.resolve_advert` canonical form,
        so budget knobs a policy does not read cannot split sharing."""
        return (cfg.n_caches, cfg.cache_sizes, cfg.bpes,
                cfg.update_intervals, cfg.est_intervals,
                cfg.q_horizon, cfg.q_delta, cfg.seed,
                resolve_advert(cfg))

    @classmethod
    def compute(cls, sim, trace: np.ndarray, chunk_size: Optional[int] = None,
                spill=None) -> "SystemTrace":
        """Run the full sweep on ``sim``'s nodes (advancing them in place
        to the end-of-run state) and record everything any policy replay
        needs.

        ``chunk_size`` folds the trace through the sweep in slices of
        that many requests: the LRU dict, the int32 CBF working counters,
        the advert cadence/token carries and the q-estimators thread
        through chunk boundaries unchanged, so the result is BIT-IDENTICAL
        to the one-shot sweep (``chunk_size=None``, a single fold
        iteration) while the transient working set — raw hash-index rows,
        designated positions, eviction lists — stays O(chunk) instead of
        O(trace).

        ``spill`` (a directory path or an ``ArtifactStore``, whose
        ``spill_dir()`` then scopes the files) additionally backs the
        per-request OUTPUT arrays by preallocated ``.npy`` memmaps filled
        chunk-by-chunk, bounding peak RSS at O(chunk + cache state); the
        memmaps are ordinary ndarrays to every consumer.  The caller owns
        the spill directory's lifetime."""
        global SWEEPS_COMPUTED
        SWEEPS_COMPUTED += 1
        n = sim.cfg.n_caches
        nodes = sim.nodes
        N = int(trace.shape[0])
        fresh = _is_fresh(sim)
        if chunk_size is not None:
            # same contract as iter_trace_chunks: reject early, by name
            from repro.cachesim.tracefiles import validate_chunk_size
            validate_chunk_size(chunk_size)
        step = N if chunk_size is None else min(int(chunk_size), N)

        # view inputs at entry — events below record every later change
        fp0 = [nd.ind.fp_est for nd in nodes]
        fn0 = [nd.ind.fn_est for nd in nodes]
        q0 = [qe.q for qe in sim.q_est]

        ind_all, in_dj, dj_all, pats, ver_per_req = _alloc_outputs(
            N, n, spill)
        events: List[Tuple] = []
        cnt_carry: List = [None] * n        # int32 CBF working counters
        pow2 = 1 << np.arange(n, dtype=np.int64)
        # indicator-quality measurement on the designated cache (Fig. 1)
        quality = {"fn_events": 0, "fn_opportunities": 0, "fp_events": 0,
                   "fp_opportunities": 0, "resident": 0}
        start = 0
        while start < N:
            stop = min(start + step, N)
            nc = stop - start
            tchunk = trace[start:stop]
            last = stop == N
            dj_all[start:stop] = djc = sim._designated_batch(tchunk)
            ind_c = ind_all[start:stop]
            in_dj_c = in_dj[start:stop]
            for j, nd in enumerate(nodes):
                pos = np.flatnonzero(djc == j)
                idx_j = hash_indices(tchunk, nd.ind.cbf.k, nd.ind.cbf.m,
                                     nd.ind.cbf.seed)
                mem, ins_gpos, evict_keys, evict_iidx = _lru_sweep(
                    nd.lru, tchunk, pos)
                in_dj_c[pos] = mem
                cnt_carry[j] = _cbf_event_walk(
                    nd, j, idx_j, ins_gpos, evict_keys, evict_iidx,
                    ind_c, events, nc,
                    base=start, cnt=cnt_carry[j], finalize=last)
                id_ = ind_c[pos, j]
                held = int(np.count_nonzero(mem))
                quality["fn_opportunities"] += held
                quality["resident"] += held
                quality["fn_events"] += int(np.count_nonzero(mem & ~id_))
                quality["fp_opportunities"] += int(pos.shape[0]) - held
                quality["fp_events"] += int(np.count_nonzero(~mem & id_))
            events.extend(_q_epoch_walk(sim.q_est, ind_c, nc, base=start))
            pats[start:stop] = ind_c @ pow2
            start = stop

        pi_v, nu_v, fp_v, fn_v, points = _assemble_versions(
            n, fp0, fn0, q0, events, N)
        for i, (s0, vid) in enumerate(points):
            s1 = points[i + 1][0] if i + 1 < len(points) else N
            ver_per_req[s0:s1] = vid

        return cls(
            key=cls.system_key(sim.cfg), n=n, trace_len=N,
            ind_all=ind_all, in_dj=in_dj, dj_all=dj_all, pats=pats,
            ver_per_req=ver_per_req,
            pi_v=pi_v, nu_v=nu_v, fp_v=fp_v, fn_v=fn_v,
            quality=quality,
            final_state=cls._snapshot(sim),
            from_fresh=fresh, _trace=trace)

    @staticmethod
    def _snapshot(sim) -> dict:
        return {
            "nodes": [{
                "lru_keys": list(nd.lru._d.keys()),
                "counters": nd.ind.cbf.counters.copy(),
                "stale": nd.ind.stale.copy(),
                "fp_est": nd.ind.fp_est, "fn_est": nd.ind.fn_est,
                "version": nd.version,
                "since_adv": nd._since_adv, "since_est": nd._since_est,
                "since_chk": nd._since_chk, "n_ins": nd._n_ins,
                "adv_tokens": nd.adv_tokens,
                "adv_ins": [int(e[0]) for e in nd.advert_events],
                "adv_bytes": [float(e[1]) for e in nd.advert_events],
            } for nd in sim.nodes],
            "q": [{
                "q": qe.q, "version": qe.version, "count": qe._count,
                "positives": qe._positives, "boot": qe._bootstrapped,
            } for qe in sim.q_est],
        }

    # -- topology composition ----------------------------------------------

    def forward_positions(self) -> np.ndarray:
        """Positions (indices into THIS sweep's arrival stream) of the
        requests NOT resident in their designated cache — the
        residency-miss subsequence a parent tier receives when this
        sweep's system is one hop of a hierarchy
        (``repro.cachesim.topology``).  Hash-designated placement makes
        it policy-independent, so the forwarded stream — and with it
        every deeper tier's sweep — is shareable across policies and
        topology cells exactly like the sweep itself.  Derived lazily
        from ``in_dj`` and memoised; stored in the schema-v3 ``.npz``
        payload so hydrated sweeps skip the scan."""
        if self._fwd_pos is None:
            self._fwd_pos = np.flatnonzero(~self.in_dj).astype(np.int64)
        return self._fwd_pos

    # -- serialisation (the content-addressed artifact store) --------------

    def to_arrays(self) -> Dict[str, np.ndarray]:
        """Flatten the sweep into named ndarrays — the ``.npz`` payload of
        ``repro.cachesim.store``.  Everything a replay consumes round-trips
        bit-exactly: per-request arrays as-is, the view-version history as
        float64, the final-state snapshot as concatenated per-node arrays
        plus length vectors (node counts / bitmap sizes may vary).  The
        trace itself is NOT stored — the store keys entries on its content
        hash, so :meth:`from_arrays` re-attaches the caller's array.
        ``plan_cache`` tables are stored as separate per-key artifacts."""
        fs = self.final_state
        nodes, qs = fs["nodes"], fs["q"]

        def _cat(parts, dtype):
            parts = [np.asarray(p, dtype) for p in parts]
            return (np.concatenate(parts) if parts
                    else np.empty(0, dtype)), \
                np.asarray([p.shape[0] for p in parts], np.int64)

        lru_cat, lru_len = _cat([nd["lru_keys"] for nd in nodes], np.uint64)
        cnt_cat, cnt_len = _cat([nd["counters"] for nd in nodes], np.uint8)
        stale_cat, stale_len = _cat([nd["stale"] for nd in nodes], bool)
        adv_ins_cat, adv_len = _cat([nd["adv_ins"] for nd in nodes],
                                    np.int64)
        adv_bytes_cat, _ = _cat([nd["adv_bytes"] for nd in nodes],
                                np.float64)
        return {
            "n": np.int64(self.n), "trace_len": np.int64(self.trace_len),
            "from_fresh": np.bool_(self.from_fresh),
            "ind_all": self.ind_all, "in_dj": self.in_dj,
            "dj_all": self.dj_all, "pats": self.pats,
            "ver_per_req": self.ver_per_req,
            "fwd_pos": self.forward_positions(),
            "pi_v": self.pi_v, "nu_v": self.nu_v,
            "fp_v": self.fp_v, "fn_v": self.fn_v,
            "quality": np.asarray([self.quality[k] for k in _QUALITY_KEYS],
                                  np.int64),
            "node_lru": lru_cat, "node_lru_len": lru_len,
            "node_counters": cnt_cat, "node_counters_len": cnt_len,
            "node_stale": stale_cat, "node_stale_len": stale_len,
            "node_fp_est": np.asarray([nd["fp_est"] for nd in nodes],
                                      np.float64),
            "node_fn_est": np.asarray([nd["fn_est"] for nd in nodes],
                                      np.float64),
            "node_version": np.asarray([nd["version"] for nd in nodes],
                                       np.int64),
            "node_since_adv": np.asarray([nd["since_adv"] for nd in nodes],
                                         np.int64),
            "node_since_est": np.asarray([nd["since_est"] for nd in nodes],
                                         np.int64),
            "node_since_chk": np.asarray([nd["since_chk"] for nd in nodes],
                                         np.int64),
            "node_n_ins": np.asarray([nd["n_ins"] for nd in nodes],
                                     np.int64),
            "node_adv_tokens": np.asarray([nd["adv_tokens"]
                                           for nd in nodes], np.float64),
            "node_adv_ins": adv_ins_cat, "node_adv_len": adv_len,
            "node_adv_bytes": adv_bytes_cat,
            "q_q": np.asarray([q["q"] for q in qs], np.float64),
            "q_version": np.asarray([q["version"] for q in qs], np.int64),
            "q_count": np.asarray([q["count"] for q in qs], np.int64),
            "q_positives": np.asarray([q["positives"] for q in qs], np.int64),
            "q_boot": np.asarray([q["boot"] for q in qs], bool),
        }

    @classmethod
    def from_arrays(cls, arrays, key: tuple,
                    trace: np.ndarray) -> "SystemTrace":
        """Rebuild a sweep from :meth:`to_arrays` output.  ``key`` is the
        ``system_key`` the store looked the entry up under, ``trace`` the
        caller's (content-hash-verified) request array — the hydrated
        sweep replays bit-identically to the one :meth:`compute` built."""
        def _split(cat, lens):
            out, lo = [], 0
            for ln in np.asarray(lens, np.int64).tolist():
                out.append(cat[lo:lo + ln])
                lo += ln
            return out

        lrus = _split(arrays["node_lru"], arrays["node_lru_len"])
        cnts = _split(arrays["node_counters"], arrays["node_counters_len"])
        stales = _split(arrays["node_stale"], arrays["node_stale_len"])
        adv_ins = _split(arrays["node_adv_ins"], arrays["node_adv_len"])
        adv_bytes = _split(arrays["node_adv_bytes"], arrays["node_adv_len"])
        n_nodes = len(lrus)
        final_state = {
            "nodes": [{
                "lru_keys": lrus[j].tolist(),
                "counters": np.ascontiguousarray(cnts[j], np.uint8),
                "stale": np.ascontiguousarray(stales[j], bool),
                "fp_est": float(arrays["node_fp_est"][j]),
                "fn_est": float(arrays["node_fn_est"][j]),
                "version": int(arrays["node_version"][j]),
                "since_adv": int(arrays["node_since_adv"][j]),
                "since_est": int(arrays["node_since_est"][j]),
                "since_chk": int(arrays["node_since_chk"][j]),
                "n_ins": int(arrays["node_n_ins"][j]),
                "adv_tokens": float(arrays["node_adv_tokens"][j]),
                "adv_ins": np.asarray(adv_ins[j], np.int64).tolist(),
                "adv_bytes": np.asarray(adv_bytes[j],
                                        np.float64).tolist(),
            } for j in range(n_nodes)],
            "q": [{
                "q": float(arrays["q_q"][j]),
                "version": int(arrays["q_version"][j]),
                "count": int(arrays["q_count"][j]),
                "positives": int(arrays["q_positives"][j]),
                "boot": bool(arrays["q_boot"][j]),
            } for j in range(int(np.asarray(arrays["q_q"]).shape[0]))],
        }
        quality = {k: int(v) for k, v in
                   zip(_QUALITY_KEYS, np.asarray(arrays["quality"]))}
        return cls(
            key=key, n=int(arrays["n"]), trace_len=int(arrays["trace_len"]),
            ind_all=np.ascontiguousarray(arrays["ind_all"], bool),
            in_dj=np.ascontiguousarray(arrays["in_dj"], bool),
            dj_all=np.ascontiguousarray(arrays["dj_all"], np.int64),
            pats=np.ascontiguousarray(arrays["pats"], np.int64),
            ver_per_req=np.ascontiguousarray(arrays["ver_per_req"], np.int64),
            pi_v=np.ascontiguousarray(arrays["pi_v"], np.float64),
            nu_v=np.ascontiguousarray(arrays["nu_v"], np.float64),
            fp_v=np.ascontiguousarray(arrays["fp_v"], np.float64),
            fn_v=np.ascontiguousarray(arrays["fn_v"], np.float64),
            quality=quality, final_state=final_state,
            from_fresh=bool(arrays["from_fresh"]), _trace=trace,
            _fwd_pos=(np.ascontiguousarray(arrays["fwd_pos"], np.int64)
                      if "fwd_pos" in arrays else None))

    # -- reuse -------------------------------------------------------------

    def install(self, sim, trace: np.ndarray) -> None:
        """Skip the sweep for a fresh, same-system simulator: put its nodes
        directly into the recorded end-of-run state."""
        if self.key != self.system_key(sim.cfg):
            raise ValueError(
                "SystemTrace system config mismatch: "
                f"{self.key} != {self.system_key(sim.cfg)}")
        if not self.from_fresh or not _is_fresh(sim):
            raise ValueError("SystemTrace sharing requires fresh simulators")
        if trace.shape[0] != self.trace_len or \
                not np.array_equal(self._trace, trace):
            raise ValueError("SystemTrace was computed for a different trace")
        from collections import OrderedDict
        for nd, snap in zip(sim.nodes, self.final_state["nodes"]):
            nd.lru._d = OrderedDict.fromkeys(snap["lru_keys"])
            nd.ind.cbf.counters = snap["counters"].copy()
            nd.ind.stale = snap["stale"].copy()
            nd.ind.fp_est = snap["fp_est"]
            nd.ind.fn_est = snap["fn_est"]
            nd.version = snap["version"]
            nd._since_adv = snap["since_adv"]
            nd._since_est = snap["since_est"]
            nd._since_chk = snap["since_chk"]
            nd._n_ins = snap["n_ins"]
            nd.adv_tokens = snap["adv_tokens"]
            nd.advert_events = list(zip(snap["adv_ins"],
                                        snap["adv_bytes"]))
        for qe, snap in zip(sim.q_est, self.final_state["q"]):
            qe.q = snap["q"]
            qe.version = snap["version"]
            qe._count = snap["count"]
            qe._positives = snap["positives"]
            qe._bootstrapped = snap["boot"]

    def add_quality(self, res) -> None:
        """Accumulate the (policy-independent) Fig. 1 counters."""
        for k, v in self.quality.items():
            setattr(res, k, getattr(res, k) + v)

    def advert_streams(self) -> List[Tuple[np.ndarray, np.ndarray]]:
        """Per-cache advertisement event streams: one ``(insertion
        ordinals int64, bytes-on-wire float64)`` array pair per cache,
        read from the end-of-run snapshot.  Ordinals are absolute 1-based
        insertion counts into that cache."""
        return [(np.asarray(nd["adv_ins"], np.int64),
                 np.asarray(nd["adv_bytes"], np.float64))
                for nd in self.final_state["nodes"]]

    def add_advert(self, res) -> None:
        """Attach the (policy-independent) advert-event totals to a
        result, mirroring the reference loop's accumulation — plain
        attributes, NOT SimResult dataclass fields (golden files pin the
        dataclass field set)."""
        nodes = self.final_state["nodes"]
        res.advert_events = (getattr(res, "advert_events", 0) +
                             sum(len(nd["adv_ins"]) for nd in nodes))
        res.advert_bytes = (getattr(res, "advert_bytes", 0.0) +
                            sum(b for nd in nodes
                                for b in nd["adv_bytes"]))
