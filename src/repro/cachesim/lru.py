"""LRU cache (paper Sec. V-A: 'arguably the most common policy')."""
from __future__ import annotations

from collections import OrderedDict
from typing import Optional, Tuple


class LRUCache:
    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self._d: "OrderedDict[int, None]" = OrderedDict()

    def __contains__(self, key: int) -> bool:
        return key in self._d

    def __len__(self) -> int:
        return len(self._d)

    def touch(self, key: int) -> bool:
        """Refresh recency; returns True if the key was present."""
        if key in self._d:
            self._d.move_to_end(key)
            return True
        return False

    def put(self, key: int) -> Tuple[bool, Optional[int]]:
        """Insert (or refresh).  Returns (inserted_new, evicted_key)."""
        if key in self._d:
            self._d.move_to_end(key)
            return False, None
        evicted = None
        if len(self._d) >= self.capacity:
            evicted, _ = self._d.popitem(last=False)
        self._d[key] = None
        return True, evicted

    def keys(self):
        return self._d.keys()
