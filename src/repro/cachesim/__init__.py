from repro.cachesim.lru import LRUCache
from repro.cachesim.simulator import SimConfig, SimResult, Simulator
from repro.cachesim.traces import get_trace, TRACES

__all__ = ["LRUCache", "SimConfig", "SimResult", "Simulator", "get_trace", "TRACES"]
