"""Trace-driven multi-cache simulation (paper Sec. V).

Two bit-exact engines share the system model (``SimConfig.engine``):
the per-request reference loop, and the epoch-batched fast engine
(``repro.cachesim.fastpath``) built on two invariants — stale bitmaps
only change at advertisement boundaries, and (pi, nu) views only change
at ``(node.version, q_est.version)`` bumps, bounding distinct decisions
by 2^n per view version.  See the ``repro.cachesim.simulator`` module
docstring for the full invariant statement.
"""
from repro.cachesim.lru import LRUCache
from repro.cachesim.simulator import SimConfig, SimResult, Simulator, run_policies
from repro.cachesim.traces import get_trace, TRACES

__all__ = ["LRUCache", "SimConfig", "SimResult", "Simulator", "run_policies",
           "get_trace", "TRACES"]
