"""Trace-driven multi-cache simulation (paper Sec. V).

Two bit-exact engines share the system model (``SimConfig.engine``): the
per-request reference loop, and the shared-SystemTrace fast architecture —
a policy-independent system sweep (``repro.cachesim.systemstate``)
computed once per (trace, system config) and reused across policies,
feeding per-policy replays: decision-table lookups for the model-based
policies (``repro.cachesim.fastpath``) and a speculative segmented replay
for the calibrated policy (``repro.cachesim.fna_cal_fast``).
``run_policies`` and ``repro.cachesim.sweep`` exploit the sharing for
policy x trace x axis grids, and ``repro.cachesim.scenarios`` names the
experiment configurations (paper Figs. 1, 3-7 plus heterogeneous
beyond-paper regimes) that drive ``benchmarks/paper_figs.py`` and the
golden differential suite.  See the ``repro.cachesim.simulator`` module
docstring for the invariant statement.

``repro.cachesim.topology`` composes the same engine into hierarchical
PATH/TREE topologies of tier nodes (``TopoConfig`` + ``run_topology``) —
a residency miss at depth d re-enters the identical one-hop system at
depth d + 1 — with per-tier sweeps shared across grid cells and depths
(``docs/topology.md``).
"""
from repro.cachesim.engine import (
    DecisionPlan,
    PROVIDERS,
    TablePlan,
    plan_for,
    register_provider,
    run_cells,
)
from repro.cachesim.lru import LRUCache
from repro.cachesim.scenarios import (
    GOLDEN_SCENARIOS,
    SCENARIOS,
    Scenario,
    get_scenario,
    list_scenarios,
    run_scenario,
)
from repro.cachesim.simulator import SimConfig, SimResult, Simulator, run_policies
from repro.cachesim.store import ArtifactStore
from repro.cachesim.sweep import run_grid, run_sweep, sweep_records
from repro.cachesim.systemstate import SystemTrace
from repro.cachesim.topology import (
    TierSpec,
    TierSystem,
    TopoConfig,
    TopoResult,
    run_topo_grid,
    run_topology,
)
from repro.cachesim.tracefiles import (
    TraceInfo,
    load_trace_file,
    register_trace_file,
    trace_info,
)
from repro.cachesim.traces import get_trace, TRACES

__all__ = ["ArtifactStore",
           "LRUCache", "SimConfig", "SimResult", "Simulator", "SystemTrace",
           "Scenario", "SCENARIOS", "GOLDEN_SCENARIOS", "get_scenario",
           "list_scenarios", "run_scenario", "run_policies", "run_grid",
           "run_sweep", "sweep_records", "get_trace", "TRACES",
           "TraceInfo", "load_trace_file", "register_trace_file",
           "trace_info",
           "DecisionPlan", "TablePlan", "PROVIDERS", "plan_for",
           "register_provider", "run_cells",
           "TierSpec", "TierSystem", "TopoConfig", "TopoResult",
           "run_topology", "run_topo_grid"]
