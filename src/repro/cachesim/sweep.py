"""Policy x trace x system-axis sweep runner (paper Figs. 4-7 grids).

The paper's headline claim — FNA matching FNO's cost with an order of
magnitude fewer advertised bits — is established on multi-dimensional
sweeps: every policy, over every workload, across a range of system
parameters (advertisement intervals, indicator budgets, cache sizes,
cache counts).  The system evolution is policy-independent (hash
placement), so each (trace, cell) computes its
:class:`~repro.cachesim.systemstate.SystemTrace` exactly once and replays
every policy against it (via :func:`repro.cachesim.simulator.
run_policies`): a P-policy grid costs one system sweep per cell plus
P cheap replays, instead of P full simulations.

:func:`run_grid` sweeps an arbitrary ``SimConfig`` field.  A cell value
is one of:

  * a scalar — assigned to the swept field (``update_interval=512``);
  * a per-cache sequence — assigned as-is (staggered advertisement
    cadences: ``update_interval=(100, 400, 1600)``);
  * a mapping of several SimConfig overrides — for axes whose cells move
    coupled fields (paper Fig. 6 scales ``update_interval`` with
    ``cache_size``; Fig. 7 resizes the homogeneous cost vector with
    ``n_caches``).

Swept fields split into two kinds, classified per cell by
``SystemTrace.system_key``:

  * SYSTEM-side axes change the indicators or cache dynamics
    (``update_interval``, ``bpe``, ``cache_size``, ``n_caches``, ...):
    every cell is its own system evolution, so cells never share sweeps
    with each other — only policies within a cell do.
  * DECISION-side axes leave the system evolution untouched
    (``miss_penalty``, ``costs``, ``policy``, the calibration knobs):
    all their cells land in one group that computes a SINGLE
    :class:`~repro.cachesim.systemstate.SystemTrace` per trace and
    replays every (cell, policy) against it, with the ds_pgm family's
    decision tables stacked into one batched call
    (:func:`repro.cachesim.engine.run_cells`).  The paper's Fig. 3
    penalty grid thus costs one sweep per trace instead of one per cell.

:func:`run_grid` also carries the perf tier on top of the grouping:

  * ``store=`` consults the content-addressed artifact store
    (``repro.cachesim.store``) so repeated grid runs never recompute a
    (trace bytes x system key) sweep or its decision tables;
  * ``workers=N`` runs the independent system-key groups' PHASE-1 sweeps
    in a spawn-based process pool, with the store as the cross-process
    hand-off: workers persist sweeps, then the ordinary serial pass runs
    entirely warm — so the parallel path is bit-identical to the serial
    one by construction (the replays are the same code on the same
    hydrated artifacts).  With no ``store`` given, a temporary store
    scoped to the call is used.

:func:`run_sweep` is the ``update_interval`` special case (Figs. 4-6),
kept as the stable entry point for benchmarks and tests.
"""
from __future__ import annotations

import dataclasses
import shutil
import tempfile
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from repro.cachesim.simulator import SimConfig, SimResult
from repro.cachesim.systemstate import SystemTrace
from repro.cachesim.traces import get_trace

DEFAULT_POLICIES = ("fna", "fna_cal", "fno", "pi")

#: one grid-cell key: (trace name, axis label)
CellKey = Tuple[str, object]


def hashable_label(value):
    """Normalise an axis value into a hashable cell-key / record-label
    component (lists/arrays -> tuples, numpy scalars -> Python scalars).
    Public: the figure pipeline and the golden suite key on it too."""
    if isinstance(value, np.ndarray):
        value = value.tolist()
    if isinstance(value, (list, tuple)):
        return tuple(hashable_label(v) for v in value)
    if isinstance(value, np.generic):
        return value.item()
    return value


def cell_overrides(axis: str, value) -> dict:
    """The SimConfig field overrides one axis value denotes."""
    if isinstance(value, Mapping):
        return {k: hashable_label(v) for k, v in value.items()}
    return {axis: hashable_label(value)}


def cell_label(axis: str, value):
    """The hashable grid key / record label of one axis value (for a
    mapping cell: its swept-field entry, else the full override tuple)."""
    if isinstance(value, Mapping):
        if axis in value:
            return hashable_label(value[axis])
        return tuple(sorted((k, hashable_label(v)) for k, v in value.items()))
    return hashable_label(value)


def _sweep_worker(store_root: str, trace: np.ndarray, cfg,
                  chunk_size: Optional[int] = None) -> str:
    """Process-pool job: compute ONE system-key group's sweep and persist
    it to the shared store (the cross-process hand-off).  Module-level so
    the spawn context can pickle it; returns "hit"/"computed" for
    observability.  Workers never ship a SystemTrace back — the parent's
    serial pass hydrates from the store, which is what makes the
    parallel path bit-identical to the serial one."""
    from repro.cachesim.simulator import Simulator
    from repro.cachesim.store import ArtifactStore
    store = ArtifactStore(store_root)
    trace = np.asarray(trace, dtype=np.uint64)
    digest = ArtifactStore.trace_digest(trace)
    key = SystemTrace.system_key(cfg)
    if store.has_sweep(digest, key):
        return "hit"
    st = SystemTrace.compute(Simulator(cfg), trace, chunk_size=chunk_size)
    store.save_sweep(st, trace_digest=digest)
    return "computed"


def _farm_sweeps(jobs, store, workers: int,
                 chunk_size: Optional[int] = None) -> None:
    """Run the phase-1 sweep jobs ``[(trace, cfg)]`` across a spawn-based
    process pool, persisting each into ``store``.  spawn (not fork): the
    parent may hold a live XLA client, which is not fork-safe; workers
    only run the NumPy sweep phase anyway."""
    import multiprocessing
    from concurrent.futures import ProcessPoolExecutor
    ctx = multiprocessing.get_context("spawn")
    root = str(store.root)
    with ProcessPoolExecutor(max_workers=min(workers, len(jobs)),
                             mp_context=ctx) as pool:
        futs = [pool.submit(_sweep_worker, root, trace, cfg, chunk_size)
                for trace, cfg in jobs]
        for f in futs:
            f.result()      # propagate worker failures loudly


def run_grid(traces: Union[Mapping[str, np.ndarray], Sequence[str]],
             base: SimConfig,
             axis: str,
             values: Sequence,
             policies: Sequence[str] = DEFAULT_POLICIES,
             n_requests: int = 100_000,
             share_system: bool = True,
             backend: str = "numpy",
             mesh=None,
             store=None,
             workers: int = 0,
             chunk_size: Optional[int] = None,
             ) -> Dict[CellKey, Dict[str, SimResult]]:
    """Run a policy grid over an arbitrary system axis; returns
    ``{(trace_name, label): {policy: SimResult}}``.

    ``traces`` is either a mapping of name -> request array, or a
    sequence of :func:`~repro.cachesim.traces.get_trace` names generated
    at ``n_requests`` with ``base.seed``.  ``share_system=False`` forces
    per-policy full runs (benchmarking the amortisation itself).

    ``store`` (an ``ArtifactStore``, a root path, or None) persists and
    reuses sweeps/tables content-addressed on (trace bytes x system key)
    — see ``repro.cachesim.store``.  ``workers=N`` (N > 1) additionally
    computes the independent system-key groups' sweeps in an N-process
    spawn pool first, handing them off through the store (a temporary
    one when none is given); the subsequent serial pass then runs warm,
    so results are bit-identical to ``workers=0``.

    ``backend="jax"`` builds each group's stacked decision tables with
    the jitted kernel, sharding the cell axis across the devices of
    ``mesh`` (auto-created when None and more than one device is
    visible); see :func:`repro.cachesim.engine.run_cells`.  Replay and
    the returned results are unchanged up to the ~1e-12 near-tie
    dead-band on table masks.

    ``chunk_size`` streams every phase-1 sweep (serial and farmed)
    through fixed-size trace slices — bit-identical results, bounded
    sweep working set (see ``SystemTrace.compute``).
    """
    from repro.cachesim.engine import plan_for, run_cells
    from repro.cachesim.store import as_store
    from repro.cachesim.topology import TopoConfig, run_topo_grid
    if not isinstance(traces, Mapping):
        traces = {name: get_trace(name, n_requests, seed=base.seed)
                  for name in traces}
    if isinstance(base, TopoConfig):
        # hierarchical grids (repro.cachesim.topology): topology axes
        # (depth, fanout, per-tier penalty/cadence/queue knobs) or
        # SimConfig axes broadcast through the shared base; per-tier
        # sweeps are shared across cells (and depths) through one
        # store-backed pool.  ``backend``/``mesh``/``workers`` do not
        # apply — per-tier grids prefetch nothing batched yet.
        return run_topo_grid(traces, base, axis, values,
                             policies=policies,
                             share_system=share_system, store=store,
                             chunk_size=chunk_size)
    # classify cells by the policy-independent system key: cells of a
    # decision-side axis all share one key (and thus ONE SystemTrace
    # per trace); system-side cells each form their own group
    per_trace: List[Tuple[str, np.ndarray, List[CellKey], Dict]] = []
    for name, trace in traces.items():
        order: List[CellKey] = []
        groups: Dict[tuple, List[Tuple[CellKey, SimConfig]]] = {}
        for value in values:
            key = (name, cell_label(axis, value))
            if key in order:
                raise ValueError(
                    f"duplicate grid cell {key!r}: two axis values share "
                    f"the label {key[1]!r} — give mapping cells distinct "
                    f"{axis!r} entries (or sweep a different axis)")
            order.append(key)
            cfg = dataclasses.replace(base, **cell_overrides(axis, value))
            groups.setdefault(SystemTrace.system_key(cfg),
                              []).append((key, cfg))
        per_trace.append((name, trace, order, groups))

    store = as_store(store)
    tmp_root = None
    try:
        if workers > 1 and share_system:
            if store is None:
                # the hand-off needs SOME shared medium; scope it to the call
                tmp_root = tempfile.mkdtemp(prefix="repro-store-")
                store = as_store(tmp_root)
            # one phase-1 job per (trace, group) whose sweep the serial
            # pass below would compute and that isn't already stored
            jobs = []
            for name, trace, _, groups in per_trace:
                tr = np.asarray(trace, dtype=np.uint64)
                digest = store.trace_digest(tr)
                for sys_key, cells in groups.items():
                    cfgs = [cfg for _, cfg in cells]
                    sweepable = all(cfg.engine == "fast" for cfg in cfgs) \
                        and tr.shape[0] > 0 and any(
                            plan_for(dataclasses.replace(cfg, policy=p))
                            is not None for cfg in cfgs for p in policies)
                    if sweepable and not store.has_sweep(digest, sys_key):
                        jobs.append((tr, cfgs[0]))
            if len(jobs) > 1:   # a 1-job farm is just spawn overhead
                _farm_sweeps(jobs, store, workers, chunk_size=chunk_size)

        out: Dict[CellKey, Dict[str, SimResult]] = {}
        for name, trace, order, groups in per_trace:
            results: Dict[CellKey, Dict[str, SimResult]] = {}
            for cells in groups.values():
                group_out = run_cells(trace, [cfg for _, cfg in cells],
                                      policies, share_system=share_system,
                                      backend=backend, mesh=mesh,
                                      store=store, chunk_size=chunk_size)
                for (key, _), cell_res in zip(cells, group_out):
                    results[key] = cell_res
            for key in order:       # keep the caller's cell order
                out[key] = results[key]
        return out
    finally:
        if tmp_root is not None:
            shutil.rmtree(tmp_root, ignore_errors=True)


def run_sweep(traces: Union[Mapping[str, np.ndarray], Sequence[str]],
              base: SimConfig,
              update_intervals: Sequence[int],
              policies: Sequence[str] = DEFAULT_POLICIES,
              n_requests: int = 100_000,
              share_system: bool = True,
              ) -> Dict[CellKey, Dict[str, SimResult]]:
    """The ``update_interval`` grid (paper Figs. 4-6 x-axis); see
    :func:`run_grid`."""
    values = [int(i) for i in update_intervals]
    return run_grid(traces, base, "update_interval", values,
                    policies=policies, n_requests=n_requests,
                    share_system=share_system)


#: record keys an axis label may never shadow: the per-policy result
#: fields every record carries, plus the trace column and the advert
#: totals (attached as plain attributes by both engines)
_RESERVED_RECORD_KEYS = (frozenset(SimResult(policy="").to_dict()) |
                         {"trace", "advert_events", "advert_bytes"})


def axis_column(axis: str) -> str:
    """The record column an axis is flattened under.  An axis whose name
    collides with a :meth:`SimResult.to_dict` field (e.g. a future
    ``n_requests`` axis vs the ``n`` request counter's sibling fields) or
    with ``trace`` would be silently overwritten by the result dict —
    those are prefixed ``axis_<name>`` instead."""
    return axis if axis not in _RESERVED_RECORD_KEYS else f"axis_{axis}"


def sweep_records(grid: Dict[CellKey, Dict[str, SimResult]],
                  axis: str = "update_interval") -> List[dict]:
    """Flatten a :func:`run_grid`/:func:`run_sweep` grid into one record
    per (trace, cell, policy) — ready for CSV/JSON dumps or plotting.
    Per-cache tuple labels serialise as lists in JSON; the axis lands in
    column :func:`axis_column` (prefixed on a result-field collision)."""
    col = axis_column(axis)
    records = []
    for (name, label), cell in grid.items():
        for policy, res in cell.items():
            rec = {"trace": name, col: label}
            rec.update(res.to_dict())
            if hasattr(res, "advert_events"):
                rec["advert_events"] = int(res.advert_events)
                rec["advert_bytes"] = round(float(res.advert_bytes), 2)
            records.append(rec)
    return records
