"""Policy x trace x system-axis sweep runner (paper Figs. 4-7 grids).

The paper's headline claim — FNA matching FNO's cost with an order of
magnitude fewer advertised bits — is established on multi-dimensional
sweeps: every policy, over every workload, across a range of system
parameters (advertisement intervals, indicator budgets, cache sizes,
cache counts).  The system evolution is policy-independent (hash
placement), so each (trace, cell) computes its
:class:`~repro.cachesim.systemstate.SystemTrace` exactly once and replays
every policy against it (via :func:`repro.cachesim.simulator.
run_policies`): a P-policy grid costs one system sweep per cell plus
P cheap replays, instead of P full simulations.

:func:`run_grid` sweeps an arbitrary ``SimConfig`` field.  A cell value
is one of:

  * a scalar — assigned to the swept field (``update_interval=512``);
  * a per-cache sequence — assigned as-is (staggered advertisement
    cadences: ``update_interval=(100, 400, 1600)``);
  * a mapping of several SimConfig overrides — for axes whose cells move
    coupled fields (paper Fig. 6 scales ``update_interval`` with
    ``cache_size``; Fig. 7 resizes the homogeneous cost vector with
    ``n_caches``).

Swept fields are SYSTEM configuration whenever they change the
indicators or cache dynamics (``update_interval``, ``bpe``,
``cache_size``, ``n_caches``, ...), so cells never share sweeps with
each other — only policies within a cell do.  Decision-side axes
(``miss_penalty``, ``costs``) would in principle allow cross-cell
sharing too; ``run_grid`` does not exploit that today.

:func:`run_sweep` is the ``update_interval`` special case (Figs. 4-6),
kept as the stable entry point for benchmarks and tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Sequence, Tuple, Union

import numpy as np

from repro.cachesim.simulator import SimConfig, SimResult, run_policies
from repro.cachesim.traces import get_trace

DEFAULT_POLICIES = ("fna", "fna_cal", "fno", "pi")

#: one grid-cell key: (trace name, axis label)
CellKey = Tuple[str, object]


def hashable_label(value):
    """Normalise an axis value into a hashable cell-key / record-label
    component (lists/arrays -> tuples, numpy scalars -> Python scalars).
    Public: the figure pipeline and the golden suite key on it too."""
    if isinstance(value, np.ndarray):
        value = value.tolist()
    if isinstance(value, (list, tuple)):
        return tuple(hashable_label(v) for v in value)
    if isinstance(value, np.generic):
        return value.item()
    return value


def cell_overrides(axis: str, value) -> dict:
    """The SimConfig field overrides one axis value denotes."""
    if isinstance(value, Mapping):
        return {k: hashable_label(v) for k, v in value.items()}
    return {axis: hashable_label(value)}


def cell_label(axis: str, value):
    """The hashable grid key / record label of one axis value (for a
    mapping cell: its swept-field entry, else the full override tuple)."""
    if isinstance(value, Mapping):
        if axis in value:
            return hashable_label(value[axis])
        return tuple(sorted((k, hashable_label(v)) for k, v in value.items()))
    return hashable_label(value)


def run_grid(traces: Union[Mapping[str, np.ndarray], Sequence[str]],
             base: SimConfig,
             axis: str,
             values: Sequence,
             policies: Sequence[str] = DEFAULT_POLICIES,
             n_requests: int = 100_000,
             share_system: bool = True,
             ) -> Dict[CellKey, Dict[str, SimResult]]:
    """Run a policy grid over an arbitrary system axis; returns
    ``{(trace_name, label): {policy: SimResult}}``.

    ``traces`` is either a mapping of name -> request array, or a
    sequence of :func:`~repro.cachesim.traces.get_trace` names generated
    at ``n_requests`` with ``base.seed``.  ``share_system=False`` forces
    per-policy full runs (benchmarking the amortisation itself).
    """
    if not isinstance(traces, Mapping):
        traces = {name: get_trace(name, n_requests, seed=base.seed)
                  for name in traces}
    out: Dict[CellKey, Dict[str, SimResult]] = {}
    for name, trace in traces.items():
        for value in values:
            key = (name, cell_label(axis, value))
            if key in out:
                raise ValueError(
                    f"duplicate grid cell {key!r}: two axis values share "
                    f"the label {key[1]!r} — give mapping cells distinct "
                    f"{axis!r} entries (or sweep a different axis)")
            cfg = dataclasses.replace(base, **cell_overrides(axis, value))
            out[key] = run_policies(
                trace, cfg, policies=policies, share_system=share_system)
    return out


def run_sweep(traces: Union[Mapping[str, np.ndarray], Sequence[str]],
              base: SimConfig,
              update_intervals: Sequence[int],
              policies: Sequence[str] = DEFAULT_POLICIES,
              n_requests: int = 100_000,
              share_system: bool = True,
              ) -> Dict[CellKey, Dict[str, SimResult]]:
    """The ``update_interval`` grid (paper Figs. 4-6 x-axis); see
    :func:`run_grid`."""
    values = [int(i) for i in update_intervals]
    return run_grid(traces, base, "update_interval", values,
                    policies=policies, n_requests=n_requests,
                    share_system=share_system)


def sweep_records(grid: Dict[CellKey, Dict[str, SimResult]],
                  axis: str = "update_interval") -> List[dict]:
    """Flatten a :func:`run_grid`/:func:`run_sweep` grid into one record
    per (trace, cell, policy) — ready for CSV/JSON dumps or plotting.
    Per-cache tuple labels serialise as lists in JSON."""
    records = []
    for (name, label), cell in grid.items():
        for policy, res in cell.items():
            rec = {"trace": name, axis: label}
            rec.update(res.to_dict())
            records.append(rec)
    return records
