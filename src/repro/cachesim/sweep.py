"""Policy x trace x system-axis sweep runner (paper Figs. 4-7 grids).

The paper's headline claim — FNA matching FNO's cost with an order of
magnitude fewer advertised bits — is established on multi-dimensional
sweeps: every policy, over every workload, across a range of system
parameters (advertisement intervals, indicator budgets, cache sizes,
cache counts).  The system evolution is policy-independent (hash
placement), so each (trace, cell) computes its
:class:`~repro.cachesim.systemstate.SystemTrace` exactly once and replays
every policy against it (via :func:`repro.cachesim.simulator.
run_policies`): a P-policy grid costs one system sweep per cell plus
P cheap replays, instead of P full simulations.

:func:`run_grid` sweeps an arbitrary ``SimConfig`` field.  A cell value
is one of:

  * a scalar — assigned to the swept field (``update_interval=512``);
  * a per-cache sequence — assigned as-is (staggered advertisement
    cadences: ``update_interval=(100, 400, 1600)``);
  * a mapping of several SimConfig overrides — for axes whose cells move
    coupled fields (paper Fig. 6 scales ``update_interval`` with
    ``cache_size``; Fig. 7 resizes the homogeneous cost vector with
    ``n_caches``).

Swept fields split into two kinds, classified per cell by
``SystemTrace.system_key``:

  * SYSTEM-side axes change the indicators or cache dynamics
    (``update_interval``, ``bpe``, ``cache_size``, ``n_caches``, ...):
    every cell is its own system evolution, so cells never share sweeps
    with each other — only policies within a cell do.
  * DECISION-side axes leave the system evolution untouched
    (``miss_penalty``, ``costs``, ``policy``, the calibration knobs):
    all their cells land in one group that computes a SINGLE
    :class:`~repro.cachesim.systemstate.SystemTrace` per trace and
    replays every (cell, policy) against it, with the ds_pgm family's
    decision tables stacked into one batched call
    (:func:`repro.cachesim.engine.run_cells`).  The paper's Fig. 3
    penalty grid thus costs one sweep per trace instead of one per cell.

:func:`run_sweep` is the ``update_interval`` special case (Figs. 4-6),
kept as the stable entry point for benchmarks and tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Sequence, Tuple, Union

import numpy as np

from repro.cachesim.simulator import SimConfig, SimResult
from repro.cachesim.systemstate import SystemTrace
from repro.cachesim.traces import get_trace

DEFAULT_POLICIES = ("fna", "fna_cal", "fno", "pi")

#: one grid-cell key: (trace name, axis label)
CellKey = Tuple[str, object]


def hashable_label(value):
    """Normalise an axis value into a hashable cell-key / record-label
    component (lists/arrays -> tuples, numpy scalars -> Python scalars).
    Public: the figure pipeline and the golden suite key on it too."""
    if isinstance(value, np.ndarray):
        value = value.tolist()
    if isinstance(value, (list, tuple)):
        return tuple(hashable_label(v) for v in value)
    if isinstance(value, np.generic):
        return value.item()
    return value


def cell_overrides(axis: str, value) -> dict:
    """The SimConfig field overrides one axis value denotes."""
    if isinstance(value, Mapping):
        return {k: hashable_label(v) for k, v in value.items()}
    return {axis: hashable_label(value)}


def cell_label(axis: str, value):
    """The hashable grid key / record label of one axis value (for a
    mapping cell: its swept-field entry, else the full override tuple)."""
    if isinstance(value, Mapping):
        if axis in value:
            return hashable_label(value[axis])
        return tuple(sorted((k, hashable_label(v)) for k, v in value.items()))
    return hashable_label(value)


def run_grid(traces: Union[Mapping[str, np.ndarray], Sequence[str]],
             base: SimConfig,
             axis: str,
             values: Sequence,
             policies: Sequence[str] = DEFAULT_POLICIES,
             n_requests: int = 100_000,
             share_system: bool = True,
             backend: str = "numpy",
             mesh=None,
             ) -> Dict[CellKey, Dict[str, SimResult]]:
    """Run a policy grid over an arbitrary system axis; returns
    ``{(trace_name, label): {policy: SimResult}}``.

    ``traces`` is either a mapping of name -> request array, or a
    sequence of :func:`~repro.cachesim.traces.get_trace` names generated
    at ``n_requests`` with ``base.seed``.  ``share_system=False`` forces
    per-policy full runs (benchmarking the amortisation itself).

    ``backend="jax"`` builds each group's stacked decision tables with
    the jitted kernel, sharding the cell axis across the devices of
    ``mesh`` (auto-created when None and more than one device is
    visible); see :func:`repro.cachesim.engine.run_cells`.  Replay and
    the returned results are unchanged up to the ~1e-12 near-tie
    dead-band on table masks.
    """
    from repro.cachesim.engine import run_cells
    if not isinstance(traces, Mapping):
        traces = {name: get_trace(name, n_requests, seed=base.seed)
                  for name in traces}
    out: Dict[CellKey, Dict[str, SimResult]] = {}
    for name, trace in traces.items():
        # classify cells by the policy-independent system key: cells of a
        # decision-side axis all share one key (and thus ONE SystemTrace
        # per trace); system-side cells each form their own group
        order: List[CellKey] = []
        groups: Dict[tuple, List[Tuple[CellKey, SimConfig]]] = {}
        for value in values:
            key = (name, cell_label(axis, value))
            if key in order:
                raise ValueError(
                    f"duplicate grid cell {key!r}: two axis values share "
                    f"the label {key[1]!r} — give mapping cells distinct "
                    f"{axis!r} entries (or sweep a different axis)")
            order.append(key)
            cfg = dataclasses.replace(base, **cell_overrides(axis, value))
            groups.setdefault(SystemTrace.system_key(cfg),
                              []).append((key, cfg))
        results: Dict[CellKey, Dict[str, SimResult]] = {}
        for cells in groups.values():
            group_out = run_cells(trace, [cfg for _, cfg in cells],
                                  policies, share_system=share_system,
                                  backend=backend, mesh=mesh)
            for (key, _), cell_res in zip(cells, group_out):
                results[key] = cell_res
        for key in order:       # keep the caller's cell order
            out[key] = results[key]
    return out


def run_sweep(traces: Union[Mapping[str, np.ndarray], Sequence[str]],
              base: SimConfig,
              update_intervals: Sequence[int],
              policies: Sequence[str] = DEFAULT_POLICIES,
              n_requests: int = 100_000,
              share_system: bool = True,
              ) -> Dict[CellKey, Dict[str, SimResult]]:
    """The ``update_interval`` grid (paper Figs. 4-6 x-axis); see
    :func:`run_grid`."""
    values = [int(i) for i in update_intervals]
    return run_grid(traces, base, "update_interval", values,
                    policies=policies, n_requests=n_requests,
                    share_system=share_system)


#: record keys an axis label may never shadow: the per-policy result
#: fields every record carries, plus the trace column
_RESERVED_RECORD_KEYS = frozenset(SimResult(policy="").to_dict()) | {"trace"}


def axis_column(axis: str) -> str:
    """The record column an axis is flattened under.  An axis whose name
    collides with a :meth:`SimResult.to_dict` field (e.g. a future
    ``n_requests`` axis vs the ``n`` request counter's sibling fields) or
    with ``trace`` would be silently overwritten by the result dict —
    those are prefixed ``axis_<name>`` instead."""
    return axis if axis not in _RESERVED_RECORD_KEYS else f"axis_{axis}"


def sweep_records(grid: Dict[CellKey, Dict[str, SimResult]],
                  axis: str = "update_interval") -> List[dict]:
    """Flatten a :func:`run_grid`/:func:`run_sweep` grid into one record
    per (trace, cell, policy) — ready for CSV/JSON dumps or plotting.
    Per-cache tuple labels serialise as lists in JSON; the axis lands in
    column :func:`axis_column` (prefixed on a result-field collision)."""
    col = axis_column(axis)
    records = []
    for (name, label), cell in grid.items():
        for policy, res in cell.items():
            rec = {"trace": name, col: label}
            rec.update(res.to_dict())
            records.append(rec)
    return records
