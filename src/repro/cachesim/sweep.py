"""Policy x trace x update-interval sweep runner (paper Figs. 4-6 grids).

The paper's headline claim — FNA matching FNO's cost with an order of
magnitude fewer advertised bits — is established on multi-dimensional
sweeps: every policy, over every workload, across a range of
advertisement intervals.  The system evolution is policy-independent
(hash placement), so each (trace, update_interval) grid cell computes its
:class:`~repro.cachesim.systemstate.SystemTrace` exactly once and replays
every policy against it (via :func:`repro.cachesim.simulator.
run_policies`): a P-policy grid costs one system sweep per cell plus
P cheap replays, instead of P full simulations.

``update_interval`` is part of the SYSTEM configuration (it changes the
advertisement cadence and hence the indicators themselves), so cells
never share sweeps with each other — only policies within a cell do.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Sequence, Tuple, Union

import numpy as np

from repro.cachesim.simulator import SimConfig, SimResult, run_policies
from repro.cachesim.traces import get_trace

DEFAULT_POLICIES = ("fna", "fna_cal", "fno", "pi")


def run_sweep(traces: Union[Mapping[str, np.ndarray], Sequence[str]],
              base: SimConfig,
              update_intervals: Sequence[int],
              policies: Sequence[str] = DEFAULT_POLICIES,
              n_requests: int = 100_000,
              ) -> Dict[Tuple[str, int], Dict[str, SimResult]]:
    """Run the full grid; returns ``{(trace_name, interval): {policy:
    SimResult}}``.

    ``traces`` is either a mapping of name -> request array, or a
    sequence of :func:`~repro.cachesim.traces.get_trace` names generated
    at ``n_requests`` with ``base.seed``.
    """
    if not isinstance(traces, Mapping):
        traces = {name: get_trace(name, n_requests, seed=base.seed)
                  for name in traces}
    out: Dict[Tuple[str, int], Dict[str, SimResult]] = {}
    for name, trace in traces.items():
        for interval in update_intervals:
            cfg = dataclasses.replace(base, update_interval=int(interval))
            out[(name, int(interval))] = run_policies(
                trace, cfg, policies=policies)
    return out


def sweep_records(grid: Dict[Tuple[str, int], Dict[str, SimResult]]
                  ) -> List[dict]:
    """Flatten a :func:`run_sweep` grid into one record per (trace,
    interval, policy) — ready for CSV/JSON dumps or plotting."""
    records = []
    for (name, interval), cell in grid.items():
        for policy, res in cell.items():
            rec = {"trace": name, "update_interval": interval}
            rec.update(res.to_dict())
            records.append(rec)
    return records
