"""Decision-plan layer of the fast engine: pluggable policy-table
providers and cross-cell sharing for decision-side sweep axes.

The fast engine runs in three phases (see ``repro.cachesim.simulator``):

  1. SYSTEM SWEEP — the policy-independent
     :class:`~repro.cachesim.systemstate.SystemTrace`, computed once per
     (trace, system config);
  2. DECISION PLAN — this module: how a given (policy, subroutine)
     configuration turns the sweep's view history into per-request
     selections;
  3. REPLAY — vectorised table lookups + the scalar cost fold
     (``repro.cachesim.fastpath.accumulate_replay``).

Phase 2 is a REGISTRY of :class:`DecisionPlan` providers rather than an
``if/elif`` ladder: ``plan_for(cfg)`` returns the first registered plan
whose :meth:`~DecisionPlan.matches` accepts the configuration, or
``None`` when the configuration is outside every plan's budget (the
simulator then falls back to the reference loop).  The built-in registry,
in match order:

  ================  =====================================================
  ``fna_cal``       speculative segmented replay
                    (``repro.cachesim.fna_cal_fast``) — the one policy
                    whose state moves per probe outcome
  ``pi``            the perfect-information lower bound: a direct
                    vectorised replay (its "table" is the membership bit)
  ``hocs``          Algorithm 1 decision tables via the exact batched
                    mirror ``repro.core.batched.hocs_selection_tables``
  ``ds_pgm``        (version x pattern) tables in one batched
                    ``repro.core.batched.selection_tables`` call
                    (CS_FNA and CS_FNO)
  ``exhaustive``    the batched 2^n-subset enumeration
                    (``repro.core.batched.exhaustive_tables``, chunked;
                    n <= 12 — the full table budget)
  ``scalar``        the generic fallback: one scalar ``sim.alg`` call per
                    (version, pattern) — the ONLY remaining scalar table
                    loop.  No built-in (policy, subroutine, n <= 12)
                    combination reaches it any more; it stays registered
                    as the safety net for externally registered scalar
                    subroutines
  ================  =====================================================

Table plans memoise their ``[V * 2^n]`` selection-bitmask arrays on the
shared ``SystemTrace`` (``st.plan_cache``), keyed by the decision-side
configuration (costs, miss penalty, CS_FNO flag).  That cache is also the
hand-off point for CROSS-CELL sharing: a decision-side sweep axis (miss
penalty, access-cost vector, policy — anything that leaves
``SystemTrace.system_key`` unchanged) produces a group of cells that
differ only in their plan inputs, so :func:`run_cells` computes ONE
system sweep for the whole group and :func:`prefetch_tables` stacks every
ds_pgm-family (cell, policy) table build into a single
``repro.core.batched.selection_tables_cells`` evaluation.  A C-cell,
P-policy decision grid therefore costs one sweep + one stacked table
batch + C*P cheap replays instead of C*P full simulations.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.batched import MAX_EXHAUSTIVE_TABLE_CACHES

# 2^n table rows per version: past this the reference loop is the better
# deal for every provider (single source of truth for the fast engine)
MAX_TABLE_CACHES = 12


# ---------------------------------------------------------------------------
# Plan protocol
# ---------------------------------------------------------------------------

class DecisionPlan:
    """One policy family's replay strategy against a shared SystemTrace."""

    name = "?"

    def matches(self, cfg) -> bool:
        """Whether this plan covers ``cfg`` (policy, subroutine, budget)."""
        raise NotImplementedError

    def selections(self, sim, st) -> np.ndarray:
        """[N] int64 per-request selection bitmasks for ``sim`` against
        the shared sweep ``st`` — the committed (post-exploration) cache
        subset probed for each request, bit j = cache j.  This is the
        one-hop decision interface: the flat replay folds it into a
        SimResult below, and ``repro.cachesim.topology`` re-accounts the
        same masks under per-tier penalties/latencies."""
        raise NotImplementedError

    def replay(self, sim, st, res):
        """Phase 2+3: produce per-request selections for ``sim`` against
        the shared sweep ``st`` and fold them into ``res``."""
        from repro.cachesim.fastpath import accumulate_replay
        return accumulate_replay(res, st, self.selections(sim, st),
                                 list(sim.cfg.costs), sim.cfg.miss_penalty)


class TablePlan(DecisionPlan):
    """A plan whose decisions are a pure (view version, indication
    pattern) function — phase 2 builds ``[V * 2^n]`` selection bitmasks,
    phase 3 is a vectorised lookup.  Tables are memoised on
    ``st.plan_cache`` under :meth:`cache_key`, which is how the sweep
    runner's stacked prefetch hands them over."""

    def cache_key(self, cfg) -> tuple:
        """The decision-side configuration the tables depend on."""
        raise NotImplementedError

    def tables(self, sim, st) -> np.ndarray:
        """[V * 2^n] int64 selection bitmasks, row (v * 2^n + p)."""
        raise NotImplementedError

    def selections(self, sim, st) -> np.ndarray:
        cfg = sim.cfg
        key = self.cache_key(cfg)
        selm_tab = st.plan_cache.get(key)
        if selm_tab is None:
            selm_tab = self.tables(sim, st)
            st.plan_cache[key] = selm_tab
        k = 1 << st.n
        return selm_tab[st.ver_per_req * k + st.pats]            # [N]


# ---------------------------------------------------------------------------
# Built-in providers
# ---------------------------------------------------------------------------

class FnaCalSegmented(DecisionPlan):
    """The calibrated policy: per-probe EWMA state breaks the frozen-view
    invariant, so it replays via the speculate-and-commit segments of
    ``repro.cachesim.fna_cal_fast`` (whose speculation tables come from
    the same batched builders as the table plans below)."""

    name = "fna_cal"

    def matches(self, cfg) -> bool:
        if cfg.policy != "fna_cal":
            return False
        # the verification pass needs the batched subset enumeration;
        # past its budget the reference loop wins
        return cfg.alg != "exhaustive" or \
            cfg.n_caches <= MAX_EXHAUSTIVE_TABLE_CACHES

    def selections(self, sim, st) -> np.ndarray:
        from repro.cachesim.fna_cal_fast import fna_cal_selections
        return fna_cal_selections(sim, st)


class PiReplay(DecisionPlan):
    """PI accesses the cheapest cache truly holding x; hash placement
    means only the designated cache can — so membership IS the plan:
    probe the designated cache iff it truly holds x, nothing otherwise.
    The default selections-fold replay is bit-identical to a dedicated
    one: a single-cache mask costs exactly ``costs[dj]``, the empty mask
    exactly ``0.0 + miss_penalty == miss_penalty``."""

    name = "pi"

    def matches(self, cfg) -> bool:
        return cfg.policy == "pi"

    def selections(self, sim, st) -> np.ndarray:
        return np.where(st.in_dj, np.int64(1) << st.dj_all, np.int64(0))


class HocsTables(TablePlan):
    """Algorithm 1 on pooled homogeneous estimates, via the exact batched
    mirror (``repro.core.batched.hocs_selection_tables``).  The tables do
    not depend on the (homogeneous) cost level, so a costs-axis decision
    grid shares one build across its cells."""

    name = "hocs"

    def matches(self, cfg) -> bool:
        return cfg.policy == "hocs"

    def cache_key(self, cfg) -> tuple:
        return ("hocs", float(cfg.miss_penalty))

    def tables(self, sim, st) -> np.ndarray:
        from repro.core.batched import hocs_selection_tables
        return hocs_selection_tables(
            st.pi_v, st.nu_v, sim.cfg.miss_penalty).reshape(-1)


class DsPgmTables(TablePlan):
    """CS_FNA / CS_FNO with the DS_PGM subroutine — the batched JAX path
    (float64, bit-exact modulo the ~1e-12 near-tie caveat documented on
    ``repro.core.batched.selection_tables``)."""

    name = "ds_pgm"

    def matches(self, cfg) -> bool:
        return cfg.policy in ("fna", "fno") and cfg.alg == "ds_pgm"

    def cache_key(self, cfg) -> tuple:
        return ("ds_pgm", cfg.policy == "fno", tuple(cfg.costs),
                float(cfg.miss_penalty))

    def tables(self, sim, st) -> np.ndarray:
        from repro.core.batched import selection_tables
        cfg = sim.cfg
        n = st.n
        k = 1 << n
        pi_mat, nu_mat = st.pi_v, st.nu_v
        v_count = pi_mat.shape[0]
        # pad V to a power-of-two bucket: XLA compiles per shape, and
        # bucketing makes shapes recur across runs (padding rows are
        # copies of the last version; their masks are discarded)
        vpad = 1 << max(4, (v_count - 1).bit_length())
        if vpad > v_count:
            pi_mat = np.concatenate(
                [pi_mat, np.repeat(pi_mat[-1:], vpad - v_count, 0)])
            nu_mat = np.concatenate(
                [nu_mat, np.repeat(nu_mat[-1:], vpad - v_count, 0)])
        mask = selection_tables(list(cfg.costs), pi_mat, nu_mat,
                                cfg.miss_penalty,
                                fno=(cfg.policy == "fno"))
        pow2 = 1 << np.arange(n, dtype=np.int64)
        return (mask.reshape(-1, n)[:v_count * k] @ pow2).astype(np.int64)


class ExhaustiveTables(TablePlan):
    """CS_FNA / CS_FNO with the exact Eq. (10) subroutine — the batched
    2^n-subset enumeration (IEEE operation-order-exact vs the scalar
    loop).  Covers the full table budget (n <= 12 =
    ``MAX_EXHAUSTIVE_TABLE_CACHES``): the build is chunked so the
    [rows, 2^n] subset matrix stays memory-bounded however large the
    version history grows — ``chunk_rows`` overrides the default
    ~32 MB auto-sizing (None) for callers tuning the working set."""

    name = "exhaustive"
    #: rows per subset-DP chunk; None = auto-size from the chunk budget
    #: (``repro.core.batched.EXHAUSTIVE_CHUNK_ELEMS``)
    chunk_rows = None

    def matches(self, cfg) -> bool:
        return cfg.policy in ("fna", "fno") and cfg.alg == "exhaustive" \
            and cfg.n_caches <= MAX_EXHAUSTIVE_TABLE_CACHES

    def cache_key(self, cfg) -> tuple:
        return ("exhaustive", cfg.policy == "fno", tuple(cfg.costs),
                float(cfg.miss_penalty))

    def tables(self, sim, st) -> np.ndarray:
        from repro.core.batched import exhaustive_tables
        cfg = sim.cfg
        return exhaustive_tables(list(cfg.costs), st.pi_v, st.nu_v,
                                 cfg.miss_penalty,
                                 fno=(cfg.policy == "fno"),
                                 chunk=self.chunk_rows).reshape(-1)


class ScalarTables(TablePlan):
    """Generic fallback: one scalar subroutine call per (version,
    pattern).  The only scalar table loop left in the fast engine.  Now
    that the exhaustive provider covers the whole n <= 12 table budget,
    no built-in (policy, subroutine) combination reaches this plan; it
    stays registered as the safety net for externally registered scalar
    subroutines (any ``sim.alg`` without a batched twin)."""

    name = "scalar"

    def matches(self, cfg) -> bool:
        return cfg.policy in ("fna", "fno")

    def cache_key(self, cfg) -> tuple:
        return ("scalar", cfg.alg, cfg.policy == "fno", tuple(cfg.costs),
                float(cfg.miss_penalty))

    def tables(self, sim, st) -> np.ndarray:
        cfg = sim.cfg
        costs = list(cfg.costs)
        M = cfg.miss_penalty
        n = st.n
        k = 1 << n
        fno = cfg.policy == "fno"
        v_count = st.pi_v.shape[0]
        sel = np.empty(v_count * k, dtype=np.int64)
        for v in range(v_count):
            pi, nu = st.pi_v[v], st.nu_v[v]
            for p in range(k):
                if fno:
                    pos = [j for j in range(n) if (p >> j) & 1]
                    chosen = []
                    if pos:
                        sub = sim.alg([costs[j] for j in pos],
                                      [float(pi[j]) for j in pos], M)
                        chosen = [pos[t] for t in sub]
                else:
                    rhos = [float(pi[j]) if (p >> j) & 1 else float(nu[j])
                            for j in range(n)]
                    chosen = sim.alg(costs, rhos, M)
                m = 0
                for j in chosen:
                    m |= 1 << j
                sel[v * k + p] = m
        return sel


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

#: ordered provider registry — first match wins; the scalar fallback last
PROVIDERS: List[DecisionPlan] = [
    FnaCalSegmented(), PiReplay(), HocsTables(), DsPgmTables(),
    ExhaustiveTables(), ScalarTables(),
]


def register_provider(plan: DecisionPlan, *, index: int = 0) -> None:
    """Install a custom provider (at ``index``, so it can shadow a
    built-in; the scalar fallback should stay last)."""
    PROVIDERS.insert(index, plan)


def plan_for(cfg) -> Optional[DecisionPlan]:
    """The first registered plan covering ``cfg``, or ``None`` when the
    configuration is outside every plan's budget (the simulator falls
    back to the reference loop)."""
    if cfg.n_caches > MAX_TABLE_CACHES:
        return None
    for plan in PROVIDERS:
        if plan.matches(cfg):
            return plan
    return None


# ---------------------------------------------------------------------------
# Cross-cell sharing for decision-side sweep axes
# ---------------------------------------------------------------------------

def table_keys_for(cfgs: Sequence, policies: Sequence[str]):
    """Every distinct ``plan_cache`` key a (cells x policies) panel will
    consult, in first-use order — the preload/flush manifest of the
    artifact store (``repro.cachesim.store``)."""
    keys = []
    seen = set()
    for cfg in cfgs:
        for p in policies:
            pcfg = dataclasses.replace(cfg, policy=p)
            plan = plan_for(pcfg)
            if not isinstance(plan, TablePlan):
                continue
            key = plan.cache_key(pcfg)
            if key not in seen:
                seen.add(key)
                keys.append(key)
    return keys


def _plan_jobs(system, cfgs, policies, plan_cls):
    """Unseeded (cache key, configured pcfg) pairs dispatching to
    ``plan_cls``, deduplicated in first-use order."""
    jobs = []
    seen = set()
    for cfg in cfgs:
        for p in policies:
            pcfg = dataclasses.replace(cfg, policy=p)
            plan = plan_for(pcfg)
            if type(plan) is not plan_cls:
                continue
            key = plan.cache_key(pcfg)
            if key in system.plan_cache or key in seen:
                continue
            seen.add(key)
            jobs.append((key, pcfg))
    return jobs


def _prefetch_exhaustive(system, cfgs, policies) -> None:
    """Stack exhaustive-subroutine table builds across decision cells:
    one chunked subset-DP pass per (costs, fno) group covers every
    penalty cell (``repro.core.batched.exhaustive_tables_cells``), so a
    penalty grid pays the 2^n enumeration once instead of per cell."""
    from repro.core.batched import exhaustive_tables_cells
    groups: Dict[tuple, list] = {}
    for key, pcfg in _plan_jobs(system, cfgs, policies, ExhaustiveTables):
        groups.setdefault((tuple(pcfg.costs), pcfg.policy == "fno"),
                          []).append((key, float(pcfg.miss_penalty)))
    for (costs, fno), jobs in groups.items():
        if len(jobs) < 2:    # a single build gains nothing from stacking
            continue
        tabs = exhaustive_tables_cells(
            list(costs), system.pi_v, system.nu_v,
            [m for _, m in jobs], fno=fno)
        for (key, _), tab in zip(jobs, tabs):
            system.plan_cache[key] = tab.reshape(-1)


def _prefetch_hocs(system, cfgs, policies) -> None:
    """Stack HOCS table builds across decision cells: the pooled
    estimates are penalty-independent, so one
    ``repro.core.batched.hocs_selection_tables_cells`` call covers every
    penalty cell of the group."""
    from repro.core.batched import hocs_selection_tables_cells
    jobs = _plan_jobs(system, cfgs, policies, HocsTables)
    if len(jobs) < 2:        # a single build gains nothing from stacking
        return
    tabs = hocs_selection_tables_cells(
        system.pi_v, system.nu_v, [pcfg.miss_penalty for _, pcfg in jobs])
    for (key, _), tab in zip(jobs, tabs):
        system.plan_cache[key] = tab.reshape(-1)


def prefetch_tables(system, cfgs: Sequence, policies: Sequence[str],
                    *, backend: str = "numpy", mesh=None) -> None:
    """Stack every stackable (cell, policy) table build of a decision-
    side group into one batched call per provider family, seeding
    ``system.plan_cache`` so the per-cell replays become pure lookups:
    ds_pgm via ``repro.core.batched.selection_tables_cells``, the
    exhaustive subroutine via ``exhaustive_tables_cells`` (per (costs,
    fno) group), and HOCS via ``hocs_selection_tables_cells``.

    Row-level independence of each batched builder makes every stacked
    slice bit-identical to the per-cell build it replaces.

    ``backend="jax"`` routes the ds_pgm stacked build through the jitted
    ``selection_tables_cells_jax`` kernel instead — optionally sharded
    over the cell axis of ``mesh`` (``launch.mesh.make_sweep_mesh``).
    Unlike the NumPy path it stacks even a SINGLE job: the jit dispatch
    is the same either way, and seeding the cache keeps every cell's
    tables on the one compiled path.  Masks can differ from the NumPy
    build only inside the ~1e-12 near-tie dead-band (FMA contraction;
    see ``selection_tables_cells_jax``).  The exhaustive/HOCS stacks
    always evaluate on the NumPy oracle.
    """
    _prefetch_exhaustive(system, cfgs, policies)
    _prefetch_hocs(system, cfgs, policies)
    ds_plan = next(p for p in PROVIDERS if isinstance(p, DsPgmTables))
    jobs = []                # (cache key, costs, penalty, fno)
    seen = set()
    for cfg in cfgs:
        for p in policies:
            pcfg = dataclasses.replace(cfg, policy=p)
            if not isinstance(plan_for(pcfg), DsPgmTables):
                continue
            key = ds_plan.cache_key(pcfg)
            if key in system.plan_cache or key in seen:
                continue
            seen.add(key)
            jobs.append((key, tuple(pcfg.costs),
                         float(pcfg.miss_penalty), p == "fno"))
    if not jobs:
        return
    if backend == "jax":
        from repro.core.batched import selection_tables_cells_jax
        masks = selection_tables_cells_jax(
            [j[1] for j in jobs], system.pi_v, system.nu_v,
            [j[2] for j in jobs], [j[3] for j in jobs],
            mesh=mesh)                                   # [C, V, 2^n, n]
    else:
        if len(jobs) < 2:    # a single build gains nothing from stacking
            return
        from repro.core.batched import selection_tables_cells
        masks = selection_tables_cells(
            [j[1] for j in jobs], system.pi_v, system.nu_v,
            [j[2] for j in jobs], [j[3] for j in jobs])  # [C, V, 2^n, n]
    n = system.n
    pow2 = 1 << np.arange(n, dtype=np.int64)
    for (key, *_), mask in zip(jobs, masks):
        system.plan_cache[key] = \
            (mask.reshape(-1, n) @ pow2).astype(np.int64)


def run_cells(trace: np.ndarray, cfgs: Sequence, policies: Sequence[str],
              share_system: bool = True, *, backend: str = "numpy",
              mesh=None, store=None, chunk_size: Optional[int] = None,
              spill=None) -> List[Dict]:
    """Run a policy panel over several decision-side cells that share one
    system evolution; returns ``[{policy: SimResult}]`` aligned with
    ``cfgs``.

    On the fast engine with ``share_system=True`` the policy-independent
    system sweep is computed EXACTLY ONCE for the whole group (all cells
    must share ``SystemTrace.system_key`` — ``repro.cachesim.sweep``
    groups cells accordingly) and the ds_pgm-family decision tables of
    every (cell, policy) are prefetched in one stacked batched call.
    ``share_system=False`` forces independent full runs (benchmarking the
    amortisation itself); the reference engine always runs full.

    ``store`` (an ``ArtifactStore``, a root path, or None) consults the
    content-addressed artifact store (``repro.cachesim.store``) before
    the sweep: a hit hydrates the stored ``SystemTrace`` (bit-identical
    replay) instead of computing, a miss computes and persists it.
    Decision tables are preloaded from the store under the same (trace
    digest, system key) and any freshly built ones are flushed back
    after the replays — on the NumPy backend only, so stored tables are
    always golden-oracle output (a JAX run still loads and benefits
    from them; its near-tie dead-band is documented in
    ``docs/engine.md``).

    ``backend="jax"`` builds the stacked tables with the jitted
    (optionally device-sharded) kernel — ``mesh=None`` auto-creates the
    sweep mesh when more than one device is visible (see
    :func:`prefetch_tables`).  The replay phase is unchanged either way.

    ``chunk_size`` streams every phase-1 sweep this call performs (the
    shared one and any per-cell fallback) through fixed-size trace
    slices; ``spill`` memmap-backs the shared sweep's per-request
    arrays.  Both are bit-identity-preserving — see
    ``SystemTrace.compute``.
    """
    from repro.cachesim.simulator import Simulator
    from repro.cachesim.store import as_store
    from repro.cachesim.systemstate import SystemTrace
    trace = np.asarray(trace, dtype=np.uint64)
    out: List[Dict] = [dict() for _ in cfgs]
    system = None
    share = share_system and bool(cfgs) and trace.shape[0] > 0 and \
        all(cfg.engine == "fast" for cfg in cfgs)
    store = as_store(store) if share else None
    digest = None
    preloaded = set()
    if backend == "jax" and mesh is None:
        from repro.launch.mesh import make_sweep_mesh
        mesh = make_sweep_mesh()
    if share:
        fastable = any(
            plan_for(dataclasses.replace(cfg, policy=p)) is not None
            for cfg in cfgs for p in policies)
        if fastable:
            sys_key = SystemTrace.system_key(cfgs[0])
            if store is not None:
                digest = store.trace_digest(trace)
                system = store.load_sweep(trace, sys_key,
                                          trace_digest=digest)
            if system is None:
                system = SystemTrace.compute(Simulator(cfgs[0]), trace,
                                             chunk_size=chunk_size,
                                             spill=spill)
                if store is not None:
                    store.save_sweep(system, trace_digest=digest)
            if store is not None and backend == "numpy":
                for key in table_keys_for(cfgs, policies):
                    tab = store.load_table(digest, sys_key, key)
                    if tab is not None:
                        system.plan_cache[key] = tab
                        preloaded.add(key)
            prefetch_tables(system, cfgs, policies,
                            backend=backend, mesh=mesh)
    for ci, cfg in enumerate(cfgs):
        for p in policies:
            sim = Simulator(dataclasses.replace(cfg, policy=p))
            out[ci][p] = sim.run(trace,
                                 system=system if share_system else None,
                                 chunk_size=chunk_size)
            if share_system and system is None:
                system = getattr(sim, "last_system", None)
    # flush tables built this run (prefetched or replay-built) so the
    # next warm run starts with every lookup already on disk
    if store is not None and digest is not None and \
            system is not None and backend == "numpy":
        for key, tab in system.plan_cache.items():
            if key not in preloaded:
                store.save_table(digest, system.key, key, tab)
    return out
