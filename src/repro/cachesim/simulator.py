"""Trace-driven multi-cache simulator (paper Sec. V).

System model:
  * n caches (LRU) with sizes C_j and access costs c_j; miss penalty M.
  * the controller places each (missed) item in a single designated cache,
    chosen by hashing the key — the load-balancing/content-maximising
    policy of Sec. V-A ("a missed item is placed in a single cache chosen
    by the controller"), which also makes cache dynamics identical across
    access policies (fair comparison).
  * each cache keeps a CBF for bookkeeping, advertises a compressed bitmap
    every ``update_interval`` insertions, and re-estimates (FP, FN) via
    Eqs. (7)-(8) every ``est_interval`` insertions.
  * the client runs CS_FNA / CS_FNO (Algorithm 2) with per-cache EWMA
    q-estimates (Eq. 9), or the PI lower bound.

Every request pays sum(c_j for j accessed) + M if no accessed cache holds
the item (the realised service cost; its mean is the paper's metric).

Engines
-------
``SimConfig.engine`` selects between two bit-exact implementations:

  * ``"reference"`` — the per-request scalar loop (the oracle).
  * ``"fast"``      — the shared-SystemTrace architecture: a
    policy-independent system sweep (``repro.cachesim.systemstate``)
    feeding per-policy replays (``repro.cachesim.fastpath`` for the
    model-based policies, ``repro.cachesim.fna_cal_fast`` for the
    calibrated one).

The fast architecture rests on one structural fact and two exact
invariants:

  S (shared system state): the controller places every missed request in
     its hash-designated cache, so the SYSTEM state — LRU contents, CBF
     counters, stale bitmaps, Eq. 7-8 estimates, Eq. 9 q-estimates — is
     the same for every policy.  Phase 1 therefore runs ONCE per (trace,
     system config) as a :class:`~repro.cachesim.systemstate.SystemTrace`
     and is reused across policies: :func:`run_policies` and
     ``repro.cachesim.sweep`` pay one sweep plus a cheap replay per
     policy.

  I1 (advertisement epochs): the client-visible STALE bitmaps only change
     when a cache advertises, which happens after ``update_interval``
     insertions into that cache.  Between two advertisement boundaries the
     indication I_j(x) of every request is a pure function of the frozen
     bitmap, so indications for a whole epoch slice are computed in one
     vectorised reduction over the precomputed hash indices.

  I2 (view versions): the client-side views (pi_j, nu_j) only move when
     ``(node.version, q_est.version)`` bumps — i.e. at FP/FN re-estimation
     (every ``est_interval`` insertions), at advertisements, and at
     q-epoch boundaries (every ``q_horizon`` requests).  Between bumps a
     model-based policy's decision depends on the request ONLY through the
     n-bit indication pattern, so there are at most 2^n distinct
     selections per view version; the fast engine memoises the full
     decision table per version (via the batched JAX ``ds_pgm_batched``
     path) and turns per-request policy calls into table lookups.

``fna_cal`` breaks I2 (its empirical EWMAs move on every probe outcome),
but its decisions still change only when a drifting rho crosses a DS_PGM
decision boundary, so it replays in speculate-and-commit segments —
frozen decision tables, exact batched EWMA trajectories, and a batched
float64 verification pass per segment (``repro.cachesim.fna_cal_fast``).
Everything else (LRU dynamics, CBF bookkeeping cadence, Eq. 7-9 updates,
cost accounting order) is replicated operation-for-operation, so the two
engines produce identical ``SimResult``s for every policy.  Both
subroutines run fast: DS_PGM through the batched prefix scan, exhaustive
through a batched 2^n-subset enumeration (chunked, bit-exact DP over
subset masks, n <= 12 like every table plan).  The only remaining
reference-engine fallback is cache counts beyond the table budget
(n > 12).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core import (
    CacheView,
    QEstimator,
    cs_fna,
    cs_fno,
    ds_pgm,
    exhaustive,
    hash_indices,
    optimal_k,
    perfect_information,
)
from repro.core.indicator import StaleIndicatorPair
from repro.cachesim import advert as _adv
from repro.cachesim.lru import LRUCache


@dataclass
class SimConfig:
    n_caches: int = 3
    # cache_size / bpe / update_interval / est_interval accept either one
    # scalar (every cache identical — the paper's Figs. 4-7 setups) or a
    # per-cache sequence of length n_caches (heterogeneous tiers, staggered
    # advertisement cadences, delayed-view caches; scenario regimes beyond
    # the paper).  ``cache_sizes``/``bpes``/``update_intervals``/
    # ``est_intervals`` expose the normalised per-cache tuples.
    cache_size: Union[int, Sequence[int]] = 10_000
    costs: Sequence[float] = (1.0, 2.0, 3.0)
    miss_penalty: float = 100.0
    bpe: Union[float, Sequence[float]] = 14.0
    update_interval: Union[int, Sequence[int]] = 1_000
    # ^ insertions between advertisements
    est_interval: Union[int, Sequence[int]] = 50
    # ^ insertions between FP/FN re-estimation
    # --- advertisement-event subsystem (repro.cachesim.advert; arXiv:
    # 2104.01386 / 2405.17801).  All five accept a scalar or a per-cache
    # sequence; ``advert_policies``/... expose the normalised tuples and
    # ``repro.cachesim.advert.resolve_advert`` the canonical spec -------
    advert_policy: Union[str, Sequence[str]] = "periodic"
    # ^ periodic (the paper's fixed cadence — exact legacy behaviour) |
    #   delta (same cadence, measured delta-vs-full bytes on the wire) |
    #   self_adjusting (drift-triggered under a token-bucket budget)
    advert_bandwidth: Union[float, Sequence[float]] = 0.0
    # ^ token-bucket refill, bytes per insertion (self_adjusting only)
    advert_burst: Union[float, Sequence[float]] = 0.0
    # ^ bucket capacity in bytes; 0 -> one full advertisement (m/8)
    advert_threshold: Union[float, Sequence[float]] = 0.05
    # ^ Eq. (7) predicted-FN drift that triggers an advertisement
    advert_check: Union[int, Sequence[int]] = 0
    # ^ insertions between drift checks; 0 -> the cache's est_interval
    q_horizon: int = 100              # Eq. (9) epoch T
    q_delta: float = 0.25             # Eq. (9) smoothing
    policy: str = "fna"               # fna | fna_cal | fno | pi | hocs
    # "hocs": Algorithm 1 (fully-homogeneous optimal) — requires identical
    # costs; uses pooled pi/nu estimates and accesses the r1* cheapest
    # positive + r0* cheapest negative caches.
    alg: str = "ds_pgm"               # ds_pgm | exhaustive (subroutine)
    engine: str = "fast"              # fast | reference (bit-exact twins
    # for every policy; fna_cal uses the speculative segmented replay of
    # repro.cachesim.fna_cal_fast — see module docstring)
    seed: int = 0
    # --- fna_cal (beyond-paper): empirical exclusion-probability feedback ---
    # Eq. (7) counts BITS, inflating FN by ~k when staleness concentrates in
    # few items; fna_cal corrects nu/pi with EWMA outcomes of its own probes
    # (plus epsilon-exploration so the estimate can't freeze).
    cal_gamma: float = 0.05
    cal_min_obs: int = 30
    cal_epsilon: float = 0.005

    def __post_init__(self):
        if len(self.costs) != self.n_caches:
            # synthesise a cost vector ONLY when ``costs`` was left at the
            # class default and the cache count moved away from it; an
            # EXPLICIT mismatch is a config typo and must fail loudly
            # (silently rewriting it ran scenarios with wrong costs)
            default = type(self).__dataclass_fields__["costs"].default
            if tuple(self.costs) != default:
                raise ValueError(
                    f"costs {tuple(self.costs)!r} has length "
                    f"{len(self.costs)}, expected n_caches={self.n_caches}; "
                    f"pass one cost per cache (a (1, 2, 3, ...) vector is "
                    f"only synthesised while costs is left at the class "
                    f"default {default})")
            self.costs = tuple(1.0 + (i % 3) for i in range(self.n_caches))
        # validate per-cache sequence lengths AND values eagerly — a
        # wrong-length sequence or a degenerate interval must fail at
        # construction, not deep inside a sweep
        for f in ("cache_sizes", "bpes", "update_intervals",
                  "est_intervals", "advert_policies", "advert_bandwidths",
                  "advert_bursts", "advert_thresholds", "advert_checks"):
            getattr(self, f)
        if self.q_horizon < 1:
            raise ValueError(
                f"q_horizon must be a positive epoch length, "
                f"got {self.q_horizon!r}")

    def _per_cache(self, value, cast, name: str, minimum=None) -> tuple:
        if isinstance(value, (list, tuple, np.ndarray)):
            vals = tuple(cast(v) for v in value)
            if len(vals) != self.n_caches:
                raise ValueError(
                    f"per-cache sequence {name}={value!r} has length "
                    f"{len(vals)}, expected n_caches={self.n_caches}")
        else:
            vals = (cast(value),) * self.n_caches
        if minimum is not None and any(v < minimum for v in vals):
            raise ValueError(
                f"{name}={value!r} must be >= {minimum} per cache")
        return vals

    @property
    def cache_sizes(self) -> tuple:
        return self._per_cache(self.cache_size, int, "cache_size", 1)

    @property
    def bpes(self) -> tuple:
        vals = self._per_cache(self.bpe, float, "bpe")
        if any(v <= 0 for v in vals):
            raise ValueError(f"bpe={self.bpe!r} must be > 0 per cache")
        return vals

    @property
    def update_intervals(self) -> tuple:
        return self._per_cache(self.update_interval, int,
                               "update_interval", 1)

    @property
    def est_intervals(self) -> tuple:
        return self._per_cache(self.est_interval, int, "est_interval", 1)

    # --- advertisement-event knobs (repro.cachesim.advert) ----------------

    @property
    def advert_policies(self) -> tuple:
        from repro.cachesim.advert import ADVERT_POLICIES
        vals = self._per_cache(self.advert_policy, str, "advert_policy")
        bad = [v for v in vals if v not in ADVERT_POLICIES]
        if bad:
            raise ValueError(
                f"unknown advert_policy {bad[0]!r}; "
                f"known: {ADVERT_POLICIES}")
        return vals

    @property
    def advert_bandwidths(self) -> tuple:
        return self._per_cache(self.advert_bandwidth, float,
                               "advert_bandwidth", 0.0)

    @property
    def advert_bursts(self) -> tuple:
        return self._per_cache(self.advert_burst, float, "advert_burst",
                               0.0)

    @property
    def advert_thresholds(self) -> tuple:
        return self._per_cache(self.advert_threshold, float,
                               "advert_threshold", 0.0)

    @property
    def advert_checks(self) -> tuple:
        return self._per_cache(self.advert_check, int, "advert_check", 0)


@dataclass
class SimResult:
    policy: str
    n_requests: int = 0
    total_cost: float = 0.0
    hits: int = 0
    pos_accesses: int = 0
    neg_accesses: int = 0
    # designated-cache indicator quality (Fig. 1 measurement)
    fn_events: int = 0
    fn_opportunities: int = 0
    fp_events: int = 0
    fp_opportunities: int = 0
    resident: int = 0

    @property
    def mean_cost(self) -> float:
        return self.total_cost / max(self.n_requests, 1)

    @property
    def hit_ratio(self) -> float:
        return self.hits / max(self.n_requests, 1)

    @property
    def fn_ratio(self) -> float:
        return self.fn_events / max(self.fn_opportunities, 1)

    @property
    def fp_ratio(self) -> float:
        return self.fp_events / max(self.fp_opportunities, 1)

    def to_dict(self) -> Dict:
        return {
            "policy": self.policy, "n": self.n_requests,
            "mean_cost": round(self.mean_cost, 4),
            "hit_ratio": round(self.hit_ratio, 4),
            "fn_ratio": round(self.fn_ratio, 5),
            "fp_ratio": round(self.fp_ratio, 5),
            "pos_accesses": self.pos_accesses, "neg_accesses": self.neg_accesses,
        }


class _CacheNode:
    def __init__(self, size: int, bpe: float, seed: int,
                 update_interval: int, est_interval: int,
                 advert: tuple = ("periodic", 0.0, 0.0, 0.0, 0)):
        self.lru = LRUCache(size)
        m = int(bpe * size)
        k = optimal_k(bpe)
        self.ind = StaleIndicatorPair(m, k, seed=seed)
        self.update_interval = update_interval
        self.est_interval = est_interval
        # resolved advert spec (repro.cachesim.advert.resolve_advert):
        # (policy, bandwidth bytes/insertion, burst bytes, threshold,
        # check interval)
        (self.adv_policy, self.adv_bandwidth, self.adv_burst,
         self.adv_threshold, self.check_interval) = advert
        self.adv_tokens = float(self.adv_burst)   # bucket starts full
        self.advert_events: List = []             # [(insertion ord, bytes)]
        self.version = 0  # bumped whenever fp/fn estimates change
        self._since_adv = 0
        self._since_est = 0
        self._since_chk = 0
        self._n_ins = 0
        # scalar-lookup memo, bounded: an unbounded per-key memo leaks
        # hundreds of MB on recency-heavy million-request runs (~250k
        # fresh ids per cache).  hash_indices is deterministic, so
        # dropping entries never changes results — the memo is cleared
        # whenever it outgrows a small multiple of the cache size (the
        # working set a scalar caller can actually re-hit).
        self._idx_memo: Dict[int, np.ndarray] = {}
        self._idx_memo_cap = max(2 * int(size), 1024)
        self.ind.advertise()

    def _idx(self, key: int) -> np.ndarray:
        r = self._idx_memo.get(key)
        if r is None:
            r = hash_indices(np.asarray([key], dtype=np.uint64),
                             self.ind.cbf.k, self.ind.cbf.m, self.ind.cbf.seed)[0]
            if len(self._idx_memo) >= self._idx_memo_cap:
                self._idx_memo.clear()
            self._idx_memo[key] = r
        return r

    def stale_query(self, key: int) -> bool:
        return bool(np.all(self.ind.stale[self._idx(key)]))

    def insert(self, key: int, idx: Optional[np.ndarray] = None) -> bool:
        """Controller placement: LRU put + CBF bookkeeping + periodic
        advertisement / estimation driven by insertions.  Returns True when
        the FP/FN estimates changed (``version`` bumped).  ``idx`` lets the
        caller supply the key's precomputed ``hash_indices`` row (the
        reference loop already holds one per request), bypassing the memo.
        """
        inserted, evicted = self.lru.put(key)
        if not inserted:
            return False
        c = self.ind.cbf
        if idx is None:
            idx = self._idx(key)
        c.counters[idx] = np.minimum(c.counters[idx].astype(np.int32) + 1, 255)
        if evicted is not None:
            eidx = self._idx(evicted)
            c.counters[eidx] = np.maximum(c.counters[eidx].astype(np.int32) - 1, 0)
        self._since_adv += 1
        self._since_est += 1
        self._n_ins += 1
        bumped = False
        if self._since_est >= self.est_interval:
            self.ind.estimate_rates()
            self._since_est = 0
            self.version += 1
            bumped = True
        # advertisement decision (repro.cachesim.advert): periodic/delta
        # fire on the fixed insertion cadence; self_adjusting on drift
        # within its token-bucket budget at the check cadence
        if self.adv_policy == "self_adjusting":
            self._since_chk += 1
            if self._since_chk >= self.check_interval:
                self.adv_tokens = _adv.refill(
                    self.adv_tokens, self.adv_burst, self.adv_bandwidth,
                    self.check_interval)
                self._since_chk = 0
                cost = _adv.self_adjusting_decision(
                    self.ind, self.adv_tokens, self.adv_threshold)
                if cost is not None:
                    self.adv_tokens -= cost
                    self._advertise_event(cost)
                    bumped = True
        elif self._since_adv >= self.update_interval:
            self._advertise_event(_adv.advert_cost(self.ind,
                                                   self.adv_policy))
            bumped = True
        return bumped

    def _advertise_event(self, cost: float) -> None:
        """Advertise now: publish the bitmap, reset the staleness
        estimates, and record the (insertion ordinal, bytes) event."""
        self.ind.advertise()
        # a fresh advertisement resets the staleness estimates
        self.ind.estimate_rates()
        self._since_adv = 0
        self._since_est = 0
        self.version += 1
        self.advert_events.append((self._n_ins, float(cost)))


class Simulator:
    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        sizes, bpes = cfg.cache_sizes, cfg.bpes
        upd, est = cfg.update_intervals, cfg.est_intervals
        adv = _adv.resolve_advert(cfg)
        self.nodes = [
            _CacheNode(sizes[j], bpes[j], seed=cfg.seed * 1000 + j,
                       update_interval=upd[j], est_interval=est[j],
                       advert=adv[j])
            for j in range(cfg.n_caches)
        ]
        self.q_est = [QEstimator(cfg.q_horizon, cfg.q_delta)
                      for _ in range(cfg.n_caches)]
        self.alg = {"ds_pgm": ds_pgm, "exhaustive": exhaustive}[cfg.alg]

    def _designated(self, key: int) -> int:
        """The single cache the controller places (and measures) ``key`` in."""
        return int(key) % self.cfg.n_caches

    def _designated_batch(self, keys: np.ndarray) -> np.ndarray:
        """Vectorised :meth:`_designated` for the fast engine."""
        return (np.asarray(keys, dtype=np.uint64)
                % np.uint64(self.cfg.n_caches)).astype(np.int64)

    def _refresh_views(self):
        """Recompute per-cache (pi, nu) only when fp/fn/q estimates moved."""
        from repro.core.model import exclusion_probabilities, hit_ratio_from_q
        for j, nd in enumerate(self.nodes):
            ver = (nd.version, self.q_est[j].version)
            if self._view_ver[j] != ver:
                fp, fn, q = nd.ind.fp_est, nd.ind.fn_est, self.q_est[j].value
                h = hit_ratio_from_q(q, fp, fn)
                self._pi[j], self._nu[j] = exclusion_probabilities(h, fp, fn)
                self._view_ver[j] = ver

    def run(self, trace: np.ndarray, result: Optional[SimResult] = None,
            system=None, chunk_size: Optional[int] = None,
            spill=None) -> SimResult:
        """Simulate ``trace``.  ``system`` optionally supplies a shared
        :class:`~repro.cachesim.systemstate.SystemTrace` computed by an
        earlier fast run over the same (trace, system config) — the sweep
        is then skipped and only the per-policy replay runs.  After a fast
        run, the artifact is published as ``self.last_system``.

        ``chunk_size``/``spill`` stream the fast engine's phase-1 sweep
        through fixed-size trace slices (bit-identical results, bounded
        working set — see ``SystemTrace.compute``); the per-request
        reference loop is already O(1) in the trace and ignores both."""
        cfg = self.cfg
        res = result or SimResult(policy=cfg.policy)
        trace = np.asarray(trace, dtype=np.uint64)
        self._pi = [1.0] * cfg.n_caches
        self._nu = [1.0] * cfg.n_caches
        self._view_ver = [None] * cfg.n_caches
        if cfg.engine not in ("fast", "reference"):
            raise ValueError(f"unknown engine {cfg.engine!r}")
        if cfg.engine == "fast":
            # run_fast owns the table-budget fallbacks (n beyond the
            # DS_PGM table or exhaustive-enumeration limits drops to the
            # reference loop transparently)
            from repro.cachesim.fastpath import run_fast
            return run_fast(self, trace, res, system=system,
                            chunk_size=chunk_size, spill=spill)
        return self._run_reference(trace, res)

    def _run_reference(self, trace: np.ndarray, res: SimResult,
                       record: Optional[dict] = None) -> SimResult:
        """The seed per-request scalar loop — the bit-exact oracle.

        ``record``, when given a dict, is filled with the loop's
        per-request observables — ``selm`` (committed post-exploration
        selection bitmask), ``in_dj`` (designated-cache residency),
        ``pats`` (indication-pattern bitmask) and ``dj`` (designated
        cache index) — without altering any computation.  This is how
        ``repro.cachesim.topology`` runs its reference path: the same
        oracle loop per tier, re-accounted under per-tier knobs."""
        cfg = self.cfg
        # view state is (re-)initialised here, not only in run(), so the
        # recording path can drive the oracle loop directly
        self._pi = [1.0] * cfg.n_caches
        self._nu = [1.0] * cfg.n_caches
        self._view_ver = [None] * cfg.n_caches
        costs = list(cfg.costs)
        n = cfg.n_caches
        M = cfg.miss_penalty
        nodes = self.nodes
        # fna_cal empirical estimators (miss prob given indication, per cache).
        # Optimistic init: when FP+FN >= ~1 the indicator is uninformative and
        # h is UNIDENTIFIABLE from (q, FP, FN) — Eq. (1) inversion clamps to
        # h=0, nu=1 and no model-based policy ever probes.  Optimism under
        # uncertainty bootstraps the empirical estimator out of that fixed
        # point (see EXPERIMENTS.md §Perf R-series).
        cal = cfg.policy == "fna_cal"
        nu_emp = [0.90] * n
        pi_emp = [0.5] * n
        nu_obs = [0] * n
        pi_obs = [0] * n
        g = cfg.cal_gamma
        rng_cal = np.random.default_rng(cfg.seed + 12345)
        eps_draws = rng_cal.random(trace.shape[0]) if cal else None
        eps_pick = rng_cal.integers(0, n, trace.shape[0]) if cal else None
        # vectorised stale-query indices for the whole trace, per cache
        idx_all = [hash_indices(trace, nd.ind.cbf.k, nd.ind.cbf.m, nd.ind.cbf.seed)
                   for nd in nodes]
        is_pi = cfg.policy == "pi"
        is_fna = cfg.policy == "fna"
        alg = self.alg
        if record is not None:
            Nr = trace.shape[0]
            record["selm"] = np.zeros(Nr, dtype=np.int64)
            record["in_dj"] = np.zeros(Nr, dtype=bool)
            record["pats"] = np.zeros(Nr, dtype=np.int64)
            record["dj"] = np.zeros(Nr, dtype=np.int64)
        for i in range(trace.shape[0]):
            x = int(trace[i])
            indications = [bool(nodes[j].ind.stale[idx_all[j][i]].all())
                           for j in range(n)]
            for qe, ind in zip(self.q_est, indications):
                qe.observe(ind)
            # --- indicator-quality measurement on the designated cache ---
            dj = self._designated(x)
            in_dj = x in nodes[dj].lru
            if in_dj:
                res.fn_opportunities += 1
                res.fn_events += int(not indications[dj])
                res.resident += 1
            else:
                res.fp_opportunities += 1
                res.fp_events += int(indications[dj])
            # --- selection ---
            if is_pi:
                sel = perfect_information(costs, [x in nd.lru for nd in nodes])
            else:
                self._refresh_views()
                if cfg.policy == "fna_cal":
                    # blend: model-based (Eqs. 7-9) until enough probe
                    # outcomes; switch to the empirical EWMA immediately when
                    # the indicator is uninformative (FP+FN ~ 1)
                    rhos = []
                    for j in range(n):
                        uninformative = (nodes[j].ind.fp_est +
                                         nodes[j].ind.fn_est) >= 0.95
                        if indications[j]:
                            use_emp = pi_obs[j] >= cfg.cal_min_obs or uninformative
                            r = pi_emp[j] if use_emp else self._pi[j]
                        else:
                            use_emp = nu_obs[j] >= cfg.cal_min_obs or uninformative
                            r = nu_emp[j] if use_emp else self._nu[j]
                        rhos.append(r)
                    sel = alg(costs, rhos, M)
                    if eps_draws[i] < cfg.cal_epsilon:  # forced exploration
                        jx = int(eps_pick[i])
                        if jx not in sel:
                            sel = sorted(sel + [jx])
                elif cfg.policy == "hocs":  # Algorithm 1 (homogeneous)
                    pos = [j for j in range(n) if indications[j]]
                    neg = [j for j in range(n) if not indications[j]]
                    pi_h = sum(self._pi) / n
                    nu_h = sum(self._nu) / n
                    from repro.core import hocs_fna as _hocs
                    r0, r1 = _hocs(len(pos), n, pi_h, nu_h, M)
                    sel = sorted(pos[:r1] + neg[:r0])
                elif is_fna:  # Algorithm 2: rho = pi on positive, nu on negative
                    rhos = [self._pi[j] if indications[j] else self._nu[j]
                            for j in range(n)]
                    sel = alg(costs, rhos, M)
                else:       # FNO: positive-indication caches only
                    pos = [j for j in range(n) if indications[j]]
                    if pos:
                        sub = alg([costs[j] for j in pos],
                                  [self._pi[j] for j in pos], M)
                        sel = [pos[t] for t in sub]
                    else:
                        sel = []
                if cal:  # feed probe outcomes back into the estimators
                    for j in sel:
                        absent = x not in nodes[j].lru
                        if indications[j]:
                            pi_emp[j] = (1 - g) * pi_emp[j] + g * absent
                            pi_obs[j] += 1
                        else:
                            nu_emp[j] = (1 - g) * nu_emp[j] + g * absent
                            nu_obs[j] += 1
            if record is not None:
                record["in_dj"][i] = in_dj
                record["dj"][i] = dj
                record["pats"][i] = sum(1 << j for j in range(n)
                                        if indications[j])
                record["selm"][i] = sum(1 << j for j in sel)
            # --- realised cost ---
            cost = sum(costs[j] for j in sel)
            hit = any(x in nodes[j].lru for j in sel)
            if not hit:
                cost += M
            res.total_cost += cost
            res.hits += int(hit)
            res.pos_accesses += sum(1 for j in sel if indications[j])
            res.neg_accesses += sum(1 for j in sel if not indications[j])
            res.n_requests += 1
            # --- system update: fetch-and-place into the designated cache ---
            # reuse the request's precomputed hash row (bit-exact by
            # construction) so the scalar memo only ever sees evictions
            nodes[dj].insert(x, idx=idx_all[dj][i])
        # advert-event totals ride as plain attributes (NOT dataclass
        # fields — the golden harness serialises every SimResult field and
        # pre-existing golden files must stay byte-identical)
        res.advert_events = (getattr(res, "advert_events", 0) +
                             sum(len(nd.advert_events) for nd in nodes))
        res.advert_bytes = (getattr(res, "advert_bytes", 0.0) +
                            sum(b for nd in nodes
                                for _, b in nd.advert_events))
        return res


def run_policies(trace: np.ndarray, base: SimConfig,
                 policies: Sequence[str] = ("fna", "fno", "pi"),
                 share_system: bool = True) -> Dict[str, SimResult]:
    """Run several policies over the same trace (independent sim instances —
    cache dynamics are identical by construction).

    On the fast engine the policy-independent system sweep is computed
    exactly once and every policy only pays its decision-plan/replay
    phase (the single-cell case of
    :func:`repro.cachesim.engine.run_cells`; the sweep runner extends the
    same sharing across decision-side grid cells).  Pass
    ``share_system=False`` to force per-policy full runs (benchmarking)."""
    from repro.cachesim.engine import run_cells
    return run_cells(trace, [base], policies, share_system=share_system)[0]
