"""Real-trace ingestion: external request logs -> simulator request arrays.

The paper's headline results (Figs. 3-7) are established on *measured*
traces — Wiki, Gradle, Scarab, F2 — and the journal version
(arXiv:2203.09119) plus the bandwidth-constrained follow-up
(arXiv:2104.01386) lean even harder on measured workloads.  This module
turns the request-log shapes that family of papers uses into the exact
``np.int64`` request-array contract the synthetic generators
(``repro.cachesim.traces``) emit, so every scenario / sweep / golden
machinery runs unchanged on real logs.

Formats
-------
  * ``"keys"`` — one request key per line (the wiki-access-log shape
    after URL extraction).  Blank lines and ``#`` comments are skipped.
  * ``"csv"``  — delimited rows with a configurable key column (the
    CDN-log shape: timestamp, object id, size, ...).  ``key_column`` is
    either a 0-based index (headerless file) or a column NAME, in which
    case the first row is read as the header.

Both formats are gzip-transparent: a ``.gz`` suffix or the gzip magic
bytes switch decompression on automatically.  ``fmt=None`` infers from
the (possibly ``.gz``-stripped) suffix: ``.csv`` -> csv, else keys.

Ingestion pipeline
------------------
  1. parse the log into its raw key tokens (strings);
  2. densely remap keys to ``0..n_unique-1`` in FIRST-APPEARANCE order —
     deterministic, so the same file always yields the same array (the
     simulator hashes ids for placement, so dense ids lose nothing and
     keep memory bounded);
  3. cache the remapped array as ``<path>.<options-digest>.npz`` (one
     cache file per parse-option set), keyed by the source's SHA-256 —
     a million-request log parses once; the cache survives ``touch``
     (content hash, not mtime) and invalidates itself the moment the
     file's bytes change.  The cache lives next to the source by
     default; when ``REPRO_STORE`` is set it lives under the artifact
     store's ``traces/`` directory instead (fixing read-only source
     checkouts), with the next-to-source location kept as a read
     fallback so pre-existing caches still hit;
  4. optionally subsample: ``stride`` keeps every stride-th request,
     then ``head`` truncates — so a golden/smoke run can take a short
     but structure-preserving prefix of a long log.

:class:`TraceInfo` reports the Sec. V-B catalog/working-set quantities
that predict FNA behaviour — request count, unique-key count, and the
top-1% popularity concentration — for the array actually returned
(i.e. after subsampling).

Aliases
-------
:func:`register_trace_file` binds a short name (plus default loader
kwargs) to a path; ``traces.get_trace`` resolves registered aliases and
the literal ``file:<path>`` spelling, so scenarios bind to log files
exactly like they bind to generators (see ``docs/scenarios.md``).
"""
from __future__ import annotations

import gzip
import hashlib
import io
import os
import zipfile
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Optional, Tuple, Union

import numpy as np

#: the literal-path trace-name prefix understood by ``traces.get_trace``
FILE_PREFIX = "file:"

#: alias -> {"path": ..., **loader kwargs} (see register_trace_file)
TRACE_FILES: Dict[str, dict] = {}

_GZIP_MAGIC = b"\x1f\x8b"


# ---------------------------------------------------------------------------
# TraceInfo: the Sec. V-B catalog / working-set statistics
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TraceInfo:
    """Catalog statistics of one loaded request array."""
    path: str                 # source file (or "<array>" for in-memory)
    fmt: str                  # "keys" | "csv" | "synthetic"
    n_requests: int           # requests in the returned array
    n_unique: int             # distinct keys in the returned array
    n_requests_file: int      # requests in the full file (pre-subsample)
    top1pct_ids: int          # ceil(1% of the catalog), >= 1
    top1pct_share: float      # fraction of requests to those hottest ids

    def to_dict(self) -> dict:
        return {
            "path": self.path, "format": self.fmt,
            "n_requests": self.n_requests, "n_unique": self.n_unique,
            "n_requests_file": self.n_requests_file,
            "top1pct_ids": self.top1pct_ids,
            "top1pct_share": round(self.top1pct_share, 6),
        }


def trace_info(ids: np.ndarray, path: str = "<array>", fmt: str = "synthetic",
               n_requests_file: Optional[int] = None) -> TraceInfo:
    """Compute :class:`TraceInfo` for any request array (works on the
    synthetic generators' output too)."""
    ids = np.asarray(ids)
    n = int(ids.shape[0])
    _, counts = np.unique(ids, return_counts=True)
    n_unique = int(counts.shape[0])
    top = max(1, -(-n_unique // 100))           # ceil(n_unique / 100)
    hottest = np.sort(counts)[::-1][:top]
    share = float(hottest.sum() / n) if n else 0.0
    return TraceInfo(path=str(path), fmt=fmt, n_requests=n,
                     n_unique=n_unique,
                     n_requests_file=int(n_requests_file
                                         if n_requests_file is not None else n),
                     top1pct_ids=top, top1pct_share=share)


# ---------------------------------------------------------------------------
# Parsing
# ---------------------------------------------------------------------------

def _is_gzip(path: Path) -> bool:
    if path.suffix.lower() == ".gz":
        return True
    with open(path, "rb") as f:
        return f.read(2) == _GZIP_MAGIC


def _open_text(path: Path) -> io.TextIOBase:
    if _is_gzip(path):
        return io.TextIOWrapper(gzip.open(path, "rb"), encoding="utf-8")
    return open(path, "r", encoding="utf-8")


def infer_format(path: Union[str, Path]) -> str:
    """``.csv`` (optionally ``.gz``-wrapped) -> "csv", anything else ->
    "keys"."""
    p = Path(path)
    if p.suffix.lower() == ".gz":
        p = p.with_suffix("")
    return "csv" if p.suffix.lower() == ".csv" else "keys"


def _iter_keys(f: io.TextIOBase):
    """Yield raw key tokens of a ``keys``-format stream, one at a time."""
    for line in f:
        tok = line.strip()
        if not tok or tok.startswith("#"):
            continue
        yield tok


def _iter_csv(f: io.TextIOBase, key_column: Union[int, str],
              delimiter: str):
    """Yield raw key tokens of a ``csv``-format stream, one at a time."""
    import csv as _csv
    reader = _csv.reader(f, delimiter=delimiter)
    if isinstance(key_column, str):
        # the header is the first non-comment row (CDN exporters often
        # prepend banner lines)
        header = next((r for r in reader
                       if r and not r[0].startswith("#")), None)
        if header is None:
            return
        cols = [c.strip() for c in header]
        if key_column not in cols:
            raise ValueError(
                f"key column {key_column!r} not in CSV header {cols}")
        col = cols.index(key_column)
    else:
        col = int(key_column)
    for row in reader:
        if not row or row[0].startswith("#"):
            continue
        if col >= len(row):
            raise ValueError(
                f"CSV row {reader.line_num} has {len(row)} column(s), "
                f"key column is {col}")
        yield row[col].strip()


def _parse_keys(f: io.TextIOBase) -> list:
    return list(_iter_keys(f))


def _parse_csv(f: io.TextIOBase, key_column: Union[int, str],
               delimiter: str) -> list:
    return list(_iter_csv(f, key_column, delimiter))


def dense_remap(keys) -> np.ndarray:
    """Deterministically remap arbitrary key tokens to dense int64 ids in
    FIRST-APPEARANCE order (the id of a key is the number of distinct
    keys seen strictly before it)."""
    arr = np.asarray(keys)
    if arr.shape[0] == 0:
        return np.empty(0, dtype=np.int64)
    _, first, inv = np.unique(arr, return_index=True, return_inverse=True)
    order = np.argsort(first, kind="stable")    # uniques by first appearance
    rank = np.empty(order.shape[0], dtype=np.int64)
    rank[order] = np.arange(order.shape[0], dtype=np.int64)
    return rank[inv.reshape(-1)]


#: requests per chunk yielded by :func:`iter_trace_chunks` (and folded by
#: the streaming statistics pass) when the caller does not choose one
DEFAULT_CHUNK = 1 << 20


def _remap_chunk(tokens: list, mapping: Dict[str, int]) -> np.ndarray:
    """Dense-remap one chunk of raw key tokens against the cross-chunk
    ``mapping`` (token -> id, mutated in place).  Ids are assigned in
    global first-appearance order, so concatenating the chunk outputs is
    bit-identical to :func:`dense_remap` over the whole token stream.
    Only the chunk's DISTINCT tokens touch the dict — the bulk remap is
    a vectorised table lookup."""
    arr = np.asarray(tokens)
    uniq, first, inv = np.unique(arr, return_index=True, return_inverse=True)
    order = np.argsort(first, kind="stable")    # uniques by first appearance
    lut = np.empty(uniq.shape[0], dtype=np.int64)
    u_list = uniq.tolist()
    for ui in order.tolist():
        tok = u_list[ui]
        nid = mapping.get(tok)
        if nid is None:
            mapping[tok] = nid = len(mapping)
        lut[ui] = nid
    return lut[inv.reshape(-1)]


def iter_trace_chunks(path: Union[str, Path], fmt: Optional[str] = None,
                      key_column: Union[int, str] = 0, delimiter: str = ",",
                      chunk_size: int = DEFAULT_CHUNK,
                      remap: Optional[Dict[str, int]] = None):
    """Stream one log file as dense-remapped ``np.int64`` chunks.

    The generator holds O(chunk + catalog) memory — one chunk of raw
    tokens plus the token -> id dict — never the whole file.  The
    concatenation of the yielded chunks is BIT-IDENTICAL to
    :func:`parse_trace_file` on the same file: the dense remap is carried
    incrementally across chunks in first-appearance order.

    ``remap`` optionally supplies (and receives, mutated in place) the
    carry dict, so a caller can continue one id space across several
    files.

    A plain function returning the generator (not a generator itself) so
    a bad ``chunk_size`` raises HERE, at the call site, not at the first
    ``next()`` deep inside a consumer loop."""
    validate_chunk_size(chunk_size)
    return _iter_trace_chunks(Path(path), fmt, key_column, delimiter,
                              chunk_size, remap)


def validate_chunk_size(chunk_size) -> None:
    """Reject a non-int or < 1 ``chunk_size`` with a ValueError naming
    the argument (bool is an int subclass — reject it explicitly)."""
    if isinstance(chunk_size, bool) or \
            not isinstance(chunk_size, (int, np.integer)):
        raise ValueError(
            f"chunk_size must be an int >= 1, got {chunk_size!r}")
    if chunk_size < 1:
        raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")


def _iter_trace_chunks(path: Path, fmt, key_column, delimiter, chunk_size,
                       remap):
    fmt = fmt or infer_format(path)
    mapping: Dict[str, int] = {} if remap is None else remap
    with _open_text(path) as f:
        if fmt == "keys":
            tokens = _iter_keys(f)
        elif fmt == "csv":
            tokens = _iter_csv(f, key_column, delimiter)
        else:
            raise ValueError(f"unknown trace format {fmt!r}; "
                             f"known: 'keys', 'csv'")
        buf: list = []
        for tok in tokens:
            buf.append(tok)
            if len(buf) >= chunk_size:
                yield _remap_chunk(buf, mapping)
                buf.clear()
        if buf:
            yield _remap_chunk(buf, mapping)


def parse_trace_file(path: Union[str, Path], fmt: Optional[str] = None,
                     key_column: Union[int, str] = 0,
                     delimiter: str = ",") -> np.ndarray:
    """Parse + dense-remap one log file (no cache, no subsampling).
    Implemented on the chunked iterator, so the one-shot parse and the
    streaming path share one id assignment by construction."""
    chunks = list(iter_trace_chunks(path, fmt=fmt, key_column=key_column,
                                    delimiter=delimiter))
    if not chunks:
        return np.empty(0, dtype=np.int64)
    return np.concatenate(chunks)


def stream_trace_info(path: Union[str, Path], *, fmt: Optional[str] = None,
                      key_column: Union[int, str] = 0, delimiter: str = ",",
                      head: Optional[int] = None, stride: int = 1,
                      chunk_size: int = DEFAULT_CHUNK) -> TraceInfo:
    """:class:`TraceInfo` in ONE streaming pass — no full-array
    materialisation, O(chunk + catalog) memory.

    Matches ``load_trace_file(..., with_info=True)[1]`` exactly
    (including the top-1% concentration: the per-id request counts are
    the same integers, so the shares are the same floats).  Subsampling
    semantics mirror the loader: ``stride`` selects every stride-th
    request of the FULL file, then ``head`` truncates — ids still
    reflect full-file first-appearance order."""
    path = Path(path)
    fmt = fmt or infer_format(path)
    if stride < 1:
        raise ValueError(f"stride must be >= 1, got {stride}")
    counts = np.zeros(1024, dtype=np.int64)
    n_sel = 0           # requests selected into the subsampled view
    g = 0               # global request index (pre-subsample)
    for chunk in iter_trace_chunks(path, fmt=fmt, key_column=key_column,
                                   delimiter=delimiter,
                                   chunk_size=chunk_size):
        if stride > 1:
            first = (-g) % stride
            sel = chunk[first::stride]
            rank0 = (g + first) // stride   # global rank of sel[0]
        else:
            sel, rank0 = chunk, g
        if head is not None and sel.shape[0]:
            sel = sel[:max(0, min(sel.shape[0], int(head) - rank0))]
        if sel.shape[0]:
            bc = np.bincount(sel)
            if bc.shape[0] > counts.shape[0]:
                grown = np.zeros(max(2 * counts.shape[0], bc.shape[0]),
                                 dtype=np.int64)
                grown[:counts.shape[0]] = counts
                counts = grown
            counts[:bc.shape[0]] += bc
            n_sel += int(sel.shape[0])
        g += int(chunk.shape[0])            # keep counting for the file total
    nz = counts[counts > 0]
    n_unique = int(nz.shape[0])
    top = max(1, -(-n_unique // 100))       # ceil(n_unique / 100)
    hottest = np.sort(nz)[::-1][:top]
    share = float(hottest.sum() / n_sel) if n_sel else 0.0
    return TraceInfo(path=str(path), fmt=fmt, n_requests=n_sel,
                     n_unique=n_unique, n_requests_file=g,
                     top1pct_ids=top, top1pct_share=share)


# ---------------------------------------------------------------------------
# Content-hash .npz cache
# ---------------------------------------------------------------------------

#: in-process digest memo: (path, size, mtime_ns) -> sha256.  Repeated
#: loads of one unchanged log within a process (scenario run + TraceInfo
#: for the artifact, golden + display grids) hash the bytes once; any
#: on-disk change moves size/mtime and falls through to a fresh hash.
_SHA_MEMO: Dict[tuple, str] = {}


def file_sha256(path: Union[str, Path]) -> str:
    st = os.stat(path)
    memo_key = (str(path), st.st_size, st.st_mtime_ns)
    got = _SHA_MEMO.get(memo_key)
    if got is not None:
        return got
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    _SHA_MEMO[memo_key] = digest = h.hexdigest()
    return digest


def _cache_path(path: Path, cache_dir: Optional[Union[str, Path]],
                parse_key: str) -> Path:
    # one cache file PER parse-option set (short option digest in the
    # name), so e.g. two key columns of one CSV coexist instead of
    # thrashing a single slot
    opt = hashlib.sha256(parse_key.encode()).hexdigest()[:8]
    name = f"{path.name}.{opt}.npz"
    if cache_dir is not None:
        return Path(cache_dir) / name
    return path.with_name(name)


def _cache_candidates(path: Path, cache_dir: Optional[Union[str, Path]],
                      parse_key: str) -> list:
    """Cache locations in read/write preference order.  An explicit
    ``cache_dir`` wins outright; otherwise a ``REPRO_STORE`` root (its
    ``traces/`` subdirectory) is preferred, with the legacy
    next-to-source location as read fallback (pre-existing caches still
    hit) and write fallback (read-only store root).  Filename + keying
    are identical everywhere, so entries relocate freely."""
    if cache_dir is not None:
        return [_cache_path(path, cache_dir, parse_key)]
    from repro.cachesim.store import default_root
    out = []
    root = default_root()
    if root is not None:
        out.append(_cache_path(path, root / "traces", parse_key))
    out.append(_cache_path(path, None, parse_key))
    return out


def _load_cached(cache: Path, digest: str, parse_key: str
                 ) -> Optional[np.ndarray]:
    try:
        with np.load(cache, allow_pickle=False) as z:
            if str(z["sha256"]) == digest and str(z["parse_key"]) == parse_key:
                return z["ids"].astype(np.int64, copy=False)
    except (OSError, KeyError, ValueError, zipfile.BadZipFile):
        pass          # corrupt / foreign / stale-schema cache: re-parse
    return None


def _write_cache(cache: Path, digest: str, parse_key: str,
                 ids: np.ndarray) -> bool:
    try:
        cache.parent.mkdir(parents=True, exist_ok=True)
        tmp = cache.with_name(f".{cache.name}.tmp{os.getpid()}.npz")
        np.savez_compressed(tmp, ids=ids, sha256=np.asarray(digest),
                            parse_key=np.asarray(parse_key))
        # atomic replace: a concurrent reader never sees a partial archive
        os.replace(tmp, cache)
        return True
    except OSError:
        return False  # read-only location — caller may try a fallback


# ---------------------------------------------------------------------------
# The loader
# ---------------------------------------------------------------------------

def load_trace_file(path: Union[str, Path], *, fmt: Optional[str] = None,
                    key_column: Union[int, str] = 0, delimiter: str = ",",
                    head: Optional[int] = None, stride: int = 1,
                    cache: bool = True,
                    cache_dir: Optional[Union[str, Path]] = None,
                    with_info: bool = False,
                    ) -> Union[np.ndarray, Tuple[np.ndarray, TraceInfo]]:
    """Load one request log into the simulator's ``np.int64`` contract.

    Parsing + dense remapping run once per file CONTENT (SHA-256-keyed
    ``.npz`` cache; location per :func:`_cache_candidates` — explicit
    ``cache_dir``, else the ``REPRO_STORE`` root's ``traces/``, else
    next to the source); subsampling (``stride`` then ``head``) is a
    cheap slice of the cached full array, so every (head, stride) view
    of one log shares one parse.  ``with_info=True`` additionally
    returns the :class:`TraceInfo` of the returned (post-subsample)
    array.
    """
    path = Path(path)
    if not path.exists():
        raise FileNotFoundError(f"trace file not found: {path}")
    fmt = fmt or infer_format(path)
    if stride < 1:
        raise ValueError(f"stride must be >= 1, got {stride}")
    parse_key = f"v1:{fmt}:{key_column}:{delimiter}"
    ids = None
    digest = None
    candidates = _cache_candidates(path, cache_dir, parse_key)
    if cache:
        digest = file_sha256(path)
        for cpath in candidates:
            if cpath.exists():
                ids = _load_cached(cpath, digest, parse_key)
                if ids is not None:
                    break
    if ids is None:
        ids = parse_trace_file(path, fmt=fmt, key_column=key_column,
                               delimiter=delimiter)
        if cache:
            for cpath in candidates:
                if _write_cache(cpath, digest, parse_key, ids):
                    break
    n_file = int(ids.shape[0])
    out = ids[::stride] if stride > 1 else ids
    if head is not None:
        out = out[:int(head)]
    out = np.ascontiguousarray(out, dtype=np.int64)
    if not with_info:
        return out
    return out, trace_info(out, path=str(path), fmt=fmt,
                           n_requests_file=n_file)


# ---------------------------------------------------------------------------
# Alias registry + get_trace integration
# ---------------------------------------------------------------------------

def register_trace_file(name: str, path: Union[str, Path],
                        **loader_kwargs) -> None:
    """Bind a short trace name to a log file (+ default loader kwargs).
    The path is checked lazily — at load, not registration — so modules
    may register aliases for files that appear later.  Re-registering a
    name with identical bindings is a no-op; a conflicting rebind
    raises."""
    if name in ("",) or name.startswith(FILE_PREFIX):
        raise ValueError(f"invalid trace-file alias {name!r}")
    from repro.cachesim.traces import TRACES
    if name in TRACES:
        raise ValueError(
            f"alias {name!r} shadows a built-in synthetic generator")
    spec = {"path": str(path), **loader_kwargs}
    old = TRACE_FILES.get(name)
    if old is not None and old != spec:
        raise ValueError(f"trace-file alias {name!r} already bound to {old}")
    TRACE_FILES[name] = spec


def is_trace_file(name: str) -> bool:
    """Does ``name`` denote a file-backed trace (alias or ``file:``)?"""
    return name.startswith(FILE_PREFIX) or name in TRACE_FILES


def resolve(name: str, **overrides) -> dict:
    """The loader kwargs (incl. ``path``) a trace name denotes; call-site
    ``overrides`` win over the alias' registered defaults."""
    if name.startswith(FILE_PREFIX):
        spec = {"path": name[len(FILE_PREFIX):]}
    elif name in TRACE_FILES:
        spec = dict(TRACE_FILES[name])
    else:
        raise KeyError(f"not a file-backed trace: {name!r}")
    spec.update(overrides)
    return spec


def get_file_trace(name: str, n: Optional[int] = None,
                   with_info: bool = False, **kwargs):
    """``traces.get_trace`` backend for file-backed names: ``n`` bounds
    the returned length (``head`` subsample; an explicit ``head`` kwarg
    wins).  ``seed`` is accepted-and-ignored so generator-shaped call
    sites work unchanged (file replay is deterministic by nature)."""
    kwargs.pop("seed", None)
    spec = resolve(name, **kwargs)
    spec.setdefault("head", n)
    path = spec.pop("path")
    return load_trace_file(path, with_info=with_info, **spec)
