"""Content-addressed artifact store: never compute the same sweep twice.

The fast engine's expensive phase is the policy-independent system sweep
(:class:`~repro.cachesim.systemstate.SystemTrace`); decision tables are
second.  Both are pure functions of durable inputs — the request trace's
BYTES and the ``SystemTrace.system_key`` configuration tuple (plus, for
tables, the provider's decision-side ``cache_key``) — so repeated figure
runs, CI golden jobs, and a fleet of sweep workers can share one artifact
pool instead of recomputing (ROADMAP item 5).

Key anatomy
-----------
An entry's filename is ``sha256(meta)`` of a human-readable meta string::

    v<SCHEMA_VERSION>|sweep|<trace sha256>|<repr(system_key)>
    v<SCHEMA_VERSION>|table|<trace sha256>|<repr(system_key)>|<repr(table_key)>

so any input change — a single trace byte, any system-side config field,
a decision-side table key, or the serialisation schema itself — lands on
a different filename and the old entry is simply never consulted again.
The meta string is also stored INSIDE the ``.npz`` payload and verified
on load, so a hash collision or a foreign file in the store directory
reads as a miss, never as wrong data.

Layout and durability
---------------------
::

    <root>/sweeps/<digest>.npz   SystemTrace snapshots (see
                                 SystemTrace.to_arrays: per-request
                                 arrays, view-version history, quality
                                 counters, final-state snapshot)
    <root>/tables/<digest>.npz   plan_cache decision tables ([V * 2^n]
                                 int64 selection bitmasks)
    <root>/traces/               the tracefiles.py parse cache (same
                                 filename scheme as next-to-source)

Writes are atomic (``os.replace`` of a same-directory temp file), so a
concurrent reader — or a second writer racing on the same entry — never
observes a partial archive; last writer wins with identical content.
A corrupt or truncated entry is treated as a miss, unlinked best-effort,
and rebuilt from scratch.

Hydrated sweeps replay **bit-identically** to cold compute: the replay
phase consumes exactly the arrays the store round-trips (float64/int64
binary, no text formatting), and the golden-scenario suite in
``tests/test_store.py`` asserts it across every scenario x policy.

``REPRO_STORE`` (environment) names a default root for the CLI and the
tracefiles parse cache; library callers pass a root or an
:class:`ArtifactStore` explicitly (``run_grid(store=...)``).
"""
from __future__ import annotations

import hashlib
import os
import zipfile
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Tuple, Union

import numpy as np

#: bumped whenever the serialised layout (SystemTrace.to_arrays schema,
#: table payload shape) changes — old entries then miss by construction.
#: v2: advert-event subsystem (per-node advert streams + token-bucket
#: state in the sweep snapshot; system_key grew the advert spec)
#: v3: hierarchical topologies (``repro.cachesim.topology``) — the sweep
#: payload gained ``fwd_pos``, the forwarded residency-miss positions a
#: parent tier consumes; per-tier sweeps are stored under the SAME
#: (trace digest, system key) scheme, keyed by each tier's own arrival
#: stream, so one stored tier is reused by every topology cell (and
#: depth) that routes the same stream into the same system config
SCHEMA_VERSION = 3

#: environment variable naming the default store root (CLI + tracefiles)
ENV_VAR = "REPRO_STORE"


def default_root() -> Optional[Path]:
    """The ``REPRO_STORE`` root, or None when unset/empty."""
    root = os.environ.get(ENV_VAR, "").strip()
    return Path(root) if root else None


def as_store(store) -> Optional["ArtifactStore"]:
    """Normalise a ``store=`` argument: None passes through, a path
    becomes an :class:`ArtifactStore`, a store is returned as-is."""
    if store is None or isinstance(store, ArtifactStore):
        return store
    return ArtifactStore(store)


class ArtifactStore:
    """One store root; see the module docstring for the layout/keying."""

    def __init__(self, root: Union[str, Path]):
        self.root = Path(root)
        #: observability counters (benchmarks record them per run)
        self.stats: Dict[str, int] = {
            "sweep_hits": 0, "sweep_misses": 0,
            "table_hits": 0, "table_misses": 0,
            "writes": 0, "corrupt_dropped": 0,
        }

    def __repr__(self) -> str:
        return f"ArtifactStore({str(self.root)!r})"

    # -- keying ------------------------------------------------------------

    @staticmethod
    def trace_digest(trace: np.ndarray) -> str:
        """SHA-256 of the trace CONTENT in the engine's canonical dtype
        (uint64, the form ``run_cells`` hands to the sweep) — workers and
        parents hash identical bytes regardless of the caller's dtype."""
        arr = np.ascontiguousarray(np.asarray(trace), np.uint64)
        return hashlib.sha256(arr.tobytes()).hexdigest()

    @staticmethod
    def sweep_meta(trace_digest: str, system_key: tuple) -> str:
        return f"v{SCHEMA_VERSION}|sweep|{trace_digest}|{system_key!r}"

    @staticmethod
    def table_meta(trace_digest: str, system_key: tuple,
                   table_key: tuple) -> str:
        return (f"v{SCHEMA_VERSION}|table|{trace_digest}|"
                f"{system_key!r}|{table_key!r}")

    def _path(self, kind: str, meta: str) -> Path:
        digest = hashlib.sha256(meta.encode()).hexdigest()
        return self.root / f"{kind}s" / f"{digest}.npz"

    @property
    def traces_dir(self) -> Path:
        """Where the tracefiles parse cache lives under this root."""
        return self.root / "traces"

    def spill_dir(self) -> Path:
        """A fresh scratch directory under ``<root>/spill`` for the
        chunked sweep's per-request output memmaps (see
        ``SystemTrace.compute(spill=...)``).  Unique per call, so
        concurrent sweeps never collide.  Spill files are SCRATCH, not
        content-addressed entries: the caller deletes the directory when
        the arrays are no longer referenced (``entries``/``verify``/
        ``gc`` ignore it)."""
        import itertools
        seq = getattr(ArtifactStore, "_spill_seq", None)
        if seq is None:
            ArtifactStore._spill_seq = seq = itertools.count()
        d = self.root / "spill" / f"{os.getpid()}-{next(seq)}"
        d.mkdir(parents=True, exist_ok=True)
        return d

    # -- low-level entry IO ------------------------------------------------

    def _write(self, path: Path, arrays: Dict[str, np.ndarray],
               meta: str) -> None:
        try:
            path.parent.mkdir(parents=True, exist_ok=True)
            tmp = path.with_name(f".{path.name}.tmp{os.getpid()}")
            # uncompressed: sweeps are large and mostly incompressible
            # bool/float arrays; load speed is the whole point
            with open(tmp, "wb") as f:
                np.savez(f, __meta__=np.asarray(meta), **arrays)
            os.replace(tmp, path)        # atomic: readers never see partial
            self.stats["writes"] += 1
        except OSError:
            pass                         # read-only root etc.: best-effort

    def _read(self, path: Path, meta: str) -> Optional[Dict[str, np.ndarray]]:
        try:
            with np.load(path, allow_pickle=False) as z:
                if str(z["__meta__"]) != meta:
                    return None          # foreign/colliding entry: miss
                out = {k: z[k] for k in z.files if k != "__meta__"}
            # touch-on-hit: ``store_tool gc`` deletes oldest-mtime first
            # (documented as LRU) — without refreshing mtime on reads it
            # would evict the WARMEST entries under a long-lived store
            try:
                os.utime(path)
            except OSError:
                pass                     # read-only root etc.: best-effort
            return out
        except FileNotFoundError:
            return None
        except (OSError, KeyError, ValueError, zipfile.BadZipFile):
            # corrupt / truncated: drop so the rebuild can land cleanly
            self.stats["corrupt_dropped"] += 1
            try:
                path.unlink()
            except OSError:
                pass
            return None

    # -- sweeps ------------------------------------------------------------

    def has_sweep(self, trace_digest: str, system_key: tuple) -> bool:
        """Cheap existence probe (no load/verify) — the parallel runner
        uses it to skip farming already-stored groups."""
        return self._path("sweep",
                          self.sweep_meta(trace_digest, system_key)).exists()

    def load_sweep(self, trace: np.ndarray, system_key: tuple, *,
                   trace_digest: Optional[str] = None):
        """The stored :class:`SystemTrace` for (trace bytes, system_key),
        hydrated against ``trace``, or None on a miss."""
        from repro.cachesim.systemstate import SystemTrace
        if trace_digest is None:
            trace_digest = self.trace_digest(trace)
        meta = self.sweep_meta(trace_digest, system_key)
        arrays = self._read(self._path("sweep", meta), meta)
        if arrays is None:
            self.stats["sweep_misses"] += 1
            return None
        self.stats["sweep_hits"] += 1
        return SystemTrace.from_arrays(arrays, key=system_key, trace=trace)

    def save_sweep(self, st, *, trace_digest: Optional[str] = None) -> None:
        """Persist one computed sweep (its ``plan_cache`` tables are
        separate artifacts — see :meth:`save_table`)."""
        if trace_digest is None:
            trace_digest = self.trace_digest(st._trace)
        meta = self.sweep_meta(trace_digest, st.key)
        self._write(self._path("sweep", meta), st.to_arrays(), meta)

    # -- decision tables ---------------------------------------------------

    def load_table(self, trace_digest: str, system_key: tuple,
                   table_key: tuple) -> Optional[np.ndarray]:
        meta = self.table_meta(trace_digest, system_key, table_key)
        arrays = self._read(self._path("table", meta), meta)
        if arrays is None:
            self.stats["table_misses"] += 1
            return None
        self.stats["table_hits"] += 1
        return np.ascontiguousarray(arrays["table"], np.int64)

    def save_table(self, trace_digest: str, system_key: tuple,
                   table_key: tuple, table: np.ndarray) -> None:
        meta = self.table_meta(trace_digest, system_key, table_key)
        self._write(self._path("table", meta),
                    {"table": np.asarray(table, np.int64)}, meta)

    # -- maintenance (tools/store_tool.py) ---------------------------------

    def entries(self) -> List[Tuple[Path, str, int, float]]:
        """Every stored artifact as (path, kind, size bytes, mtime),
        oldest first — traces/ parse caches included."""
        out = []
        for kind in ("sweeps", "tables", "traces"):
            d = self.root / kind
            if not d.is_dir():
                continue
            for p in sorted(d.iterdir()):
                if p.name.startswith(".") or not p.is_file():
                    continue
                st = p.stat()
                out.append((p, kind, st.st_size, st.st_mtime))
        out.sort(key=lambda e: e[3])
        return out

    def verify(self) -> Iterator[Tuple[Path, bool]]:
        """Yield (path, ok) per entry: ok means the archive opens and its
        arrays load (traces/ entries are checked as archives only — their
        keying lives in ``tracefiles``)."""
        for path, _, _, _ in self.entries():
            ok = True
            try:
                with np.load(path, allow_pickle=False) as z:
                    for k in z.files:
                        z[k]
            except (OSError, KeyError, ValueError, zipfile.BadZipFile):
                ok = False
            yield path, ok

    def gc(self, max_bytes: int) -> List[Path]:
        """Delete oldest entries (by mtime) until the store fits in
        ``max_bytes``; returns the deleted paths."""
        entries = self.entries()
        total = sum(size for _, _, size, _ in entries)
        deleted = []
        for path, _, size, _ in entries:
            if total <= max_bytes:
                break
            try:
                path.unlink()
                total -= size
                deleted.append(path)
            except OSError:
                pass
        return deleted
