"""Speculative segmented fast replay for the calibrated policy (fna_cal).

``fna_cal`` corrects the bit-counting FN inflation of Eq. (7) with
empirical probe feedback: per-cache EWMAs of observed exclusion outcomes,
blended with the model views until ``cal_min_obs`` probes accumulate (or
immediately when the indicator is uninformative, FP+FN >= 0.95), plus
epsilon-exploration.  Its EWMAs move on EVERY probe outcome, which breaks
the frozen-view invariant (I2) the table-driven fast path relies on — but
its DECISIONS only change when a drifting rho crosses a DS_PGM decision
boundary, which is far rarer than a probe: measured on the gradle trace
the 2^n decision table changes on ~2% of requests, in a bimodal pattern —
long stable runs punctuated by short flip bursts while a rho hovers at a
boundary.

The engine speculates and commits:

  1. SPECULATE a vectorised replay of a window through a frozen 2^n
     decision table (plus the precomputed epsilon-exploration draws — the
     reference RNG stream is replicated exactly).  The table need not be
     correct — it is a guess whose quality only affects speed — so in the
     post-warmup regime (every branch past min-obs, model views ignored)
     it is patched one row at a time from verification verdicts instead
     of being rebuilt; while model views are still blended in,
     per-view-version tables are rebuilt from the frozen calibration
     state, the whole (version x pattern) batch in one
     ``repro.core.batched`` call (``selection_tables`` backend="numpy" /
     ``exhaustive_tables`` — the same float64 math as the verification
     pass, so a correct speculation always verifies).
  2. RECONSTRUCT the exact calibration-state trajectory the speculated
     probes imply: probe counts are integer cumsums; EWMA paths advance
     per (cache, branch) through :func:`repro.core.estimator.ewma_path` —
     the bit-identical scalar recurrence batched over the segment's probe
     events — and broadcast back per request.  Probe outcomes come free
     from the shared ``SystemTrace``: only the designated cache can hold
     a key, so ``in_dj`` determines every probe's result.
  3. VERIFY with one batched float64 DS_PGM evaluation of the true
     per-request rho matrix (``repro.core.batched.rho_selection_tables``)
     and COMMIT up to the first request whose recomputed EWMA / min-obs /
     exploration state alters the decision.  The mismatched request
     itself is then replayed by one step of the scalar BRIDGE — a
     reference-exact transcription of the decision/feedback loop over the
     precomputed system arrays — which both guarantees forward progress
     independent of float coincidences and yields the fresh table row.
  4. ADAPT: the window doubles on a fully-committed segment and shrinks
     on early mismatch; when commits collapse below the speculation
     break-even (a flip burst), the engine drops into the scalar bridge
     for a stretch instead of thrashing table rebuilds.

Bit-exactness: bridge-committed requests replicate the reference
operations literally; speculatively-committed requests are verified
equal to the float64 batched evaluation of the true rho (DS_PGM prefix
scan, or the 2^n-subset enumeration when ``alg="exhaustive"``, n <= 12) —
the same near-tie parity caveat as ``repro.cachesim.fastpath``, ruled
out empirically by ``tests/test_fna_cal_fast.py`` across traces and
calibration settings.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.cachesim.systemstate import SystemTrace
from repro.core.batched import rho_exhaustive_tables, rho_selection_tables
from repro.core.estimator import ewma_path
from repro.core.policies import ds_pgm_mask, exhaustive_mask

_START_WINDOW = 512
_SPEC_MIN_WINDOW = 128       # smallest window worth a speculation round
_MAX_WINDOW = 65_536
_CHUNK = 256                 # trajectory/verification granularity: the
# speculated WINDOW can be huge (table lookups are cheap), but the
# expensive exact-state reconstruction + verification walk it in chunks
# and abort at the first mismatching chunk, so the work wasted past a
# mis-speculation is bounded by one chunk instead of the whole window
_BURST_COMMIT = 8            # commits below this => flip burst => bridge
_BRIDGE_LEN = 32             # scalar requests per bridge stretch
# while any branch still blends model views, cap tables built per segment
_MAX_SEG_VERSIONS = 16


def replay_fna_cal(sim, st: SystemTrace, res):
    """Full fna_cal fast replay: committed selections + the shared fold."""
    from repro.cachesim.fastpath import accumulate_replay
    return accumulate_replay(res, st, fna_cal_selections(sim, st),
                             [float(c) for c in sim.cfg.costs],
                             float(sim.cfg.miss_penalty))


def fna_cal_selections(sim, st: SystemTrace) -> np.ndarray:
    """[N] committed (post-exploration) selection bitmasks for fna_cal —
    the speculate/verify/bridge engine described in the module docstring,
    minus the cost fold.  Exposed separately so the topology layer can
    re-account the same decisions under per-tier penalties."""
    cfg = sim.cfg
    n = st.n
    N = st.trace_len
    k = 1 << n
    costs = [float(c) for c in cfg.costs]
    M = float(cfg.miss_penalty)
    g = float(cfg.cal_gamma)
    min_obs = int(cfg.cal_min_obs)
    # the speculate-and-commit loop is subroutine-agnostic: it needs a
    # scalar bitmask call (bridge/table rows) and a batched float64
    # verifier over an arbitrary rho matrix.  ds_pgm pairs the stripped
    # scalar variant with the prefix-scan verifier; exhaustive (n <= 12 —
    # the Simulator dispatch falls back to the reference loop beyond the
    # table budget) pairs it with the batched 2^n-subset enumeration.
    if cfg.alg == "exhaustive":
        mask_fn, verify_fn = exhaustive_mask, rho_exhaustive_tables
    else:
        mask_fn, verify_fn = ds_pgm_mask, rho_selection_tables
    arange_n = np.arange(n)
    pow2 = (np.int64(1) << arange_n).astype(np.int64)
    bits_of = ((np.arange(k)[:, None] >> arange_n) & 1).astype(bool)  # [2^n, n]

    # epsilon-exploration draws: the exact RNG stream of the reference loop
    rng = np.random.default_rng(cfg.seed + 12345)
    eps_draws = rng.random(N)
    eps_pick = rng.integers(0, n, N)
    eps_bits = np.where(eps_draws < cfg.cal_epsilon,
                        np.int64(1) << eps_pick, np.int64(0))

    ver = st.ver_per_req
    # probe outcome per (request, cache): only the designated cache can
    # hold a key, so absence is a pure function of the shared sweep
    absent = np.ones((N, n), dtype=np.float64)
    absent[np.arange(N), st.dj_all] = (~st.in_dj).astype(np.float64)
    uninf_v = (st.fp_v + st.fn_v) >= 0.95           # [V, n]
    # scalar-bridge views of the per-version data (python lists: the
    # bridge reads a handful of scalars per request)
    uninf_l = uninf_v.tolist()
    mpi_l = st.pi_v.tolist()
    mnu_l = st.nu_v.tolist()

    # calibration state (optimistic init — see the reference loop)
    pi_emp = np.full(n, 0.5, np.float64)
    nu_emp = np.full(n, 0.90, np.float64)
    pi_obs = np.zeros(n, np.int64)
    nu_obs = np.zeros(n, np.int64)

    selm = np.empty(N, dtype=np.int64)      # committed (post-eps) masks

    def bridge(s: int, count: int) -> Tuple[int, int]:
        """Reference-exact scalar replay of ``count`` requests from ``s``:
        per-request blend, scalar DS_PGM, exploration, probe feedback —
        the literal reference operations over the precomputed system
        arrays.  Mutates the calibration state in place; returns (end,
        pre-exploration mask of the last request) — the fresh table row."""
        nonlocal pi_emp, nu_emp, pi_obs, nu_obs
        end = min(s + count, N)
        pe: List[float] = pi_emp.tolist()
        ne: List[float] = nu_emp.tolist()
        po: List[int] = pi_obs.tolist()
        no: List[int] = nu_obs.tolist()
        pats_c = st.pats[s:end].tolist()
        ver_c = ver[s:end].tolist()
        abs_c = absent[s:end].tolist()
        eps_c = eps_bits[s:end].tolist()
        rng_n = range(n)
        base = 0
        for i in range(end - s):
            v = ver_c[i]
            pat = pats_c[i]
            uv = uninf_l[v]
            mp = mpi_l[v]
            mn = mnu_l[v]
            rhos = [
                (pe[j] if (po[j] >= min_obs or uv[j]) else mp[j])
                if (pat >> j) & 1
                else (ne[j] if (no[j] >= min_obs or uv[j]) else mn[j])
                for j in rng_n]
            base = mask_fn(costs, rhos, M)
            m = base | eps_c[i]
            selm[s + i] = m
            ai = abs_c[i]
            mm, j = m, 0
            while mm:
                if mm & 1:
                    a = ai[j]
                    if (pat >> j) & 1:
                        pe[j] = (1.0 - g) * pe[j] + g * a
                        po[j] += 1
                    else:
                        ne[j] = (1.0 - g) * ne[j] + g * a
                        no[j] += 1
                mm >>= 1
                j += 1
        pi_emp = np.asarray(pe, np.float64)
        nu_emp = np.asarray(ne, np.float64)
        pi_obs = np.asarray(po, np.int64)
        nu_obs = np.asarray(no, np.int64)
        return end, base

    def build_tables(vids) -> dict:
        """2^n speculation tables from the frozen calibration state, one
        per view version — the whole (version x pattern) batch produced
        by ONE ``repro.core.batched`` call (``selection_tables`` /
        ``exhaustive_tables``) instead of 2^n scalar ``mask_fn`` calls
        per version.  The batched float64 rows match ``verify_fn``'s math
        exactly, so speculation quality only improves; exactness is still
        owned by the verification pass and the scalar bridge."""
        from repro.core.batched import exhaustive_tables, selection_tables
        use_pi = pi_obs >= min_obs
        use_nu = nu_obs >= min_obs
        vids = [int(v) for v in vids]
        rp = np.where(use_pi[None, :] | uninf_v[vids],
                      pi_emp[None, :], st.pi_v[vids])          # [m, n]
        rn = np.where(use_nu[None, :] | uninf_v[vids],
                      nu_emp[None, :], st.nu_v[vids])
        if cfg.alg == "exhaustive":
            flat = exhaustive_tables(costs, rp, rn, M).reshape(-1)
        else:
            tab = selection_tables(costs, rp, rn, M, backend="numpy")
            flat = (tab.reshape(-1, n) @ pow2).astype(np.int64)
        return {v: flat[i * k:(i + 1) * k] for i, v in enumerate(vids)}

    s = 0
    window = _START_WINDOW
    table = None                # steady-state (all-emp) speculation table
    while s < N:
        if window < _SPEC_MIN_WINDOW:           # flip burst: scalar stretch
            s, _ = bridge(s, _BRIDGE_LEN)
            window = _SPEC_MIN_WINDOW
            table = None                        # state moved under the table
            continue
        L = min(window, N - s)
        all_emp = bool((pi_obs >= min_obs).all() and
                       (nu_obs >= min_obs).all())
        if not all_emp:
            # model views in play: decisions are version-dependent, so use
            # exact per-version tables and bound how many a segment builds
            cut = int(np.searchsorted(ver, ver[s] + _MAX_SEG_VERSIONS,
                                      side="left"))
            L = max(min(L, cut - s), 1)
        sl = slice(s, s + L)

        # --- 1. speculate -------------------------------------------------
        if all_emp:
            if table is None:
                table = build_tables([int(ver[s])])[int(ver[s])]
            spec = table[st.pats[sl]]
        else:
            vseg = ver[sl]
            tables = build_tables(np.unique(vseg).tolist())
            spec = np.empty(L, np.int64)
            for v, tab in tables.items():
                vm = vseg == v
                spec[vm] = tab[st.pats[sl][vm]]
        sel_spec = spec | eps_bits[sl]

        # --- 2+3. exact state trajectories + verification, chunk-wise -----
        # (the state at a chunk's start is exact because every previous
        # chunk committed in full; aborting at the first mismatching chunk
        # bounds the work wasted past a mis-speculation)
        commit = 0
        clean = True
        while commit < L and clean:
            c1 = min(commit + _CHUNK, L)
            cl = c1 - commit
            rows = slice(s + commit, s + c1)
            ind_seg = st.ind_all[rows]
            sel_b = bits_of[sel_spec[commit:c1]]        # [cl, n]
            pos_ev = sel_b & ind_seg                    # positive probes
            neg_ev = sel_b & ~ind_seg
            # probe counts BEFORE each request r (+1 row: after the chunk)
            cs_p = np.zeros((cl + 1, n), np.int64)
            cs_n = np.zeros((cl + 1, n), np.int64)
            np.cumsum(pos_ev, axis=0, out=cs_p[1:])
            np.cumsum(neg_ev, axis=0, out=cs_n[1:])
            pi_t = np.empty((cl + 1, n), np.float64)
            nu_t = np.empty((cl + 1, n), np.float64)
            a_seg = absent[rows]
            for j in range(n):
                idx = np.flatnonzero(pos_ev[:, j])
                if idx.size:
                    seq = np.empty(idx.size + 1, np.float64)
                    seq[0] = pi_emp[j]
                    seq[1:] = ewma_path(pi_emp[j], a_seg[idx, j], g)
                    pi_t[:, j] = seq[cs_p[:, j]]
                else:
                    pi_t[:, j] = pi_emp[j]
                idx = np.flatnonzero(neg_ev[:, j])
                if idx.size:
                    seq = np.empty(idx.size + 1, np.float64)
                    seq[0] = nu_emp[j]
                    seq[1:] = ewma_path(nu_emp[j], a_seg[idx, j], g)
                    nu_t[:, j] = seq[cs_n[:, j]]
                else:
                    nu_t[:, j] = nu_emp[j]
            if all_emp:
                rho = np.where(ind_seg, pi_t[:cl], nu_t[:cl])
            else:
                vc = vseg[commit:c1]
                uninf_seg = uninf_v[vc]                 # [cl, n]
                up_t = (pi_obs[None] + cs_p[:cl] >= min_obs) | uninf_seg
                un_t = (nu_obs[None] + cs_n[:cl] >= min_obs) | uninf_seg
                rho = np.where(ind_seg,
                               np.where(up_t, pi_t[:cl], st.pi_v[vc]),
                               np.where(un_t, nu_t[:cl], st.nu_v[vc]))
            true_selm = verify_fn(costs, rho, M) @ pow2
            bad = np.flatnonzero(true_selm != spec[commit:c1])
            ok = cl if bad.size == 0 else int(bad[0])
            clean = bad.size == 0
            selm[s + commit:s + commit + ok] = sel_spec[commit:commit + ok]
            pi_emp = pi_t[ok].copy()
            nu_emp = nu_t[ok].copy()
            pi_obs = pi_obs + cs_p[ok]
            nu_obs = nu_obs + cs_n[ok]
            commit += ok

        # --- 4. adapt ------------------------------------------------------
        s += commit
        if clean:
            window = min(window * 2, _MAX_WINDOW)
        else:
            # replay the mismatched request itself scalar-exactly; its
            # fresh decision patches the (speculation-only) table row
            pat = int(st.pats[s])
            s, row = bridge(s, 1)
            if all_emp and table is not None:
                table[pat] = row
            else:
                table = None
            window = 0 if commit < _BURST_COMMIT \
                else min(max(2 * commit, _SPEC_MIN_WINDOW), _MAX_WINDOW)

    return selm
