"""Epoch-batched fast engine for the trace simulator.

Bit-exact twin of ``Simulator._run_reference`` built on the invariants
documented in the ``repro.cachesim.simulator`` module docstring (I1:
stale bitmaps frozen between advertisements; I2: (pi, nu) views frozen
between version bumps) plus two structural facts the reference loop
obscures:

  * the SYSTEM state (LRU contents, CBF counters, stale bitmaps, FP/FN
    estimates, q-estimates) evolves independently of any model-based
    policy's decisions — placement is by hash and every request is
    placed in its designated cache (``fna_cal`` is the exception and
    stays on the reference engine);
  * a key can only ever reside in its DESIGNATED cache, so each cache's
    dynamics depend only on its own designated subsequence of the trace.

The engine therefore runs in phases:

  1. STATE SWEEP, per cache: a tight LRU pass over the cache's
     designated keys (the only inherently sequential work left), then an
     event walk that jumps insertion-count arithmetic from one
     estimate/advertise boundary to the next — CBF counter updates are
     applied in bulk per window, and indications are computed per
     advertisement segment as one vectorised ``all()`` reduction over
     precomputed hash indices (I1, with EXACT segment ends).  Q-epoch
     updates follow, batched per epoch.  Every (pi, nu) view change is
     recorded as (request index it takes effect, values).

  2. BATCHED TABLES — by I2, a decision within a view version is a pure
     function of the n-bit indication pattern, so the whole run needs at
     most V * 2^n distinct selections.  All of them are computed in ONE
     ``repro.core.batched.ds_pgm_batched`` call (float64, see
     ``selection_tables``) — the JAX router path, fed the simulator's
     entire version history at once.

  3. REPLAY — selections, hits and access counts become vectorised table
     lookups over the trace; only the service-cost accumulation stays a
     scalar fold so float-addition order matches the reference exactly.

Deferred CBF bookkeeping parity: the reference path's fancy-index
*assignment* counts duplicate probe indices of one key once, so buffered
rows are deduplicated before the bulk ``np.add.at``; and since every
remove is preceded by its matching add, no counter ever clamps at 0/255
mid-stream, making the batched net update equal to the sequential one.

Parity caveat: all state evolution and accounting here is replicated
operation-for-operation, but the DS_PGM tables evaluate Eq. (10) through
``exp(cumsum(log .))`` in float64 rather than the scalar running product,
and pick the argmin rather than applying the scalar path's EPS (1e-12)
improvement dead-band.  The two can only disagree when two prefix costs
coincide to within ~1e-12 absolute — a measure-zero coincidence of the
data-derived estimates, ruled out empirically by the parity suite
(``tests/test_fastpath.py``) across every policy x trace x interval
combination tested.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.cachesim.simulator import SimResult, Simulator
from repro.core import hash_indices, hocs_fna
from repro.core.policies import ds_pgm

# 2^n tables per version: past this the reference loop is the better deal
_MAX_TABLE_CACHES = 12


def _dedup_rows(rows: np.ndarray) -> np.ndarray:
    """Unique indices per row, flattened.  The reference CBF update uses
    fancy-index assignment, so duplicate probe indices within one key must
    count once."""
    s = np.sort(rows, axis=1)
    keep = np.ones(s.shape, dtype=bool)
    keep[:, 1:] = s[:, 1:] != s[:, :-1]
    return s[keep]


def _lru_sweep(lru, trace: np.ndarray, pos: np.ndarray):
    """Advance one cache's LRU through its designated subsequence.

    Returns (membership-before-put per request, global positions of the
    requests that inserted, evicted keys, insert index of each eviction).
    Identical ops on the same OrderedDict as ``LRUCache.put`` would do.
    """
    d = lru._d
    cap = lru.capacity
    keys = trace[pos].tolist()
    mem: List[bool] = []
    ins_local: List[int] = []
    evict_keys: List[int] = []
    evict_iidx: List[int] = []
    mem_append = mem.append
    move_to_end = d.move_to_end
    popitem = d.popitem
    ins_append = ins_local.append
    for li, x in enumerate(keys):
        if x in d:
            move_to_end(x)
            mem_append(True)
        else:
            mem_append(False)
            if len(d) >= cap:
                ev, _ = popitem(False)
                evict_keys.append(ev)
                evict_iidx.append(len(ins_local))
            d[x] = None
            ins_append(li)
    ins_gpos = pos[np.asarray(ins_local, dtype=np.int64)] if ins_local \
        else np.empty(0, np.int64)
    return (np.asarray(mem, dtype=bool), ins_gpos, evict_keys,
            np.asarray(evict_iidx, dtype=np.int64))


def _cbf_event_walk(nd, j: int, idx_j: np.ndarray, ins_gpos: np.ndarray,
                    evict_keys, evict_iidx: np.ndarray,
                    ind_all: np.ndarray, est_events: List[Tuple], N: int) -> None:
    """Jump from one estimate/advertise boundary to the next (no
    per-request work): bulk-apply the window's CBF updates, fire the same
    ``estimate_rates``/``advertise`` calls the reference ``insert`` would,
    fill this cache's indication column per advertisement segment, and
    record (effective request index, fp, fn) for every version bump."""
    cbf = nd.ind.cbf
    cnt = cbf.counters.astype(np.int32)
    cbf.counters = cnt              # estimate/advertise read through cbf
    ins_rows = idx_j[ins_gpos]
    ev_rows = hash_indices(np.asarray(evict_keys, dtype=np.uint64),
                           cbf.k, cbf.m, cbf.seed) if evict_keys else None
    n_ins = int(ins_gpos.shape[0])
    seg_start = 0                   # indication segment start (request idx)
    cur = 0                         # inserts flushed so far
    ev_ptr = 0
    next_est = nd.est_interval - nd._since_est
    next_adv = nd.update_interval - nd._since_adv

    def flush(upto: int) -> None:
        nonlocal cur, ev_ptr
        if upto <= cur:
            return
        np.add.at(cnt, _dedup_rows(ins_rows[cur:upto]), 1)
        hi = int(np.searchsorted(evict_iidx, upto, side="left"))
        if hi > ev_ptr:
            np.subtract.at(cnt, _dedup_rows(ev_rows[ev_ptr:hi]), 1)
            ev_ptr = hi
        cur = upto

    while True:
        nxt = min(next_est, next_adv)
        if nxt > n_ins:
            break
        flush(nxt)
        g = int(ins_gpos[nxt - 1])  # request whose insert fired the event
        bumps = 0
        if next_est == nxt:         # reference order: estimate first
            nd.ind.estimate_rates()
            bumps += 1
            next_est = nxt + nd.est_interval
        if next_adv == nxt:
            # indications in [seg_start, g] used the OLD stale bitmap
            np.all(nd.ind.stale[idx_j[seg_start:g + 1]], axis=1,
                   out=ind_all[seg_start:g + 1, j])
            nd.ind.advertise()
            # a fresh advertisement resets the staleness estimates
            nd.ind.estimate_rates()
            bumps += 1
            seg_start = g + 1
            next_est = nxt + nd.est_interval
            next_adv = nxt + nd.update_interval
        nd.version += bumps
        est_events.append((g + 1, 0, j, nd.ind.fp_est, nd.ind.fn_est))
    flush(n_ins)
    np.all(nd.ind.stale[idx_j[seg_start:N]], axis=1,
           out=ind_all[seg_start:N, j])
    cbf.counters = np.clip(cnt, 0, 255).astype(np.uint8)
    nd._since_est = nd.est_interval - (next_est - n_ins)
    nd._since_adv = nd.update_interval - (next_adv - n_ins)


def _q_epoch_walk(q_est, ind_all: np.ndarray, N: int) -> List[Tuple]:
    """Advance the q-estimators through the whole trace, one batched
    ``_close_epoch`` per epoch boundary (bit-exact: positives are integer
    counts).  Returns (effective request index, q) events per cache."""
    events: List[Tuple] = []
    horizon = q_est[0].horizon
    first = horizon - q_est[0]._count   # requests closing the first epoch
    bounds = range(first, N + 1, horizon)
    for j, qe in enumerate(q_est):
        col = ind_all[:, j]
        prev = 0
        for b in bounds:            # each slice closes exactly one epoch
            qe.observe_batch(col[prev:b])
            events.append((b - 1, 1, j, qe.q))
            prev = b
        qe.observe_batch(col[prev:N])   # partial tail
    return events


def _assemble_versions(n: int, fp0, fn0, q0, events, N: int):
    """Replay the recorded estimate/q events chronologically into the
    (pi, nu) view-version history — the same floats ``_refresh_views``
    would produce at each decision.  Returns (versions, points) where
    points[i] = (first request index using versions[i], version id)."""
    from repro.core.model import exclusion_probabilities, hit_ratio_from_q
    fp, fn, q = list(fp0), list(fn0), list(q0)
    pi = [0.0] * n
    nu = [0.0] * n

    def view(js) -> None:
        for j in js:
            h = hit_ratio_from_q(q[j], fp[j], fn[j])
            pi[j], nu[j] = exclusion_probabilities(h, fp[j], fn[j])

    view(range(n))
    versions = [(tuple(pi), tuple(nu))]
    points = [(0, 0)]
    events = sorted(events)
    i = 0
    while i < len(events):
        eff = events[i][0]
        touched = set()
        while i < len(events) and events[i][0] == eff:
            _, kind, j = events[i][:3]
            if kind == 0:
                fp[j], fn[j] = events[i][3], events[i][4]
            else:
                q[j] = events[i][3]
            touched.add(j)
            i += 1
        if eff >= N:        # bump on the last request: no decision left
            continue
        view(touched)
        v = (tuple(pi), tuple(nu))
        if versions[-1] != v:
            versions.append(v)
            points.append((eff, len(versions) - 1))
    return versions, points


def _selection_masks(sim: Simulator, versions, costs, miss_penalty: float
                     ) -> np.ndarray:
    """[V * 2^n] selection bitmasks — phase 2, one row per (version,
    indication-pattern) pair."""
    cfg = sim.cfg
    n = cfg.n_caches
    k = 1 << n
    pow2 = 1 << np.arange(n, dtype=np.int64)
    if cfg.policy == "hocs":   # Algorithm 1 on pooled homogeneous estimates
        sel = np.empty(len(versions) * k, dtype=np.int64)
        for v, (pi, nu) in enumerate(versions):
            pi_h = sum(pi) / n
            nu_h = sum(nu) / n
            for p in range(k):
                pos = [j for j in range(n) if (p >> j) & 1]
                neg = [j for j in range(n) if not (p >> j) & 1]
                r0, r1 = hocs_fna(len(pos), n, pi_h, nu_h, miss_penalty)
                m = 0
                for j in pos[:r1] + neg[:r0]:
                    m |= 1 << j
                sel[v * k + p] = m
        return sel
    if sim.alg is ds_pgm:      # the batched JAX path (float64 — bit-exact)
        from repro.core.batched import selection_tables
        pi_mat = np.asarray([v[0] for v in versions], np.float64)
        nu_mat = np.asarray([v[1] for v in versions], np.float64)
        # pad V to a power-of-two bucket: XLA compiles per shape, and
        # bucketing makes shapes recur across runs (padding rows are
        # copies of the last version; their masks are discarded)
        v = pi_mat.shape[0]
        vpad = 1 << max(4, (v - 1).bit_length())
        if vpad > v:
            pi_mat = np.concatenate([pi_mat, np.repeat(pi_mat[-1:], vpad - v, 0)])
            nu_mat = np.concatenate([nu_mat, np.repeat(nu_mat[-1:], vpad - v, 0)])
        mask = selection_tables(costs, pi_mat, nu_mat, miss_penalty,
                                fno=(cfg.policy == "fno"))
        return (mask.reshape(-1, n)[:v * k] @ pow2).astype(np.int64)
    # generic subroutine (e.g. exhaustive): scalar call per (version, pattern)
    sel = np.empty(len(versions) * k, dtype=np.int64)
    for v, (pi, nu) in enumerate(versions):
        for p in range(k):
            if cfg.policy == "fno":
                pos = [j for j in range(n) if (p >> j) & 1]
                chosen = []
                if pos:
                    sub = sim.alg([costs[j] for j in pos],
                                  [pi[j] for j in pos], miss_penalty)
                    chosen = [pos[t] for t in sub]
            else:
                rhos = [pi[j] if (p >> j) & 1 else nu[j] for j in range(n)]
                chosen = sim.alg(costs, rhos, miss_penalty)
            m = 0
            for j in chosen:
                m |= 1 << j
            sel[v * k + p] = m
    return sel


def run_fast(sim: Simulator, trace: np.ndarray, res: SimResult) -> SimResult:
    cfg = sim.cfg
    n = cfg.n_caches
    if n > _MAX_TABLE_CACHES:
        return sim._run_reference(trace, res)
    costs = list(cfg.costs)
    M = cfg.miss_penalty
    nodes = sim.nodes
    is_pi = cfg.policy == "pi"
    N = int(trace.shape[0])
    if N == 0:
        return res

    dj_all = sim._designated_batch(trace)
    pos_by_node = [np.flatnonzero(dj_all == j) for j in range(n)]
    idx_all = [hash_indices(trace, nd.ind.cbf.k, nd.ind.cbf.m, nd.ind.cbf.seed)
               for nd in nodes]
    # view inputs at entry — events below record every later change
    fp0 = [nd.ind.fp_est for nd in nodes]
    fn0 = [nd.ind.fn_est for nd in nodes]
    q0 = [qe.q for qe in sim.q_est]

    # --- phase 1: state sweep (per cache, then q epochs) ----------------
    ind_all = np.empty((N, n), dtype=bool)
    in_dj = np.empty(N, dtype=bool)     # designated-cache membership
    events: List[Tuple] = []
    for j, nd in enumerate(nodes):
        pos = pos_by_node[j]
        mem, ins_gpos, evict_keys, evict_iidx = _lru_sweep(nd.lru, trace, pos)
        in_dj[pos] = mem
        _cbf_event_walk(nd, j, idx_all[j], ins_gpos, evict_keys, evict_iidx,
                        ind_all, events, N)
    events.extend(_q_epoch_walk(sim.q_est, ind_all, N))

    # indicator-quality measurement on the designated cache (Fig. 1)
    for j in range(n):
        pos = pos_by_node[j]
        md = in_dj[pos]
        id_ = ind_all[pos, j]
        held = int(np.count_nonzero(md))
        res.fn_opportunities += held
        res.resident += held
        res.fn_events += int(np.count_nonzero(md & ~id_))
        res.fp_opportunities += int(pos.shape[0]) - held
        res.fp_events += int(np.count_nonzero(~md & id_))

    pow2 = 1 << np.arange(n, dtype=np.int64)
    pats_np = ind_all @ pow2            # n-bit indication pattern per request
    if is_pi:
        # PI accesses the cheapest cache truly holding x; hash placement
        # means only the designated cache can — so it IS the selection
        cost_arr = np.where(in_dj, np.asarray(costs, np.float64)[dj_all], M)
        hits = int(np.count_nonzero(in_dj))
        posm = ((pats_np >> dj_all) & 1).astype(bool) & in_dj
        pos_acc = int(np.count_nonzero(posm))
        neg_acc = hits - pos_acc
    else:
        # --- phase 2: every (version, pattern) selection in one batch ---
        k = 1 << n
        versions, points = _assemble_versions(n, fp0, fn0, q0, events, N)
        selmask = _selection_masks(sim, versions, costs, M)     # [V * 2^n]
        # per-selection-bitmask exact cost sums (reference summation order)
        acc_by_mask = np.asarray(
            [sum(costs[j] for j in range(n) if (m >> j) & 1) for m in range(k)],
            np.float64)
        popcount = np.asarray([bin(m).count("1") for m in range(k)], np.int64)
        # --- phase 3: vectorised replay ---------------------------------
        starts = np.asarray([p[0] for p in points] + [N], np.int64)
        ids = np.asarray([p[1] for p in points], np.int64)
        ver_per_req = np.repeat(ids, np.diff(starts))
        selm = selmask[ver_per_req * k + pats_np]               # [N]
        # a hit needs the designated cache selected AND the key resident
        hit_arr = in_dj & (((selm >> dj_all) & 1) != 0)
        acc = acc_by_mask[selm]
        cost_arr = np.where(hit_arr, acc, acc + M)
        hits = int(np.count_nonzero(hit_arr))
        pos_acc = int(popcount[selm & pats_np].sum())
        neg_acc = int(popcount[selm].sum()) - pos_acc

    # scalar fold keeps float-addition order identical to the reference
    total_cost = res.total_cost
    for c in cost_arr.tolist():
        total_cost += c

    res.total_cost = total_cost
    res.hits += hits
    res.pos_accesses += pos_acc
    res.neg_accesses += neg_acc
    res.n_requests += N
    return res
