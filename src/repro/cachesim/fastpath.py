"""Fast engine, policy side: decision tables + vectorised replay.

Bit-exact twin of ``Simulator._run_reference`` built on the invariants
documented in the ``repro.cachesim.simulator`` module docstring (I1:
stale bitmaps frozen between advertisements; I2: (pi, nu) views frozen
between version bumps) plus two structural facts the reference loop
obscures:

  * the SYSTEM state (LRU contents, CBF counters, stale bitmaps, FP/FN
    estimates, q-estimates) evolves independently of any policy's
    decisions — placement is by hash and every request is placed in its
    designated cache;
  * a key can only ever reside in its DESIGNATED cache, so each cache's
    dynamics depend only on its own designated subsequence of the trace.

The engine therefore runs in phases:

  1. SYSTEM SWEEP — per-cache LRU passes, CBF event walks, vectorised
     per-epoch indications, batched q-updates, and the full view-version
     history.  This phase lives in ``repro.cachesim.systemstate`` and is
     POLICY-INDEPENDENT: :func:`run_fast` computes a
     :class:`~repro.cachesim.systemstate.SystemTrace` once per (trace,
     system config) and ``run_policies``/``repro.cachesim.sweep`` reuse
     one artifact across every policy, so a P-policy comparison costs one
     sweep plus P cheap replays instead of P full runs.

  2. BATCHED TABLES — by I2, a decision within a view version is a pure
     function of the n-bit indication pattern, so the whole run needs at
     most V * 2^n distinct selections.  All of them are computed in ONE
     ``repro.core.batched.ds_pgm_batched`` call (float64, see
     ``selection_tables``) — the JAX router path, fed the simulator's
     entire version history at once.

  3. REPLAY — selections, hits and access counts become vectorised table
     lookups over the trace; only the service-cost accumulation stays a
     scalar fold so float-addition order matches the reference exactly.

``fna_cal`` breaks I2 — its empirical EWMAs move on every probe outcome —
so phases 2-3 are replaced by the speculative segmented replay in
``repro.cachesim.fna_cal_fast`` (same shared phase-1 artifact).

Parity caveat: all state evolution and accounting here is replicated
operation-for-operation, but the DS_PGM tables evaluate Eq. (10) through
``exp(cumsum(log .))`` in float64 rather than the scalar running product,
and pick the argmin rather than applying the scalar path's EPS (1e-12)
improvement dead-band.  The two can only disagree when two prefix costs
coincide to within ~1e-12 absolute — a measure-zero coincidence of the
data-derived estimates, ruled out empirically by the parity suite
(``tests/test_fastpath.py``) across every policy x trace x interval
combination tested.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.cachesim.simulator import SimResult, Simulator
from repro.cachesim.systemstate import SystemTrace
from repro.core import hocs_fna
from repro.core.batched import MAX_EXHAUSTIVE_TABLE_CACHES as _MAX_EXH_TABLE_CACHES
from repro.core.policies import ds_pgm, exhaustive

# 2^n tables per version: past this the reference loop is the better deal
_MAX_TABLE_CACHES = 12


def _selection_masks(sim: Simulator, pi_v: np.ndarray, nu_v: np.ndarray,
                     costs, miss_penalty: float) -> np.ndarray:
    """[V * 2^n] selection bitmasks — phase 2, one row per (version,
    indication-pattern) pair."""
    cfg = sim.cfg
    n = cfg.n_caches
    k = 1 << n
    v_count = pi_v.shape[0]
    pow2 = 1 << np.arange(n, dtype=np.int64)
    if cfg.policy == "hocs":   # Algorithm 1 on pooled homogeneous estimates
        pos_by_p = [[j for j in range(n) if (p >> j) & 1] for p in range(k)]
        neg_by_p = [[j for j in range(n) if not (p >> j) & 1]
                    for p in range(k)]
        sel = np.empty(v_count * k, dtype=np.int64)
        for v in range(v_count):
            # left-to-right Python sum: bit-identical to the reference
            # loop's sum(self._pi)/n (np.sum pairwise-accumulates for
            # n >= 8, which can differ in the last ulp)
            pi_h = sum(pi_v[v].tolist()) / n
            nu_h = sum(nu_v[v].tolist()) / n
            # (r0*, r1*) depends on the pattern only through its popcount
            r_by_nx = [hocs_fna(nx, n, pi_h, nu_h, miss_penalty)
                       for nx in range(n + 1)]
            for p in range(k):
                pos = pos_by_p[p]
                r0, r1 = r_by_nx[len(pos)]
                m = 0
                for j in pos[:r1] + neg_by_p[p][:r0]:
                    m |= 1 << j
                sel[v * k + p] = m
        return sel
    if sim.alg is ds_pgm:      # the batched JAX path (float64 — bit-exact)
        from repro.core.batched import selection_tables
        pi_mat, nu_mat = pi_v, nu_v
        # pad V to a power-of-two bucket: XLA compiles per shape, and
        # bucketing makes shapes recur across runs (padding rows are
        # copies of the last version; their masks are discarded)
        vpad = 1 << max(4, (v_count - 1).bit_length())
        if vpad > v_count:
            pi_mat = np.concatenate(
                [pi_mat, np.repeat(pi_mat[-1:], vpad - v_count, 0)])
            nu_mat = np.concatenate(
                [nu_mat, np.repeat(nu_mat[-1:], vpad - v_count, 0)])
        mask = selection_tables(costs, pi_mat, nu_mat, miss_penalty,
                                fno=(cfg.policy == "fno"))
        return (mask.reshape(-1, n)[:v_count * k] @ pow2).astype(np.int64)
    if sim.alg is exhaustive and n <= _MAX_EXH_TABLE_CACHES:
        # batched 2^n-subset enumeration over every (version, pattern) row
        from repro.core.batched import exhaustive_tables
        return exhaustive_tables(costs, pi_v, nu_v, miss_penalty,
                                 fno=(cfg.policy == "fno")).reshape(-1)
    # generic subroutine: scalar call per (version, pattern)
    sel = np.empty(v_count * k, dtype=np.int64)
    for v in range(v_count):
        pi, nu = pi_v[v], nu_v[v]
        for p in range(k):
            if cfg.policy == "fno":
                pos = [j for j in range(n) if (p >> j) & 1]
                chosen = []
                if pos:
                    sub = sim.alg([costs[j] for j in pos],
                                  [float(pi[j]) for j in pos], miss_penalty)
                    chosen = [pos[t] for t in sub]
            else:
                rhos = [float(pi[j]) if (p >> j) & 1 else float(nu[j])
                        for j in range(n)]
                chosen = sim.alg(costs, rhos, miss_penalty)
            m = 0
            for j in chosen:
                m |= 1 << j
            sel[v * k + p] = m
    return sel


def accumulate_replay(res: SimResult, st: SystemTrace, selm: np.ndarray,
                      costs, miss_penalty: float) -> SimResult:
    """Fold per-request selection bitmasks into the SimResult exactly as
    the reference loop would: per-mask cost sums in ascending cache order,
    hit iff the designated cache is both selected and resident, and a
    scalar float fold so cost-addition order matches bit-for-bit."""
    n = st.n
    k = 1 << n
    acc_by_mask = np.asarray(
        [sum(costs[j] for j in range(n) if (m >> j) & 1) for m in range(k)],
        np.float64)
    popcount = np.asarray([bin(m).count("1") for m in range(k)], np.int64)
    hit_arr = st.in_dj & (((selm >> st.dj_all) & 1) != 0)
    acc = acc_by_mask[selm]
    cost_arr = np.where(hit_arr, acc, acc + miss_penalty)
    pos_acc = int(popcount[selm & st.pats].sum())
    total_cost = res.total_cost
    for c in cost_arr.tolist():
        total_cost += c
    res.total_cost = total_cost
    res.hits += int(np.count_nonzero(hit_arr))
    res.pos_accesses += pos_acc
    res.neg_accesses += int(popcount[selm].sum()) - pos_acc
    res.n_requests += st.trace_len
    return res


def run_fast(sim: Simulator, trace: np.ndarray, res: SimResult,
             system: Optional[SystemTrace] = None) -> SimResult:
    cfg = sim.cfg
    n = cfg.n_caches
    if n > _MAX_TABLE_CACHES:
        return sim._run_reference(trace, res)
    if cfg.policy == "fna_cal" and sim.alg is exhaustive and \
            n > _MAX_EXH_TABLE_CACHES:
        # the segmented replay's verification pass needs the batched
        # subset enumeration; past its budget the reference loop wins
        return sim._run_reference(trace, res)
    costs = list(cfg.costs)
    M = cfg.miss_penalty
    N = int(trace.shape[0])
    if N == 0:
        return res

    # --- phase 1: the shared system sweep (or a reused artifact) --------
    if system is None:
        system = SystemTrace.compute(sim, trace)
    else:
        system.install(sim, trace)
    sim.last_system = system
    st = system
    st.add_quality(res)

    if cfg.policy == "fna_cal":
        from repro.cachesim.fna_cal_fast import replay_fna_cal
        return replay_fna_cal(sim, st, res)

    if cfg.policy == "pi":
        # PI accesses the cheapest cache truly holding x; hash placement
        # means only the designated cache can — so it IS the selection
        cost_arr = np.where(st.in_dj,
                            np.asarray(costs, np.float64)[st.dj_all], M)
        hits = int(np.count_nonzero(st.in_dj))
        posm = ((st.pats >> st.dj_all) & 1).astype(bool) & st.in_dj
        pos_acc = int(np.count_nonzero(posm))
        total_cost = res.total_cost
        for c in cost_arr.tolist():
            total_cost += c
        res.total_cost = total_cost
        res.hits += hits
        res.pos_accesses += pos_acc
        res.neg_accesses += hits - pos_acc
        res.n_requests += N
        return res

    # --- phase 2: every (version, pattern) selection in one batch -------
    k = 1 << n
    selmask = _selection_masks(sim, st.pi_v, st.nu_v, costs, M)  # [V * 2^n]
    # --- phase 3: vectorised replay -------------------------------------
    selm = selmask[st.ver_per_req * k + st.pats]                 # [N]
    return accumulate_replay(res, st, selm, costs, M)
