"""Fast engine, policy side: decision-plan dispatch + vectorised replay.

Bit-exact twin of ``Simulator._run_reference`` built on the invariants
documented in the ``repro.cachesim.simulator`` module docstring (I1:
stale bitmaps frozen between advertisements; I2: (pi, nu) views frozen
between version bumps) plus two structural facts the reference loop
obscures:

  * the SYSTEM state (LRU contents, CBF counters, stale bitmaps, FP/FN
    estimates, q-estimates) evolves independently of any policy's
    decisions — placement is by hash and every request is placed in its
    designated cache;
  * a key can only ever reside in its DESIGNATED cache, so each cache's
    dynamics depend only on its own designated subsequence of the trace.

The engine therefore runs in phases:

  1. SYSTEM SWEEP — per-cache LRU passes, CBF event walks, vectorised
     per-epoch indications, batched q-updates, and the full view-version
     history.  This phase lives in ``repro.cachesim.systemstate`` and is
     POLICY-INDEPENDENT: :func:`run_fast` computes a
     :class:`~repro.cachesim.systemstate.SystemTrace` once per (trace,
     system config) and ``run_policies``/``repro.cachesim.sweep`` reuse
     one artifact across every policy AND across every decision-side
     sweep cell, so a P-policy, C-cell comparison costs one sweep plus
     P*C cheap replays instead of P*C full runs.

  2. DECISION PLAN — by I2, a decision within a view version is a pure
     function of the n-bit indication pattern, so the whole run needs at
     most V * 2^n distinct selections.  HOW those are produced is the
     provider registry of ``repro.cachesim.engine``: batched JAX DS_PGM
     tables, the exact HOCS mirror, the 2^n-subset enumeration, the
     generic scalar fallback, the segmented ``fna_cal`` replay, or the
     direct PI replay — ``plan_for(cfg)`` picks the first match, and
     table plans memoise their output on the shared SystemTrace so
     decision-side sweeps can prefetch them stacked.

  3. REPLAY — selections, hits and access counts become vectorised table
     lookups over the trace (:func:`accumulate_replay`); only the
     service-cost accumulation stays a scalar fold so float-addition
     order matches the reference exactly.

``fna_cal`` breaks I2 — its empirical EWMAs move on every probe outcome —
so phases 2-3 are replaced by the speculative segmented replay in
``repro.cachesim.fna_cal_fast`` (same shared phase-1 artifact).

Parity caveat: all state evolution and accounting here is replicated
operation-for-operation, but the DS_PGM tables evaluate Eq. (10) through
``exp(cumsum(log .))`` in float64 rather than the scalar running product,
and pick the argmin rather than applying the scalar path's EPS (1e-12)
improvement dead-band.  The two can only disagree when two prefix costs
coincide to within ~1e-12 absolute — a measure-zero coincidence of the
data-derived estimates, ruled out empirically by the parity suite
(``tests/test_fastpath.py``) across every policy x trace x interval
combination tested.  The HOCS mirror carries the analogous caveat on its
candidate shortlist (``repro.core.batched.hocs_fna_batched``).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.cachesim.simulator import SimResult, Simulator
from repro.cachesim.systemstate import SystemTrace


def accumulate_replay(res: SimResult, st: SystemTrace, selm: np.ndarray,
                      costs, miss_penalty: float) -> SimResult:
    """Fold per-request selection bitmasks into the SimResult exactly as
    the reference loop would: per-mask cost sums in ascending cache order,
    hit iff the designated cache is both selected and resident, and a
    scalar float fold so cost-addition order matches bit-for-bit."""
    n = st.n
    k = 1 << n
    acc_by_mask = np.asarray(
        [sum(costs[j] for j in range(n) if (m >> j) & 1) for m in range(k)],
        np.float64)
    popcount = np.asarray([bin(m).count("1") for m in range(k)], np.int64)
    hit_arr = st.in_dj & (((selm >> st.dj_all) & 1) != 0)
    acc = acc_by_mask[selm]
    cost_arr = np.where(hit_arr, acc, acc + miss_penalty)
    pos_acc = int(popcount[selm & st.pats].sum())
    total_cost = res.total_cost
    for c in cost_arr.tolist():
        total_cost += c
    res.total_cost = total_cost
    res.hits += int(np.count_nonzero(hit_arr))
    res.pos_accesses += pos_acc
    res.neg_accesses += int(popcount[selm].sum()) - pos_acc
    res.n_requests += st.trace_len
    return res


def run_fast(sim: Simulator, trace: np.ndarray, res: SimResult,
             system: Optional[SystemTrace] = None,
             chunk_size: Optional[int] = None, spill=None) -> SimResult:
    from repro.cachesim.engine import plan_for
    plan = plan_for(sim.cfg)
    if plan is None:
        # outside every provider's budget (n beyond the table limits):
        # the reference loop is the better deal
        return sim._run_reference(trace, res)
    if trace.shape[0] == 0:
        return res

    # --- phase 1: the shared system sweep (or a reused artifact) --------
    if system is None:
        system = SystemTrace.compute(sim, trace, chunk_size=chunk_size,
                                     spill=spill)
    else:
        system.install(sim, trace)
    sim.last_system = system
    system.add_quality(res)
    system.add_advert(res)

    # --- phases 2-3: the decision plan ----------------------------------
    return plan.replay(sim, system, res)
