from repro.optim.adamw import (
    OptConfig,
    TrainState,
    global_norm,
    init_train_state,
    lr_at,
    make_train_step,
)

__all__ = ["OptConfig", "TrainState", "init_train_state", "make_train_step",
           "lr_at", "global_norm"]
