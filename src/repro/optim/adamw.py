"""AdamW + warmup-cosine schedule + global-norm clipping, from scratch.

The train state is a plain dict pytree (checkpoint friendly):
  {"params": ..., "m": ..., "v": ..., "step": int32, "ef": optional}

``make_train_step`` builds the jit-able ``train_step(state, batch)`` used by
the launcher, the dry-run lowering, and the smoke tests.  Optional int8
gradient compression with error feedback (see distributed/compression.py)
plugs in between backward and the optimizer.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
TrainState = Dict[str, Any]


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # gradient compression: "none" | "int8_ef" (quantize-dequantize with
    # error feedback; models bandwidth-compressed DP all-reduce)
    compression: str = "none"


def lr_at(cfg: OptConfig, step) -> jax.Array:
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.lr * step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac * cfg.lr + (1 - cfg.min_lr_frac) * cfg.lr * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    g = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda l: (l.astype(jnp.float32) * scale).astype(l.dtype), tree), g


def init_train_state(params: PyTree, cfg: OptConfig) -> TrainState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "params": params,
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.compression == "int8_ef":
        state["ef"] = jax.tree.map(zeros, params)
    return state


def _adamw_leaf(p, g, m, v, lr, cfg: OptConfig, t):
    g = g.astype(jnp.float32)
    pf = p.astype(jnp.float32)
    m = cfg.b1 * m + (1 - cfg.b1) * g
    v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
    mh = m / (1 - cfg.b1 ** t)
    vh = v / (1 - cfg.b2 ** t)
    upd = mh / (jnp.sqrt(vh) + cfg.eps)
    if p.ndim >= 2:  # decoupled weight decay on matrices only
        upd = upd + cfg.weight_decay * pf
    return (pf - lr * upd).astype(p.dtype), m, v


def make_train_step(model, cfg: OptConfig) -> Callable[[TrainState, Any], Tuple[TrainState, Dict]]:
    from repro.distributed import compression as comp

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict[str, jax.Array]]:
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
            state["params"], batch)
        if cfg.compression == "int8_ef":
            grads, new_ef = comp.compress_with_error_feedback(grads, state["ef"])
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
        t = (state["step"] + 1).astype(jnp.float32)
        lr = lr_at(cfg, state["step"] + 1)

        def upd(p, g, m, v):
            return _adamw_leaf(p, g, m, v, lr, cfg, t)

        flat_p, tdef = jax.tree.flatten(state["params"])
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_state = {
            "params": tdef.unflatten([o[0] for o in out]),
            "m": tdef.unflatten([o[1] for o in out]),
            "v": tdef.unflatten([o[2] for o in out]),
            "step": state["step"] + 1,
        }
        if cfg.compression == "int8_ef":
            new_state["ef"] = new_ef
        metrics = dict(metrics)
        metrics.update({"grad_norm": gnorm, "lr": lr})
        return new_state, metrics

    return train_step
