"""Fault-tolerant checkpointing: atomic, async-capable, elastic-reshard.

Layout:  <dir>/step_<N>/
            manifest.json   (paths, shapes, dtypes, step)
            arrays.npz      (flattened leaf name -> ndarray)

Properties needed at fleet scale, all implemented here:
  * ATOMIC commit — writes land in ``step_N.tmp`` and are ``os.rename``d
    (a preempted writer never leaves a half-readable checkpoint).
  * ASYNC save — device->host transfer happens synchronously (cheap),
    the disk write runs on a background thread so training continues.
  * ELASTIC restore — arrays are stored unsharded; ``restore`` lays them
    out onto ANY target mesh/shardings (mesh shape may differ from the
    writer's — node-failure recovery onto fewer hosts).
  * GC — keep the newest ``keep`` checkpoints.

On a real multi-host pod each host writes its local shards; here (single
process) the full-array path is exact and the reshard logic is identical.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any
_SEP = "::"


def _flatten(tree: PyTree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    flat = {}
    for path, leaf in leaves:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat, jax.tree.structure(tree)


def save(state: PyTree, ckpt_dir: str, step: int, *, keep: int = 3,
         async_: bool = False) -> threading.Thread | None:
    """Checkpoint ``state`` at ``step``.  Returns the writer thread if async."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat, _ = _flatten(state)  # device->host happens HERE, synchronously

    def write():
        tmp = ckpt_dir / f"step_{step}.tmp"
        final = ckpt_dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        np.savez(tmp / "arrays.npz", **flat)
        manifest = {
            "step": step,
            "time": time.time(),
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in flat.items()},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        _gc(ckpt_dir, keep)

    if async_:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def _gc(ckpt_dir: Path, keep: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(ckpt_dir / f"step_{s}", ignore_errors=True)


def all_steps(ckpt_dir) -> List[int]:
    p = Path(ckpt_dir)
    if not p.exists():
        return []
    out = []
    for d in p.iterdir():
        if d.is_dir() and d.name.startswith("step_") and not d.name.endswith(".tmp"):
            if (d / "manifest.json").exists():
                out.append(int(d.name[5:]))
    return sorted(out)


def latest_step(ckpt_dir) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, abstract_state: PyTree, step: Optional[int] = None,
            shardings: Optional[PyTree] = None) -> PyTree:
    """Restore onto the CURRENT topology.

    ``abstract_state``: pytree of ShapeDtypeStructs (or arrays) defining
    structure; ``shardings``: optional matching tree of NamedShardings for
    the (possibly different) target mesh — elastic restart path.
    """
    ckpt_dir = Path(ckpt_dir)
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    data = np.load(ckpt_dir / f"step_{step}" / "arrays.npz")
    leaves, treedef = jax.tree_util.tree_flatten_with_path(abstract_state)
    sh_leaves = None
    if shardings is not None:
        sh_leaves = [s for _, s in jax.tree_util.tree_flatten_with_path(shardings)[0]]
    out = []
    for i, (path, leaf) in enumerate(leaves):
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        arr = data[key]
        want_dtype = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
        arr = arr.astype(want_dtype)
        if sh_leaves is not None:
            out.append(jax.device_put(arr, sh_leaves[i]))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out)


class CheckpointManager:
    """Train-loop-facing wrapper: periodic + preemption saves, async by
    default, waits for the in-flight write before starting another."""

    def __init__(self, ckpt_dir: str, interval: int = 100, keep: int = 3,
                 async_: bool = True):
        self.ckpt_dir = ckpt_dir
        self.interval = interval
        self.keep = keep
        self.async_ = async_
        self._inflight: Optional[threading.Thread] = None

    def maybe_save(self, state: PyTree, step: int, *, force: bool = False) -> bool:
        if not force and (self.interval <= 0 or step % self.interval != 0):
            return False
        self.wait()
        self._inflight = save(state, self.ckpt_dir, step, keep=self.keep,
                              async_=self.async_)
        return True

    def wait(self) -> None:
        if self._inflight is not None:
            self._inflight.join()
            self._inflight = None
