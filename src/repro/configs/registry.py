"""Architecture / shape registry and dry-run cell enumeration."""
from __future__ import annotations

import importlib
from typing import Iterator, List, Tuple

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, SHAPES_BY_NAME

# arch-id -> module name
ARCH_MODULES = {
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "internvl2-1b": "internvl2_1b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "smollm-135m": "smollm_135m",
    "granite-3-2b": "granite_3_2b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "yi-6b": "yi_6b",
    "mamba2-370m": "mamba2_370m",
    "zamba2-7b": "zamba2_7b",
}

ARCHS: List[str] = list(ARCH_MODULES)

# Archs with sub-quadratic sequence mixing; only these run ``long_500k``
# (pure full-attention archs skip it — see DESIGN.md §Arch-applicability).
SUBQUADRATIC = {"mamba2-370m", "zamba2-7b"}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{ARCH_MODULES[arch]}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES_BY_NAME[name]


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """Skip rules for (arch x shape) cells."""
    if shape.name == "long_500k":
        return cfg.name in SUBQUADRATIC
    # No encoder-only archs in the pool; all archs have a decode step.
    return True


def cells() -> Iterator[Tuple[str, str]]:
    """All applicable (arch, shape) dry-run cells."""
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            if shape_applicable(cfg, shape):
                yield arch, shape.name
