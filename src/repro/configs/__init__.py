from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, SHAPES_BY_NAME
from repro.configs.registry import (
    ARCHS,
    SUBQUADRATIC,
    cells,
    get_config,
    get_shape,
    shape_applicable,
)

__all__ = [
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "SHAPES_BY_NAME",
    "ARCHS",
    "SUBQUADRATIC",
    "cells",
    "get_config",
    "get_shape",
    "shape_applicable",
]
