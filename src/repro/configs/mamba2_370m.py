"""mamba2-370m [ssm] — 48L d1024, attention-free SSD (state-space duality),
ssm_state=128, vocab 50280.  [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    head_dim=1,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_headdim=64,
    tie_embeddings=True,
)
