"""qwen3-moe-30b-a3b [moe] — 48L d2048 32H (kv=4) per-expert ff=768, vocab 151936,
MoE 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,          # per-expert hidden dim
    vocab=151936,
    n_experts=128,
    topk=8,
    moe_mode="dispatch",
    expert_pad=16,
)
