"""internvl2-1b [vlm] — 24L d896 14H (kv=2) ff=4864 vocab 151655.
InternViT frontend is a STUB: ``input_specs()`` provides precomputed patch
embeddings (B, n_patches, d_model); the assigned backbone (Qwen2-0.5B-like)
is implemented in full.  [arXiv:2404.16821]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151655,
    n_patches=1024,
)
