"""seamless-m4t-medium [audio] — enc-dec, 12L encoder + 12L decoder, d1024 16H
(kv=16 = MHA) ff=4096 vocab 256206.  The speech frontend is a STUB:
``input_specs()`` provides precomputed frame embeddings (B, S, d_model).
[arXiv:2308.11596]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,      # decoder depth
    enc_layers=12,    # encoder depth
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
)
