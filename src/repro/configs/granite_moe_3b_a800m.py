"""granite-moe-3b-a800m [moe] — 32L d1536 24H (kv=8) per-expert ff=512, vocab 49155,
MoE 40 experts top-8.  [hf:ibm-granite/granite-3.0-3b-a800m-base family].

Note: the assignment text says both "MoE 40e top-8" and "32 experts top-8";
the 3b-a800m member of the Granite-3.0 family uses 40 experts, so we use 40.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,          # per-expert hidden dim
    vocab=49155,
    n_experts=40,
    topk=8,
    moe_mode="dispatch",
    expert_pad=16,
)
