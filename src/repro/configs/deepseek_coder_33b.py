"""deepseek-coder-33b [dense] — 62L d7168 56H (kv=8) ff=19200 vocab 32256,
llama-arch GQA.  [arXiv:2401.14196]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=19200,
    vocab=32256,
)
