"""Configuration system for the repro framework.

Every assigned architecture gets its own module in ``repro.configs``
exporting a ``CONFIG: ModelConfig``.  Input shapes (the assigned
``train_4k`` / ``prefill_32k`` / ``decode_32k`` / ``long_500k`` cells) are
described by :class:`ShapeConfig` and the applicability rules live in
:func:`repro.configs.registry.cells`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters + runtime knobs.

    ``family`` selects the model implementation:
      * ``dense``   – decoder-only transformer (GQA) [transformer.py]
      * ``moe``     – decoder-only transformer with MoE FFN [transformer.py]
      * ``vlm``     – decoder-only transformer consuming a precomputed
                      patch-embedding prefix (modality frontend is a STUB)
      * ``encdec``  – encoder/decoder transformer; encoder consumes
                      precomputed audio-frame embeddings (STUB frontend)
      * ``ssm``     – attention-free Mamba2 (SSD) stack
      * ``hybrid``  – Mamba2 backbone with shared attention blocks (Zamba2)
    """

    name: str
    family: str  # dense | moe | vlm | encdec | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # Derived unless overridden: head_dim = d_model // n_heads.
    head_dim: int = 0

    # --- MoE ---
    n_experts: int = 0
    topk: int = 0
    # d_ff above is the *per-expert* hidden dim for MoE families.
    moe_mode: str = "dense"  # dense | dispatch  (see models/layers.py)
    capacity_factor: float = 1.25
    expert_pad: int = 1      # pad expert count to a multiple (16 for TP meshes)
    moe_groups: int = 16     # dispatch groups per sequence (model-axis aligned)

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256

    # --- Hybrid (Zamba2) ---
    shared_every: int = 6   # apply a shared attention block every k mamba blocks
    n_shared: int = 2       # number of alternating shared blocks

    # --- Modality stubs ---
    n_patches: int = 0      # vlm: number of precomputed patch embeddings
    enc_layers: int = 0     # encdec: encoder depth (n_layers is decoder depth)

    # --- Runtime knobs ---
    dtype: str = "bfloat16"        # activation/compute dtype
    param_dtype: str = "float32"   # master weights
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    attn_mode: str = "chunked"     # chunked | naive | pallas
    attn_chunk: int = 1024         # KV chunk for the chunked (flash-style) path
    # int8 KV cache (decoder-only families): per-(token, head) absmax scales;
    # halves decode HBM traffic vs bf16 (EXPERIMENTS.md §Perf cell 3, iter C3)
    kv_quant: bool = False
    remat: str = "full"            # full | none | dots
    # Sequence-parallel residual stream (activations sharded on "model"
    # axis between blocks).  See distributed/sharding.py.
    seq_parallel: bool = True

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))

    # ---- derived quantities -------------------------------------------------
    @property
    def vocab_padded(self) -> int:
        """Vocab padded to a multiple of 256 (TP-shardable, MXU-aligned).
        Padded entries are ordinary unused classes (standard practice)."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def n_experts_padded(self) -> int:
        if not self.n_experts:
            return 0
        return ((self.n_experts + self.expert_pad - 1) // self.expert_pad) * self.expert_pad

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def param_count(self) -> int:
        """Analytic parameter count (matches models/*.init_params)."""
        d, v = self.d_model, self.vocab_padded
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d  # unembedding
        attn = d * (self.n_heads * self.head_dim) + 2 * d * (self.n_kv_heads * self.head_dim) \
            + (self.n_heads * self.head_dim) * d
        dense_ffn = 3 * d * self.d_ff  # SwiGLU: gate, up, down
        if self.family in ("dense", "vlm"):
            n += self.n_layers * (attn + dense_ffn + 2 * d)
            if self.family == "vlm":
                n += d * d  # patch_proj
        elif self.family == "moe":
            ep = self.n_experts_padded
            moe_ffn = ep * 3 * d * self.d_ff + d * ep  # experts + router
            n += self.n_layers * (attn + moe_ffn + 2 * d)
        elif self.family == "encdec":
            cross = attn
            n += d * d + d  # frame_proj + enc_norm
            n += self.enc_layers * (attn + dense_ffn + 2 * d)
            n += self.n_layers * (attn + cross + dense_ffn + 3 * d)
        elif self.family == "ssm":
            n += self.n_layers * (self._mamba_block_params() + d)
        elif self.family == "hybrid":
            n += self.n_layers * (self._mamba_block_params() + d)
            n += self.n_shared * (attn + dense_ffn + 2 * d)
        n += d  # final norm
        return n

    def _mamba_block_params(self) -> int:
        d, di, ns = self.d_model, self.d_inner, self.ssm_state
        nh = self.ssm_heads
        # in_proj -> [z, x, B, C, dt] ; conv over (x, B, C); out_proj
        ng = 1  # single B/C group
        in_proj = d * (2 * di + 2 * ng * ns + nh)
        conv = (self.ssm_conv + 1) * (di + 2 * ng * ns)  # conv_w + conv_b
        out_proj = di * d
        misc = 3 * nh  # A_log, D, dt_bias
        return in_proj + conv + out_proj + misc + di  # + gate norm

    def active_param_count(self) -> int:
        """Params touched per token (MoE: topk experts only)."""
        if self.family != "moe":
            return self.param_count()
        full = self.param_count()
        inactive = self.n_layers * (self.n_experts - self.topk) * 3 * self.d_model * self.d_ff
        return full - inactive

    def reduced(self, **over) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=96 if self.family != "moe" else 32,
            vocab=256,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            topk=min(self.topk, 2) if self.topk else 0,
            expert_pad=1,
            moe_groups=4,
            moe_mode="dense" if self.family == "moe" else self.moe_mode,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=16,
            ssm_chunk=16,
            shared_every=2,
            n_shared=min(self.n_shared, 2),
            n_patches=16 if self.n_patches else 0,
            enc_layers=min(self.enc_layers, 2) if self.enc_layers else 0,
            dtype="float32",
            param_dtype="float32",
            remat="none",
            attn_chunk=32,
        )
        kw.update(over)
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}
