"""zamba2-7b [hybrid] — 81 Mamba2 blocks d3584 + 2 alternating *shared*
attention blocks (32H, kv=32, ff=14336) applied every 6 mamba blocks,
ssm_state=64, vocab 32000.  [arXiv:2411.15242]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    ssm_state=64,
    ssm_headdim=64,
    shared_every=6,
    n_shared=2,
)
