"""Jitted wrapper for the SSD kernel with backend auto-select."""
from __future__ import annotations

import jax

from repro.kernels.ssd.ssd import ssd_pallas


def ssd_scan(x, dt, A, B, C, *, chunk: int = 128):
    """Drop-in for models.ssm.ssd_chunked's (y, final_state) contract."""
    interpret = jax.default_backend() == "cpu"
    return ssd_pallas(x, dt, A, B, C, chunk=chunk, interpret=interpret)
