"""Oracle for the SSD kernel: the sequential Mamba2 recurrence."""
from __future__ import annotations

from repro.models.ssm import ssd_reference


def ssd_ref(x, dt, A, B, C, *, initial_state=None):
    """x: [b,s,h,p]; dt: [b,s,h]; A: [h]; B/C: [b,s,n].
    Returns (y [b,s,h,p], final_state [b,h,p,n])."""
    return ssd_reference(x, dt, A, B, C, initial_state=initial_state)
