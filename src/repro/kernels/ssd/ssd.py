"""Pallas TPU kernel: Mamba2 SSD (state-space duality) chunked scan.

Grid (B, H, nc) — chunks innermost, iterated sequentially per (batch,
head), carrying the running SSM state [P, N] in VMEM scratch.  Within a
chunk everything is dense MXU work (the duality: the intra-chunk part is a
masked [L, L] attention-like product):

  y_intra = ((C B^T) * decay_mask * dt) @ x            two [L,*] matmuls
  y_inter = exp(cum) * (C @ state_prev)                one  [L,N]@[N,P]
  state   = state_prev * full_decay + (w*x)^T @ B      one  [P,L]@[L,N]

HARDWARE ADAPTATION: the CUDA Mamba2 kernel leans on warp shuffles for the
intra-chunk cumulative sums; on TPU the cumsum over the chunk dim is a
cheap VPU op and all four products map straight onto the MXU with
[L, N, P] in {64,128} tiles.  Chunk length trades VMEM footprint
(L*(P+2N) f32) against the O(S*L) duality overhead — 128..256 fits v5e.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, state_ref, *,
                chunk: int):
    h = pl.program_id(1)
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # [L, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # [L]
    A = a_ref[h]                                     # scalar (negative)
    Bm = b_ref[0].astype(jnp.float32)                # [L, N]
    Cm = c_ref[0].astype(jnp.float32)                # [L, N]

    dA = dt * A                                      # [L]
    cum = jnp.cumsum(dA)                             # inclusive [L]
    # intra-chunk: w[i,j] = exp(cum_i - cum_j) * dt_j * (C_i . B_j), j <= i
    seg = cum[:, None] - cum[None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, seg.shape, 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, seg.shape, 1)
    decay = jnp.where(mask, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))   # [L, L]
    w = cb * decay * dt[None, :]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())))      # [L, P]
    # inter-chunk: y += exp(cum) * (C @ state^T)   state: [P, N]
    prev = state_ref[...]                                        # [P, N]
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, prev, (((1,), (1,)), ((), ())))                      # [L, P]
    # state update: state = prev * exp(sum dA) + (w2 * x)^T @ B
    w2 = jnp.exp(cum[-1] - cum) * dt                             # [L]
    new_state = prev * jnp.exp(cum[-1]) + jax.lax.dot_general(
        x * w2[:, None], Bm, (((0,), (0,)), ((), ())))           # [P, N]
    state_ref[...] = new_state
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _emit_state():
        st_ref[0, 0] = new_state.astype(st_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_pallas(x, dt, A, B, C, *, chunk: int = 128, interpret: bool = True):
    """x: [b,s,h,p]; dt: [b,s,h]; A: [h]; B/C: [b,s,n] (single group).
    Returns (y [b,s,h,p], final_state [b,h,p,n])."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    grid = (b, h, nc)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, st = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((h,), lambda bi, hi, ci: (0,)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), B, C)
    return y, st
