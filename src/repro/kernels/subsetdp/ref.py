"""Pure-jnp oracle for the subset-DP kernel.

Computes the Eq. (10) value of EVERY subset mask m for a batch of rho
rows: ``phi[b, m] = sum_{j in m} costs[j] + M * prod_{j in m} rhos[b, j]``.

The scalar reference loop (``repro.core.exhaustive``) accumulates a
subset's cost and exclusion product by ASCENDING cache index, and the
NumPy DP twin (``repro.core.batched._subset_dp``) reproduces that IEEE
operation order through its highest-set-bit recurrence.  This mirror gets
the same order a third way: n masked multiply/add sweeps in ascending j.
Multiplying a lane by exactly 1.0 (or adding exactly 0.0 to a
non-negative partial sum) is an IEEE identity, so lanes whose bit j is
clear pass through unchanged and every lane ends up with precisely the
ascending-index product/sum chain of its set bits — bit-exact with both
twins, but expressed as O(n) vectorised sweeps instead of a 2^n-step
serial recurrence.  The Pallas kernel (``subsetdp.py``) tiles the product
sweep over row blocks.

BIT-EXACTNESS vs XLA FMA CONTRACTION: the one place the subset value
mixes a multiply into an add is the final ``cost + prod``.  Inside a
single jitted computation XLA:CPU contracts that pair into an FMA (single
rounding — off by one ulp from the oracle's two roundings, and no flag or
optimization barrier reliably prevents it).  The product sweep is muls
and selects only and the cost sweep adds only, so each is contraction-
free; :func:`subset_parts_ref` therefore returns them SEPARATELY and the
caller performs the final add outside the jitted computation (NumPy, or a
second jit whose inputs they are), which rounds exactly like the oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def subset_cost_ref(costs, n: int):
    """[1, 2^n] per-subset cost sums, ascending-index add order."""
    k = 1 << n
    costs = jnp.asarray(costs)
    dtype = costs.dtype
    lanes = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)
    cost = jnp.zeros((1, k), dtype)
    zero = jnp.asarray(0.0, dtype)
    for j in range(n):
        bit = ((lanes >> j) & 1) == 1
        cost = cost + jnp.where(bit, costs[j], zero)
    return cost


def subset_prod_ref(rhos, miss_penalty):
    """[B, 2^n] per-subset exclusion products (times M), ascending-index
    multiply order — the kernel's oracle."""
    rhos = jnp.asarray(rhos)
    b, n = rhos.shape
    k = 1 << n
    dtype = rhos.dtype
    lanes = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)
    prod = jnp.full((b, k), miss_penalty, dtype)
    one = jnp.asarray(1.0, dtype)
    for j in range(n):              # static unroll: ascending-index order
        bit = ((lanes >> j) & 1) == 1
        prod = prod * jnp.where(bit, rhos[:, j][:, None], one)
    return prod


def subset_parts_ref(costs, rhos, miss_penalty):
    """(cost [1, 2^n], prod [B, 2^n]) — add them OUTSIDE this computation
    for bit-exactness with ``_subset_dp`` (see module docstring)."""
    rhos = jnp.asarray(rhos)
    n = rhos.shape[1]
    return subset_cost_ref(jnp.asarray(costs, rhos.dtype), n), \
        subset_prod_ref(rhos, miss_penalty)


def subset_dp_ref(costs, rhos, miss_penalty):
    """[B, 2^n] Eq. (10) subset values (jnp; dtype follows ``rhos``).

    Bit-exact with ``repro.core.batched._subset_dp`` when evaluated
    EAGERLY; if traced into a larger jit, XLA may contract the final add
    into an FMA (use :func:`subset_parts_ref` there instead).
    """
    cost, prod = subset_parts_ref(costs, rhos, miss_penalty)
    return cost + prod
