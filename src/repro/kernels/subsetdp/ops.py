"""Public wrappers for the subset-DP kernel.

``subset_dp`` returns the full [B, 2^n] Eq. (10) value matrix;
``subset_argmin`` the winning subset mask per row (the exhaustive table
builders only need the argmin, so the masking + first-min reduction stays
on device and the 2^n-wide value matrix never leaves it).

Backends — all BIT-EXACT with the oracle (the three evaluate identical
IEEE operation chains; ``ref.py`` explains why, and why the final
``cost + prod`` add happens outside the jitted product computation):

  * ``"numpy"``  — the serial highest-set-bit recurrence
    (``repro.core.batched._subset_dp``), the golden oracle;
  * ``"jax"``    — the jitted jnp mirror (``ref.subset_prod_ref``);
  * ``"pallas"`` — the row-tiled kernel (``subsetdp.subset_prod_pallas``),
    interpret mode auto-selected off-TPU.

Everything runs in float64 under ``enable_x64`` (the fast engine's
exactness contract); inputs/outputs are NumPy arrays so callers stay
backend-agnostic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.kernels.subsetdp.ref import subset_prod_ref
from repro.kernels.subsetdp.subsetdp import (
    default_row_block,
    subset_prod_pallas,
)

_subset_prod_ref_jit = jax.jit(subset_prod_ref)


def _subset_costs(costs: np.ndarray, n: int) -> np.ndarray:
    """[2^n] per-subset cost sums, bitwise equal to ``_subset_dp``'s
    ``cost_m`` (ascending-index adds; +0.0 on clear bits is an IEEE
    identity on the non-negative partial sums)."""
    k = 1 << n
    lanes = np.arange(k)
    cost = np.zeros(k, np.float64)
    for j in range(n):
        bit = ((lanes >> j) & 1).astype(bool)
        cost = cost + np.where(bit, costs[j], 0.0)
    return cost


def _pad_rows(rhos: np.ndarray, multiple: int) -> np.ndarray:
    pad = (-rhos.shape[0]) % multiple
    if pad:
        rhos = np.concatenate([rhos, np.repeat(rhos[-1:], pad, axis=0)])
    return rhos


def _prod(rhos: np.ndarray, miss_penalty: float, backend: str,
          row_block, interpret):
    """Device-side [B(+pad), 2^n] subset products for the jax/pallas
    backends (call under ``enable_x64``)."""
    if backend == "jax":
        return _subset_prod_ref_jit(jnp.asarray(rhos), miss_penalty)
    if backend == "pallas":
        n = rhos.shape[1]
        rb = row_block if row_block is not None else default_row_block(n)
        return subset_prod_pallas(_pad_rows(rhos, rb), miss_penalty,
                                  row_block=rb, interpret=interpret)
    raise ValueError(f"unknown subset-DP backend {backend!r}")


def subset_dp(costs, rhos, miss_penalty, *, backend: str = "pallas",
              row_block: int = None, interpret: bool = None) -> np.ndarray:
    """[B, 2^n] float64 Eq. (10) subset values; see module docstring."""
    rhos = np.asarray(rhos, np.float64)
    costs = np.asarray(costs, np.float64)
    if backend == "numpy":
        from repro.core.batched import _subset_dp
        return _subset_dp(costs, rhos, miss_penalty)
    b, n = rhos.shape
    with enable_x64():
        prod = np.asarray(_prod(rhos, float(miss_penalty), backend,
                                row_block, interpret))[:b]
    # final add OUTSIDE the jitted computation — same two roundings as the
    # oracle's ``cost_m[None, :] + prod_m`` (ref.py: FMA contraction)
    return _subset_costs(costs, n)[None, :] + prod


@jax.jit
def _masked_argmin(cost, prod, allowed):
    phi = cost[None, :] + prod      # both are inputs: nothing to contract
    k = prod.shape[1]
    lanes = jnp.arange(k, dtype=jnp.int64)[None, :]
    bad = (lanes & ~allowed[:, None]) != 0
    phi = jnp.where(bad, jnp.inf, phi)
    # first minimal subset in ascending-mask order, like np.argmin
    return jnp.argmin(phi, axis=1).astype(jnp.int64)


def subset_argmin(costs, rhos, miss_penalty, *, allowed=None,
                  backend: str = "pallas", row_block: int = None,
                  interpret: bool = None) -> np.ndarray:
    """[B] int64 winning subset masks: the Eq. (10) minimiser per row,
    FIRST minimum in ascending-mask order (matching ``np.argmin`` and the
    scalar enumeration away from the ~1e-12 near-tie dead-band).

    ``allowed`` (int64 [B], optional) restricts row b to subsets of
    ``allowed[b]`` — the CS_FNO candidate restriction; the empty set is
    always allowed.
    """
    rhos = np.asarray(rhos, np.float64)
    costs = np.asarray(costs, np.float64)
    b, n = rhos.shape
    k = 1 << n
    if backend == "numpy":
        from repro.core.batched import _subset_dp
        phi = _subset_dp(costs, rhos, miss_penalty)
        if allowed is not None:
            bad = (np.arange(k)[None, :]
                   & ~np.asarray(allowed, np.int64)[:, None]) != 0
            phi[bad] = np.inf
        return np.argmin(phi, axis=1).astype(np.int64)
    with enable_x64():
        prod = _prod(rhos, float(miss_penalty), backend,
                     row_block, interpret)[:b]
        cost = jnp.asarray(_subset_costs(costs, n))
        if allowed is None:
            allow_arr = jnp.full((b,), k - 1, jnp.int64)
        else:
            allow_arr = jnp.asarray(np.asarray(allowed, np.int64))
        return np.asarray(_masked_argmin(cost, prod, allow_arr))
