"""Pallas kernel: batched Eq. (10) subset-DP table build.

The exhaustive table builders evaluate every one of the 2^n cache subsets
for B independent rho rows (B = cells x versions x patterns on a sweep
grid).  The NumPy twin (``repro.core.batched._subset_dp``) walks a serial
``for m in range(1, 2^n)`` highest-set-bit recurrence — the one serial
loop left in the fast engine's table layer.  This kernel replaces its
row-dependent half (the [B, 2^n] exclusion-product matrix) with n masked
multiply sweeps over the 2^n subset lanes (see ``ref.py`` for why that is
bit-exact), tiled over B row blocks the way ``kernels/bloom/bloom.py``
tiles key blocks.  The row-independent cost sums ([2^n], adds only) and
the final ``cost + prod`` happen OUTSIDE the kernel — the final add must
not share a jitted computation with the multiplies, or XLA contracts the
pair into an FMA and the last ulp drifts off the oracle (``ref.py``
documents the contraction hazard).

Grid: (row_blocks,).  Block shapes:
  mp    [1]               (miss penalty — an input, not a static, so one
                           compilation serves a whole penalty sweep)
  rhos  [RB, n]           (one row block)
  out   [RB, 2^n]         (subset products, M included)

The table math is float64 (the fast engine's exactness contract), so the
kernel is expected to run in interpret mode everywhere except TPU-class
backends with native f64 — the same ``default_interpret()`` auto-selection
as the Bloom kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: elements (RB * 2^n) per output block: bounds VMEM/working-set per tile
DEFAULT_BLOCK_ELEMS = 1 << 16
MAX_ROW_BLOCK = 256


def _subsetdp_kernel(mp_ref, rhos_ref, out_ref, *, n: int):
    k = 1 << n
    rhos = rhos_ref[...]                                        # [RB, n]
    rb = rhos.shape[0]
    dtype = rhos.dtype
    lanes = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)      # subset ids
    prod = jnp.full((rb, k), mp_ref[0], dtype)
    one = jnp.asarray(1.0, dtype)
    for j in range(n):      # n is small and static: unrolled, ascending j
        bit = ((lanes >> j) & 1) == 1
        prod = prod * jnp.where(bit, rhos[:, j][:, None], one)
    out_ref[...] = prod


def default_interpret() -> bool:
    """Compiled only on TPU; interpret mode everywhere else (the table
    math is float64 — see module docstring).  Pass ``interpret=False`` to
    override."""
    return jax.default_backend() != "tpu"


def default_row_block(n: int) -> int:
    """Rows per tile, scaled down with 2^n so a tile's output block stays
    near ``DEFAULT_BLOCK_ELEMS`` elements."""
    return max(1, min(MAX_ROW_BLOCK, DEFAULT_BLOCK_ELEMS >> n))


@functools.partial(jax.jit, static_argnames=("n", "row_block", "interpret"))
def _subset_prod_jit(mp, rhos, *, n: int, row_block: int, interpret: bool):
    b = rhos.shape[0]
    assert b % row_block == 0, (b, row_block)
    k = 1 << n
    kernel = functools.partial(_subsetdp_kernel, n=n)
    return pl.pallas_call(
        kernel,
        grid=(b // row_block,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),                 # miss penalty
            pl.BlockSpec((row_block, n), lambda i: (i, 0)),     # rho block
        ],
        out_specs=pl.BlockSpec((row_block, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), rhos.dtype),
        interpret=interpret,
    )(mp, rhos)


def subset_prod_pallas(rhos, miss_penalty, *, row_block: int = None,
                       interpret: bool = None):
    """rhos: [B, n] (B % row_block == 0 — ``ops.subset_dp`` pads);
    miss_penalty: scalar or [1].  Returns the [B, 2^n] subset exclusion
    products (M included) in ``rhos.dtype``; add the per-subset cost sums
    outside the jitted computation to obtain Eq. (10) values.

    ``interpret=None`` (the default) auto-selects from the JAX backend:
    compiled on TPU, interpret mode elsewhere.
    """
    rhos = jnp.asarray(rhos)
    n = rhos.shape[1]
    if interpret is None:
        interpret = default_interpret()
    if row_block is None:
        row_block = default_row_block(n)
    mp = jnp.asarray(miss_penalty, rhos.dtype).reshape(1)
    return _subset_prod_jit(mp, rhos, n=n, row_block=row_block,
                            interpret=bool(interpret))
