from repro.kernels.subsetdp.ops import subset_argmin, subset_dp
from repro.kernels.subsetdp.ref import subset_dp_ref, subset_parts_ref
from repro.kernels.subsetdp.subsetdp import (
    default_interpret,
    default_row_block,
    subset_prod_pallas,
)

__all__ = ["subset_argmin", "subset_dp", "subset_dp_ref", "subset_parts_ref",
           "subset_prod_pallas", "default_interpret", "default_row_block"]
