from repro.kernels.bloom.ops import bloom_probe, build_indicator
from repro.kernels.bloom.ref import bloom_probe_ref, build_indicator_ref

__all__ = ["bloom_probe", "build_indicator", "bloom_probe_ref", "build_indicator_ref"]
