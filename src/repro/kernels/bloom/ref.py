"""Pure-jnp oracle for the batched Bloom-probe kernel.

Hash family: 32-bit double hashing (two finalizer-mixed streams), chosen so
the SAME arithmetic runs on TPU vector units (the host-side CBF bookkeeping
in repro.core.indicator uses splitmix64; the device router builds its own
bitmaps with THIS family via build_indicator_ref, so the two layers are
each internally consistent).

Bitmaps are byte-packed: ``bits[n_caches, m_bytes]`` uint8, bit ``i`` of
the filter lives at byte ``i >> 3``, lane ``i & 7``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

U = jnp.uint32


def _mix32(x):
    """murmur3-style 32-bit finalizer (uint32 lanes)."""
    x = x.astype(U)
    x = x ^ (x >> U(16))
    x = x * U(0x7FEB352D)
    x = x ^ (x >> U(15))
    x = x * U(0x846CA68B)
    x = x ^ (x >> U(16))
    return x


def hash_idx(keys, k: int, m: int, seed: int = 0):
    """[B, k] uint32 bit indices via double hashing."""
    keys = keys.astype(U)
    h1 = _mix32(keys ^ U(seed * 0x9E3779B9 & 0xFFFFFFFF))
    h2 = _mix32(keys ^ U(0x85EBCA6B)) | U(1)
    i = jnp.arange(k, dtype=U)
    return (h1[:, None] + i[None, :] * h2[:, None]) % U(m)


def build_indicator_ref(keys, m: int, k: int, seed: int = 0):
    """Byte-packed bitmap [m_bytes] uint8 from a key set (m % 8 == 0)."""
    idx = hash_idx(keys, k, m, seed).reshape(-1)
    bits01 = jnp.zeros((m,), jnp.uint8).at[idx].set(1)
    lanes = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    return (bits01.reshape(m // 8, 8) * lanes[None, :]).sum(
        axis=1, dtype=jnp.uint32).astype(jnp.uint8)


def bloom_probe_ref(bits, keys, k: int, seeds=None):
    """bits: [n, m_bytes] uint8; keys: [B] -> indications [B, n] int8.

    ``seeds``: per-cache hash seeds (defaults to cache index).
    """
    n, mbytes = bits.shape
    m = mbytes * 8
    seeds = seeds if seeds is not None else list(range(n))
    outs = []
    for j in range(n):
        idx = hash_idx(keys, k, m, seeds[j])          # [B, k]
        byte = (idx >> U(3)).astype(jnp.int32)
        bit = (idx & U(7)).astype(jnp.uint8)
        vals = bits[j][byte]                          # [B, k] uint8
        hit = (vals >> bit) & jnp.uint8(1)
        outs.append(jnp.all(hit == 1, axis=1))
    return jnp.stack(outs, axis=1).astype(jnp.int8)
