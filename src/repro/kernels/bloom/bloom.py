"""Pallas TPU kernel: batched Bloom-filter probe.

HARDWARE ADAPTATION (see DESIGN.md): a Bloom probe is a random gather —
hostile to TPU vector memory.  Instead of gathering, each probe extracts
its byte with a blocked iota-compare + select-reduce over the byte-packed
bitmap held in VMEM (regular, fully vectorised VPU work; no scatter/gather
unit needed).  Cost is O(k * m_bytes) compares per key block — the right
trade below ~1M filter bits, where the whole row fits in VMEM and compares
are cheaper than an HBM-latency-bound gather chain.

Grid: (key_blocks, n_caches).  Block shapes:
  keys   [KB]           (KB = 256 keys)
  bits   [1, m_bytes]   (whole filter row resident in VMEM)
  out    [KB, 1]        (int8 indications)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bloom.ref import U, _mix32

DEFAULT_KEY_BLOCK = 256
BYTE_BLOCK = 2048


def _probe_kernel(seeds_ref, keys_ref, bits_ref, out_ref, *, k: int, m: int):
    j = pl.program_id(1)
    seed = seeds_ref[j]
    keys = keys_ref[...].astype(U)
    kb = keys.shape[0]
    mbytes = bits_ref.shape[1]

    h1 = _mix32(keys ^ (seed.astype(U) * U(0x9E3779B9)))
    h2 = _mix32(keys ^ U(0x85EBCA6B)) | U(1)

    acc = jnp.ones((kb,), jnp.int32)
    for probe in range(k):  # k is small and static: unrolled
        idx = (h1 + U(probe) * h2) % U(m)
        byte_idx = (idx >> U(3)).astype(jnp.int32)   # [KB]
        bit = (idx & U(7)).astype(jnp.int32)

        def body(wb, val):
            start = wb * BYTE_BLOCK
            # row index as a size-1 dslice: a bare scalar trips the
            # interpret-mode discharge rule on current JAX
            block = pl.load(bits_ref, (pl.dslice(0, 1), pl.dslice(start, BYTE_BLOCK)))[0]
            block = block.astype(jnp.int32)          # [BB]
            lanes = start + jax.lax.broadcasted_iota(jnp.int32, (1, BYTE_BLOCK), 1)
            sel = jnp.where(byte_idx[:, None] == lanes, block[None, :], 0)
            return val + jnp.sum(sel, axis=1)        # [KB]

        nblocks = mbytes // BYTE_BLOCK
        byte_val = jax.lax.fori_loop(0, nblocks, body, jnp.zeros((kb,), jnp.int32))
        hit = (byte_val >> bit) & 1
        acc = acc * hit
    out_ref[...] = acc.astype(jnp.int8)[:, None]


def default_interpret() -> bool:
    """Compiled only on TPU; interpret mode everywhere else — including
    GPU, deliberately: the kernel's blocked iota-compare/select-reduce
    design targets TPU VMEM (see module docstring) and is not expected to
    lower well elsewhere.  Pass ``interpret=False`` to override."""
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("k", "key_block", "interpret"))
def _bloom_probe_jit(bits, keys, seeds, *, k: int, key_block: int,
                     interpret: bool):
    n, mbytes = bits.shape
    b = keys.shape[0]
    assert b % key_block == 0, (b, key_block)
    assert mbytes % BYTE_BLOCK == 0, mbytes
    m = mbytes * 8
    grid = (b // key_block, n)
    kernel = functools.partial(_probe_kernel, k=k, m=m)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), lambda i, j: (0,)),               # seeds (small)
            pl.BlockSpec((key_block,), lambda i, j: (i,)),       # keys block
            pl.BlockSpec((1, mbytes), lambda i, j: (j, 0)),      # one filter row
        ],
        out_specs=pl.BlockSpec((key_block, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.int8),
        interpret=interpret,
    )(seeds, keys, bits)


def bloom_probe_pallas(bits, keys, seeds, *, k: int,
                       key_block: int = DEFAULT_KEY_BLOCK,
                       interpret: bool = None):
    """bits: [n, m_bytes] uint8 (m_bytes % 2048 == 0); keys: [B] int32/uint32;
    seeds: [n] int32.  Returns [B, n] int8 indications.

    ``interpret=None`` (the default) auto-selects from the JAX backend:
    compiled on TPU, interpret mode elsewhere.
    """
    if interpret is None:
        interpret = default_interpret()
    return _bloom_probe_jit(bits, keys, seeds, k=k, key_block=key_block,
                            interpret=bool(interpret))
