"""Jitted public wrappers for the Bloom-probe kernel.

``bloom_probe`` pads inputs to kernel-friendly shapes; interpret mode is
auto-selected from the JAX backend (compiled on TPU, interpret elsewhere)
unless overridden via ``interpret=``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bloom.bloom import BYTE_BLOCK, DEFAULT_KEY_BLOCK, bloom_probe_pallas
from repro.kernels.bloom.ref import bloom_probe_ref, build_indicator_ref


def build_indicator(keys, m: int, k: int, seed: int = 0):
    """Device-side byte-packed indicator for a key set (router replicas)."""
    keys = jnp.asarray(keys)
    return build_indicator_ref(keys, m, k, seed)


def bloom_probe(bits, keys, *, k: int, seeds=None, use_pallas: bool = True,
                interpret: bool = None):
    """Batched probe of n stale indicator replicas.

    bits: [n, m_bytes] uint8; keys: [B] integer.  Returns [B, n] int8.
    Pads B to the kernel key block and m_bytes to the byte block.
    ``interpret=None`` auto-selects from the JAX backend (compiled on TPU,
    interpret mode elsewhere).
    """
    bits = jnp.asarray(bits, jnp.uint8)
    keys = jnp.asarray(keys)
    n, mbytes = bits.shape
    seeds_arr = jnp.asarray(seeds if seeds is not None else np.arange(n),
                            jnp.int32)
    if not use_pallas:
        return bloom_probe_ref(bits, keys, k, seeds=list(np.asarray(seeds_arr)))
    b = keys.shape[0]
    kb = DEFAULT_KEY_BLOCK
    pad_b = (-b) % kb
    pad_m = (-mbytes) % BYTE_BLOCK
    if pad_b:
        keys = jnp.pad(keys, (0, pad_b))
    if pad_m:
        bits = jnp.pad(bits, ((0, 0), (0, pad_m)))
        # NOTE: padding bytes are zero -> probes landing there read 0 bits,
        # but indices are mod the ORIGINAL m, so they never land there.
        # We keep m = original bits count by passing k/m via the unpadded
        # mbytes; see bloom_probe_pallas which derives m from the padded
        # array — so instead pad m virtually by rebuilding: safest is to
        # require callers to size m_bytes as a multiple of BYTE_BLOCK.
        raise ValueError(
            f"m_bytes={mbytes} must be a multiple of {BYTE_BLOCK} "
            f"(size filters as m = bpe*C rounded to {BYTE_BLOCK * 8} bits)")
    out = bloom_probe_pallas(bits, keys, seeds_arr, k=k, interpret=interpret)
    return out[:b]
