"""Jitted wrapper: layout shim [B,S,H,D] <-> [B,H,S,D] + backend select."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash import flash_attention_pallas


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    """q: [B,Sq,Hq,D]; k/v: [B,Sk,Hkv,D] — model layout — returns same."""
    interpret = jax.default_backend() == "cpu"
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention_pallas(qt, kt, vt, causal=causal, block_q=block_q,
                                 block_k=block_k, interpret=interpret)
    return jnp.swapaxes(out, 1, 2)
