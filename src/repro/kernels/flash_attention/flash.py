"""Pallas TPU kernel: causal flash-attention forward with GQA.

Grid (B, Hq, nQ, nK) — the KV dim is innermost, so each (b, h, iq) row
iterates its KV blocks SEQUENTIALLY (TPU grids are sequential), carrying
the online-softmax statistics in VMEM scratch:

  m   [Bq, 1]  running max
  l   [Bq, 1]  running denominator
  acc [Bq, D]  running numerator (f32)

Causal skipping: KV blocks strictly above the diagonal are predicated off
with ``pl.when`` — the memory traffic for those blocks is still issued by
the pipeline but no FLOPs are burned (a production variant would shrink
the grid per-row; noted as a hillclimb lever in EXPERIMENTS.md §Perf).

BlockSpecs put q/k/v tiles in VMEM with the MXU-aligned last dim D
(64/128) and the GQA mapping folds the query-head index to its KV head
(h // group) in the index_map — no repeated-KV materialisation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q: int, block_k: int, causal: bool, scale: float):
    iq, ik = pl.program_id(2), pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if causal:
        run = ik * block_k <= iq * block_q + block_q - 1
    else:
        run = ik >= 0  # always true (traced)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # [Bq, D]
        k = k_ref[0, 0].astype(jnp.float32)                  # [Bk, D]
        v = v_ref[0, 0].astype(jnp.float32)                  # [Bk, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [Bq, Bk]
        if causal:
            qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]                                   # [Bq, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                                # [Bq, Bk]
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, block_q: int = 128,
                           block_k: int = 128, interpret: bool = True):
    """q: [B,Hq,Sq,D]; k/v: [B,Hkv,Sk,D] (head-major) -> [B,Hq,Sq,D]."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    group = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    grid = (b, hq, sq // block_q, sk // block_k)
    scale = d ** -0.5
    kernel = functools.partial(_flash_kernel, block_q=block_q, block_k=block_k,
                               causal=causal, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, i, j: (b_, h // group, j, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, i, j: (b_, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            # (m, l, acc) carried across the KV grid dim
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
