"""Pure-jnp oracle for the flash-attention kernel (GQA, causal)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.layers import attention_naive


def attention_ref(q, k, v, *, causal: bool = True):
    """q: [B,Sq,Hq,D]; k/v: [B,Sk,Hkv,D] -> [B,Sq,Hq,D]."""
    return attention_naive(q, k, v, causal=causal)
