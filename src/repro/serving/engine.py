"""Model serving engine: prefill + decode with KV caches and sampling."""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import get_model

PyTree = Any


class ServeEngine:
    """Batched greedy/temperature decoding around a model's prefill +
    decode_step.  jit-compiled once per (batch, prompt_len, max_len)."""

    def __init__(self, cfg: ModelConfig, params: Optional[PyTree] = None,
                 rng: Optional[jax.Array] = None):
        self.cfg = cfg
        self.model = get_model(cfg)
        self.params = params if params is not None else self.model.init(
            rng if rng is not None else jax.random.PRNGKey(0))
        self._prefill = jax.jit(self.model.prefill, static_argnames=("max_len",)) \
            if cfg.family in ("dense", "moe", "vlm", "hybrid", "encdec") \
            else jax.jit(self.model.prefill)
        self._step = jax.jit(self.model.decode_step)

    def prefill(self, tokens: np.ndarray, max_len: int) -> Tuple[jax.Array, PyTree]:
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
        if self.cfg.family in ("dense", "moe", "vlm", "hybrid", "encdec"):
            if self.cfg.family == "vlm":
                batch["patch_embeds"] = jnp.zeros(
                    (tokens.shape[0], self.cfg.n_patches, self.cfg.d_model),
                    {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.cfg.dtype])
            if self.cfg.family == "encdec":
                batch["frames"] = jnp.zeros(
                    (tokens.shape[0], tokens.shape[1], self.cfg.d_model),
                    {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.cfg.dtype])
            return self._prefill(self.params, batch, max_len=max_len)
        return self._prefill(self.params, batch)

    def decode(self, cache: PyTree, first_tokens: jax.Array, n_steps: int,
               temperature: float = 0.0, rng: Optional[jax.Array] = None
               ) -> Tuple[np.ndarray, PyTree]:
        """Decode n_steps tokens.  first_tokens: [B] seeds the loop."""
        toks = first_tokens
        out = []
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        for i in range(n_steps):
            logits, cache = self._step(self.params, cache, toks)
            if temperature > 0:
                rng, k = jax.random.split(rng)
                toks = jax.random.categorical(k, logits / temperature, axis=-1)
            else:
                toks = jnp.argmax(logits, axis=-1)
            toks = jnp.clip(toks, 0, self.cfg.vocab - 1).astype(jnp.int32)
            out.append(np.asarray(toks))
        return np.stack(out, axis=1), cache
