"""Indicator-advertised distributed prefix-KV cache with FNA routing.

This is the paper's technique deployed as a first-class serving feature.

Topology: K cache nodes each hold prefill KV caches for prompt *prefixes*
(system prompts, few-shot headers, RAG contexts).  Nodes advertise their
content to the front-end router as Bloom-filter bitmaps — but only every
``update_interval`` insertions, because a fleet-wide indicator push per
insertion would burn the control-plane bandwidth (the paper's premise:
a large CDN's indicators are ~70MB; ours are bpe x capacity bits per node).

Between advertisements the router's replicas go STALE: freshly-prefilled
prefixes look absent (false negatives) and evicted ones look present
(false positives).  The router therefore runs CS_FNA (Algorithm 2):

  * nodes send (FP, FN) estimates from Eqs. (7)-(8) piggybacked on probes,
  * the router keeps per-node EWMA q estimates (Eq. 9),
  * every lookup solves the CS problem over probe costs c_j and the
    prefill-recompute penalty M, possibly probing nodes with NEGATIVE
    indications — which is exactly what recovers the hits that a
    false-negative-oblivious router forfeits.

Costs are in abstract service-cost units (probe RTT ~ 1, prefill of a
P-token prefix ~ M(P)); the e2e example (examples/serve_prefix_cache.py)
also runs REAL prefill/decode compute for the misses so the cost units
translate into wall-clock on this host.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.cachesim.lru import LRUCache
from repro.core import (
    CacheView,
    QEstimator,
    cs_fna,
    cs_fno,
    ds_pgm,
    optimal_k,
    perfect_information,
)
from repro.core.indicator import StaleIndicatorPair, hash_indices


def _per_node(value, n: int, cast, name: str) -> tuple:
    """Normalise a scalar-or-sequence node knob to an n-tuple (mirrors
    ``SimConfig._per_cache``): a scalar broadcasts, a sequence must match
    ``n_nodes`` — heterogeneous fleets (tiered capacities, staggered or
    delayed advertisement cadences) set per-node sequences."""
    if isinstance(value, (list, tuple, np.ndarray)):
        vals = tuple(cast(v) for v in value)
        if len(vals) != n:
            raise ValueError(
                f"{name} has {len(vals)} entries for {n} nodes")
        return vals
    return (cast(value),) * n


@dataclass
class ClusterConfig:
    n_nodes: int = 4
    # prefixes per node: scalar, or one value per node (tiered fleets)
    node_capacity: Union[int, Sequence[int]] = 512
    probe_costs: Sequence[float] = ()  # default 1 + j
    miss_penalty: float = 100.0        # prefill recompute in probe-cost units
    bpe: float = 14.0
    # insertions between advertisements: scalar, or per node (staggered /
    # delayed-view regimes)
    update_interval: Union[int, Sequence[int]] = 64
    est_interval: Union[int, Sequence[int]] = 8
    q_horizon: int = 50
    q_delta: float = 0.25
    policy: str = "fna"                # fna | fna_cal | fno | pi
    # fna_cal: empirical exclusion-probability feedback (beyond-paper)
    cal_gamma: float = 0.05
    cal_min_obs: int = 20
    cal_epsilon: float = 0.01

    def __post_init__(self):
        if not self.probe_costs:
            self.probe_costs = tuple(1.0 + j * 0.5 for j in range(self.n_nodes))
        if len(self.probe_costs) != self.n_nodes:
            raise ValueError(
                f"probe_costs has {len(self.probe_costs)} entries for "
                f"{self.n_nodes} nodes")

    @property
    def node_capacities(self) -> tuple:
        return _per_node(self.node_capacity, self.n_nodes, int,
                         "node_capacity")

    @property
    def update_intervals(self) -> tuple:
        return _per_node(self.update_interval, self.n_nodes, int,
                         "update_interval")

    @property
    def est_intervals(self) -> tuple:
        return _per_node(self.est_interval, self.n_nodes, int,
                         "est_interval")


class PrefixCacheNode:
    """One cache node: LRU of prefix -> KV handle + advertised indicator."""

    def __init__(self, capacity: int, bpe: float, seed: int,
                 update_interval: int, est_interval: int):
        self.lru = LRUCache(capacity)
        self.store: Dict[int, object] = {}
        m = max(64, int(bpe * capacity))
        self.ind = StaleIndicatorPair(m, optimal_k(bpe), seed=seed)
        self.update_interval = update_interval
        self.est_interval = est_interval
        self._since_adv = 0
        self._since_est = 0
        self.ind.advertise()

    def lookup(self, prefix_hash: int) -> Optional[object]:
        """The actual probe: returns the KV handle or None."""
        if self.lru.touch(prefix_hash):
            return self.store.get(prefix_hash)
        return None

    def insert(self, prefix_hash: int, kv_handle: object) -> None:
        inserted, evicted = self.lru.put(prefix_hash)
        self.store[prefix_hash] = kv_handle
        if not inserted:
            return
        self.ind.cbf.add(prefix_hash)
        if evicted is not None:
            self.store.pop(evicted, None)
            self.ind.cbf.remove(evicted)
        self._since_adv += 1
        self._since_est += 1
        if self._since_est >= self.est_interval:
            self.ind.estimate_rates()
            self._since_est = 0
        if self._since_adv >= self.update_interval:
            self.ind.advertise()
            self.ind.estimate_rates()
            self._since_adv = 0
            self._since_est = 0


@dataclass
class RouteStats:
    requests: int = 0
    probes: int = 0
    probe_cost: float = 0.0
    kv_hits: int = 0
    prefills: int = 0
    neg_probes: int = 0
    total_cost: float = 0.0

    @property
    def mean_cost(self) -> float:
        return self.total_cost / max(self.requests, 1)

    @property
    def hit_ratio(self) -> float:
        return self.kv_hits / max(self.requests, 1)

    def to_dict(self) -> Dict:
        return {"requests": self.requests, "mean_cost": round(self.mean_cost, 3),
                "hit_ratio": round(self.hit_ratio, 4), "probes": self.probes,
                "neg_probes": self.neg_probes, "prefills": self.prefills}


class FNARouter:
    """Front-end: stale indicator replicas + Algorithm 2 cache selection."""

    def __init__(self, cfg: ClusterConfig, nodes: List[PrefixCacheNode]):
        self.cfg = cfg
        self.nodes = nodes
        self.q_est = [QEstimator(cfg.q_horizon, cfg.q_delta)
                      for _ in range(cfg.n_nodes)]
        self.stats = RouteStats()
        # optimistic init: bootstraps exploration when FP+FN ~ 1 leaves h
        # unidentifiable from (q, FP, FN) — see simulator.py for the rationale
        self._nu_emp = [0.90] * cfg.n_nodes
        self._pi_emp = [0.5] * cfg.n_nodes
        self._nu_obs = [0] * cfg.n_nodes
        self._pi_obs = [0] * cfg.n_nodes
        self._rng = np.random.default_rng(1234)

    def _indications(self, prefix_hash: int) -> List[bool]:
        out = []
        for nd in self.nodes:
            idx = hash_indices(np.asarray([prefix_hash], np.uint64),
                               nd.ind.cbf.k, nd.ind.cbf.m, nd.ind.cbf.seed)[0]
            out.append(bool(nd.ind.stale[idx].all()))
        return out

    def select(self, prefix_hash: int) -> Tuple[List[int], List[bool]]:
        cfg = self.cfg
        indications = self._indications(prefix_hash)
        for qe, ind in zip(self.q_est, indications):
            qe.observe(ind)
        if cfg.policy == "pi":
            contains = [prefix_hash in nd.lru for nd in self.nodes]
            return perfect_information(list(cfg.probe_costs), contains), indications
        views = [CacheView(cost=cfg.probe_costs[j], fp=self.nodes[j].ind.fp_est,
                           fn=self.nodes[j].ind.fn_est, q=self.q_est[j].value)
                 for j in range(cfg.n_nodes)]
        if cfg.policy == "fna_cal":
            from repro.core.policies import rho_vector
            model_rho = rho_vector(views, indications)
            rhos = []
            for j in range(cfg.n_nodes):
                uninformative = (self.nodes[j].ind.fp_est +
                                 self.nodes[j].ind.fn_est) >= 0.95
                if indications[j]:
                    use = self._pi_obs[j] >= cfg.cal_min_obs or uninformative
                    rhos.append(self._pi_emp[j] if use else model_rho[j])
                else:
                    use = self._nu_obs[j] >= cfg.cal_min_obs or uninformative
                    rhos.append(self._nu_emp[j] if use else model_rho[j])
            sel = ds_pgm([v.cost for v in views], rhos, cfg.miss_penalty)
            if self._rng.random() < cfg.cal_epsilon:
                jx = int(self._rng.integers(0, cfg.n_nodes))
                if jx not in sel:
                    sel = sorted(sel + [jx])
            return sel, indications
        pol = cs_fna if cfg.policy == "fna" else cs_fno
        return pol(views, indications, cfg.miss_penalty, alg=ds_pgm), indications

    def route(self, prefix_hash: int):
        """Returns (kv_handle or None, realized_cost, selection)."""
        sel, indications = self.select(prefix_hash)
        cost = sum(self.cfg.probe_costs[j] for j in sel)
        self.stats.probes += len(sel)
        self.stats.neg_probes += sum(1 for j in sel if not indications[j])
        self.stats.probe_cost += cost
        kv = None
        g = self.cfg.cal_gamma
        for j in sel:
            found = self.nodes[j].lookup(prefix_hash)
            if self.cfg.policy == "fna_cal":  # probe-outcome feedback
                absent = found is None
                if indications[j]:
                    self._pi_emp[j] = (1 - g) * self._pi_emp[j] + g * absent
                    self._pi_obs[j] += 1
                else:
                    self._nu_emp[j] = (1 - g) * self._nu_emp[j] + g * absent
                    self._nu_obs[j] += 1
            if found is not None and kv is None:
                kv = found
        if kv is None:
            cost += self.cfg.miss_penalty
            self.stats.prefills += 1
        else:
            self.stats.kv_hits += 1
        self.stats.requests += 1
        self.stats.total_cost += cost
        return kv, cost, sel


class PrefixServeCluster:
    """Nodes + router + placement: the complete paper-technique data path."""

    def __init__(self, cfg: ClusterConfig, seed: int = 0):
        self.cfg = cfg
        caps = cfg.node_capacities
        advs, ests = cfg.update_intervals, cfg.est_intervals
        self.nodes = [
            PrefixCacheNode(caps[j], cfg.bpe, seed=seed * 100 + j,
                            update_interval=advs[j],
                            est_interval=ests[j])
            for j in range(cfg.n_nodes)
        ]
        self.router = FNARouter(cfg, self.nodes)

    def request(self, prefix_hash: int, make_kv=lambda: True):
        """Serve one request; on miss, prefill (make_kv) and place the
        result on the designated node."""
        kv, cost, sel = self.router.route(prefix_hash)
        if kv is None:
            kv = make_kv()
            dj = prefix_hash % self.cfg.n_nodes
            self.nodes[dj].insert(prefix_hash, kv)
        return kv, cost

    @property
    def stats(self) -> RouteStats:
        return self.router.stats
