"""Concurrent-client replay harness for the FNA serving router.

The simulator proved the *policy*; this proves the *implementation*: N
replay clients drive a live :class:`~repro.serving.prefix_cache.
PrefixServeCluster` and the harness records what an operator would page
on — sustained throughput and the p50/p99 DECISION latency (the wall
clock spent inside ``cluster.request``: indicator lookups, Algorithm 2
cache selection, probes, placement — the paper technique on the request
path, excluding any model prefill/decode compute).

Regimes
-------
``REGIMES`` mirrors the cachesim scenario registry's router-relevant
system shapes at serving-tier sizes, so the serving benches exercise the
same heterogeneity the golden simulator scenarios pin:

  * ``hetero_tiers``      — cheap-small through expensive-large nodes
    (scenario ``hetero_tiers``: costs (1, 2, 4), tiered capacities);
  * ``staggered_adverts`` — equal nodes whose advertisement cadences
    span 32..512 insertions (scenario ``staggered_adverts``), so the
    router faces per-node staleness levels;
  * ``delayed_view``      — one node advertises ~an order of magnitude
    less often than its peers (scenario ``delayed_view``): the FN-heavy
    regime where false-negative awareness pays.

Modes
-----
``mode="sequential"`` interleaves the clients' streams round-robin in
``batch_size`` slices on one thread — fully DETERMINISTIC for a fixed
seed (costs, hits, probe counts), the mode tests pin.  ``mode="threads"``
runs one thread per client with a router lock (the router is one
stateful event loop, as in a real front-end); arrival interleaving is
then scheduler-dependent, so only aggregate stats and latency
percentiles are meaningful.  ``rate`` optionally paces each client to a
target AGGREGATE arrival rate (reqs/s) open-loop; the achieved rate is
reported alongside.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Union

import numpy as np

from repro.serving.prefix_cache import ClusterConfig, PrefixServeCluster

#: scenario-defined router regimes (see module docstring)
REGIMES: Dict[str, ClusterConfig] = {
    "hetero_tiers": ClusterConfig(
        n_nodes=3, probe_costs=(1.0, 2.0, 4.0),
        node_capacity=(64, 192, 512), update_interval=256,
        miss_penalty=100.0),
    "staggered_adverts": ClusterConfig(
        n_nodes=3, probe_costs=(1.0, 1.5, 2.0),
        node_capacity=192, update_interval=(32, 128, 512),
        miss_penalty=100.0),
    "delayed_view": ClusterConfig(
        n_nodes=3, probe_costs=(1.0, 1.5, 2.0),
        node_capacity=192, update_interval=(48, 48, 640),
        miss_penalty=100.0),
}


def regime_config(name: str, policy: str = "fna") -> ClusterConfig:
    """A fresh ClusterConfig for one named regime + router policy."""
    if name not in REGIMES:
        raise KeyError(f"unknown replay regime {name!r}; "
                       f"known: {sorted(REGIMES)}")
    return dataclasses.replace(REGIMES[name], policy=policy)


@dataclass
class ReplayReport:
    """One replay run's operator-facing summary."""
    regime: str
    policy: str
    n_clients: int
    batch_size: int
    requests: int
    wall_s: float
    achieved_rps: float        # requests / wall (measured, not target)
    target_rps: Optional[float]
    p50_us: float              # decision latency percentiles over all
    p99_us: float              # requests (time inside cluster.request)
    mean_cost: float
    hit_ratio: float
    stats: dict                # RouteStats.to_dict()

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["wall_s"] = round(self.wall_s, 4)
        d["achieved_rps"] = round(self.achieved_rps, 1)
        d["p50_us"] = round(self.p50_us, 2)
        d["p99_us"] = round(self.p99_us, 2)
        d["mean_cost"] = round(self.mean_cost, 4)
        d["hit_ratio"] = round(self.hit_ratio, 4)
        return d


def client_streams(n_requests: int, n_clients: int, seed: int = 0,
                   p_new: float = 0.15, window: int = 96) -> List[np.ndarray]:
    """One recency-biased prefix stream per client (deterministic per
    seed); clients share a key space, so popular prefixes collide across
    clients exactly like shared system prompts do."""
    from repro.cachesim.traces import recency_trace
    if n_clients < 1:
        raise ValueError(f"n_clients must be >= 1, got {n_clients}")
    per = n_requests // n_clients
    return [recency_trace(per, p_new=p_new, window=window,
                          seed=seed * 1000 + c + 1)
            for c in range(n_clients)]


def _percentiles(lat_s: Sequence[float]):
    arr = np.asarray(lat_s, dtype=np.float64) * 1e6
    if arr.shape[0] == 0:
        return 0.0, 0.0
    return (float(np.percentile(arr, 50)), float(np.percentile(arr, 99)))


def replay(regime: Union[str, ClusterConfig], policy: str = "fna",
           n_requests: int = 4_000, n_clients: int = 4, batch_size: int = 1,
           mode: str = "threads", rate: Optional[float] = None,
           seed: int = 0,
           make_kv: Callable[[], object] = lambda: True) -> ReplayReport:
    """Replay ``n_requests`` across ``n_clients`` concurrent clients
    against one cluster; returns the :class:`ReplayReport`.

    ``regime`` is a ``REGIMES`` name or an explicit ``ClusterConfig``
    (whose policy is then overridden by ``policy``).  ``batch_size`` is
    the number of requests a client issues back-to-back per turn while
    holding the router.  ``make_kv`` builds the KV payload on a miss —
    the default stub keeps the harness model-free, so the latency rows
    isolate the ROUTING path."""
    if isinstance(regime, str):
        cfg = regime_config(regime, policy)
        regime_name = regime
    else:
        cfg = dataclasses.replace(regime, policy=policy)
        regime_name = "custom"
    if batch_size < 1:
        raise ValueError(f"batch_size must be >= 1, got {batch_size}")
    if mode not in ("sequential", "threads"):
        raise ValueError(f"unknown replay mode {mode!r}")
    cluster = PrefixServeCluster(cfg, seed=seed)
    streams = client_streams(n_requests, n_clients, seed=seed)
    lat: List[float] = []
    perf = time.perf_counter

    t0 = perf()
    if mode == "sequential":
        cursors = [0] * n_clients
        live = True
        while live:
            live = False
            for c, stream in enumerate(streams):
                i = cursors[c]
                stop = min(i + batch_size, stream.shape[0])
                for k in range(i, stop):
                    t1 = perf()
                    cluster.request(int(stream[k]), make_kv=make_kv)
                    lat.append(perf() - t1)
                cursors[c] = stop
                live = live or stop < stream.shape[0]
    else:
        lock = threading.Lock()
        lat_lock = threading.Lock()
        # open-loop pacing: each client owns every n_clients-th slot of
        # the aggregate arrival schedule
        interval = (n_clients / rate) if rate else None

        def run_client(c: int, stream: np.ndarray) -> None:
            local: List[float] = []
            n = stream.shape[0]
            i = 0
            while i < n:
                if interval is not None:
                    due = t0 + (i // batch_size) * batch_size * interval \
                        + c * interval / n_clients
                    delay = due - perf()
                    if delay > 0:
                        time.sleep(delay)
                stop = min(i + batch_size, n)
                with lock:
                    # latency measured INSIDE the router lock: the
                    # decision path itself, not queueing delay
                    for k in range(i, stop):
                        t1 = perf()
                        cluster.request(int(stream[k]), make_kv=make_kv)
                        local.append(perf() - t1)
                i = stop
            with lat_lock:
                lat.extend(local)

        threads = [threading.Thread(target=run_client, args=(c, s))
                   for c, s in enumerate(streams)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    wall = perf() - t0

    s = cluster.stats
    p50, p99 = _percentiles(lat)
    return ReplayReport(
        regime=regime_name, policy=cfg.policy, n_clients=n_clients,
        batch_size=batch_size, requests=s.requests, wall_s=wall,
        achieved_rps=s.requests / wall if wall > 0 else 0.0,
        target_rps=rate, p50_us=p50, p99_us=p99,
        mean_cost=s.mean_cost, hit_ratio=s.hit_ratio,
        stats=s.to_dict())


def batch_sweep(regime: str, policy: str = "fna",
                batch_sizes: Sequence[int] = (1, 4, 16),
                n_requests: int = 4_000, n_clients: int = 4,
                mode: str = "threads", seed: int = 0) -> List[ReplayReport]:
    """One replay per batch size (fresh cluster each), same total load —
    how much router-turn amortisation buys under contention."""
    return [replay(regime, policy=policy, n_requests=n_requests,
                   n_clients=n_clients, batch_size=b, mode=mode, seed=seed)
            for b in batch_sizes]
