from repro.serving.engine import ServeEngine
from repro.serving.prefix_cache import (
    ClusterConfig,
    FNARouter,
    PrefixCacheNode,
    PrefixServeCluster,
)

__all__ = ["ServeEngine", "PrefixCacheNode", "FNARouter", "PrefixServeCluster",
           "ClusterConfig"]
