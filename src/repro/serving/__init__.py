from repro.serving.engine import ServeEngine
from repro.serving.prefix_cache import (
    ClusterConfig,
    FNARouter,
    PrefixCacheNode,
    PrefixServeCluster,
)
from repro.serving.replay import (
    REGIMES,
    ReplayReport,
    batch_sweep,
    regime_config,
    replay,
)

__all__ = ["ServeEngine", "PrefixCacheNode", "FNARouter", "PrefixServeCluster",
           "ClusterConfig", "REGIMES", "ReplayReport", "batch_sweep",
           "regime_config", "replay"]
