"""Inspect and maintain a content-addressed artifact store
(``repro.cachesim.store``).

The store is append-only from the engine's point of view — entries are
immutable, keyed by content, and never updated in place — so the only
maintenance it ever needs is external: look at what accumulated, bound
its size, and check archive integrity after an unclean copy.

Usage::

    PYTHONPATH=src python tools/store_tool.py ls [--store DIR]
    PYTHONPATH=src python tools/store_tool.py gc --max-bytes N [--store DIR]
    PYTHONPATH=src python tools/store_tool.py verify [--store DIR]

``--store`` defaults to the ``REPRO_STORE`` environment variable.

  * ``ls``     — every entry as ``kind  size  mtime  path``, oldest
    first, plus a per-kind and total summary.
  * ``gc``     — delete oldest entries (by mtime) until the store fits
    in ``--max-bytes`` (suffixes K/M/G accepted).  mtime order makes gc
    an LRU-ish eviction under CI's restore/save cycle.
  * ``verify`` — open every archive and load its arrays; corrupt
    entries are reported (and the engine would rebuild them on next
    touch anyway).  Exit code 1 if any entry fails.
"""
from __future__ import annotations

import argparse
import datetime
import sys


def _parse_bytes(s: str) -> int:
    """Parse a size like ``500M`` / ``1.5 GB`` / ``4096`` into bytes.

    Accepts an optional K/M/G multiplier with an optional trailing ``B``
    (any case); rejects negatives and anything unparseable with a clear
    ``argparse``-friendly error instead of a bare ``float()`` traceback.
    """
    raw = s
    s = s.strip().upper()
    if s.endswith("B"):
        s = s[:-1]
    mult = 1
    for suffix, m in (("K", 1 << 10), ("M", 1 << 20), ("G", 1 << 30)):
        if s.endswith(suffix):
            s, mult = s[:-1], m
            break
    try:
        val = float(s.strip())
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"invalid size {raw!r}: expected <number>[K|M|G][B], "
            f"e.g. 500M, 1.5GB, 4096")
    if val < 0:
        raise argparse.ArgumentTypeError(
            f"invalid size {raw!r}: must be non-negative")
    return int(val * mult)


def _fmt_size(n: int) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if n < 1024 or unit == "GiB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{n}B"
        n /= 1024
    return f"{n}B"


def cmd_ls(store) -> int:
    entries = store.entries()
    totals: dict = {}
    for path, kind, size, mtime in entries:
        ts = datetime.datetime.fromtimestamp(mtime).strftime("%Y-%m-%d %H:%M")
        print(f"{kind:7s} {_fmt_size(size):>10s}  {ts}  {path}")
        n, b = totals.get(kind, (0, 0))
        totals[kind] = (n + 1, b + size)
    total_n = sum(n for n, _ in totals.values())
    total_b = sum(b for _, b in totals.values())
    for kind in sorted(totals):
        n, b = totals[kind]
        print(f"# {kind}: {n} entries, {_fmt_size(b)}")
    print(f"# total: {total_n} entries, {_fmt_size(total_b)}")
    return 0


def cmd_gc(store, max_bytes: int) -> int:
    deleted = store.gc(max_bytes)
    for p in deleted:
        print(f"deleted {p}")
    kept = sum(size for _, _, size, _ in store.entries())
    print(f"# deleted {len(deleted)} entries; {_fmt_size(kept)} kept "
          f"(limit {_fmt_size(max_bytes)})")
    return 0


def cmd_verify(store) -> int:
    bad = 0
    n = 0
    for path, ok in store.verify():
        n += 1
        if not ok:
            bad += 1
            print(f"CORRUPT {path}")
    print(f"# verified {n} entries, {bad} corrupt")
    return 1 if bad else 0


def main(argv=None) -> int:
    from repro.cachesim.store import ArtifactStore, default_root

    ap = argparse.ArgumentParser(
        prog="tools/store_tool.py",
        description="Inspect / bound / verify a repro artifact store")
    ap.add_argument("--store", default=None, metavar="DIR",
                    help="store root (default: $REPRO_STORE)")
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("ls", help="list entries oldest-first + summary")
    gc = sub.add_parser("gc", help="delete oldest entries over the limit")
    gc.add_argument("--max-bytes", required=True, metavar="N",
                    type=_parse_bytes,
                    help="target size (suffixes K/M/G[B] accepted)")
    sub.add_parser("verify", help="check every archive loads")
    args = ap.parse_args(argv)

    root = args.store or default_root()
    if root is None:
        ap.error("no store: pass --store or set REPRO_STORE")
    store = ArtifactStore(root)
    if args.cmd == "ls":
        return cmd_ls(store)
    if args.cmd == "gc":
        return cmd_gc(store, args.max_bytes)
    return cmd_verify(store)


if __name__ == "__main__":
    sys.exit(main())
