"""Write simulator request traces out as wiki/CDN-shaped log files.

The converter closes the loop of the trace-ingestion subsystem
(``repro.cachesim.tracefiles``): any synthetic generator's output — or
any existing log readable by the loader — can be serialised into the two
supported on-disk shapes, so loader round-trips are testable and
license-clean sample logs can be committed.

Formats (mirroring the loader):

  * ``keys`` — one key token per line (wiki-access-log shape);
  * ``csv``  — ``ts,key,bytes`` rows with a header (CDN-log shape); keys
    are written as ``obj<id>`` string tokens so the loader's dense
    remapping of non-integer keys is exercised, ``bytes`` is a
    deterministic function of the key (no extra RNG).

``--gzip`` compresses with a zeroed mtime header, so regenerating a
sample yields byte-identical files (diffable in review / CI).

Usage::

    # a generator, serialised
    python tools/make_trace_file.py --generator gradle --n 60000 --seed 7 \\
        --format keys --gzip -o /tmp/gradle.log.gz

    # convert an existing log between shapes
    python tools/make_trace_file.py --input access.log --format csv -o out.csv

    # regenerate the committed sample logs (tests/data/)
    python tools/make_trace_file.py --samples
"""
from __future__ import annotations

import argparse
import gzip
import io
import sys
from pathlib import Path

import numpy as np

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

from repro.cachesim import tracefiles  # noqa: E402
from repro.cachesim.traces import TRACES, get_trace  # noqa: E402

SAMPLES_DIR = REPO / "tests" / "data"

#: the committed redistributable sample logs (generated from the synthetic
#: generators, so they are license-clean): one recency-biased stream in the
#: line-per-key shape, one Zipf-like stream in the CSV shape — the two log
#: shapes the paper family's wiki/CDN workloads arrive in.
SAMPLES = (
    dict(out="sample_recency.log.gz", generator="gradle", fmt="keys",
         n=60_000, seed=7, kwargs={}),
    dict(out="sample_zipf.csv.gz", generator="wiki", fmt="csv",
         n=60_000, seed=11, kwargs={"catalog": 50_000}),
)


def _fake_bytes(key: int) -> int:
    """Deterministic CDN-ish object size column (Knuth hash, 1K..900K)."""
    return (int(key) * 2654435761) % 900_000 + 1_000


#: rows serialised per write (the text never materialises whole: an
#: ``--n``-scaled multi-GB log streams through O(chunk) memory)
WRITE_CHUNK = 1 << 16


def _iter_text(ids: np.ndarray, fmt: str):
    """Yield the log text in row chunks (identical bytes to a one-shot
    serialisation of the same array)."""
    if fmt == "keys":
        yield "# one request key per line\n"
        for lo in range(0, len(ids), WRITE_CHUNK):
            block = ids[lo:lo + WRITE_CHUNK].tolist()
            yield "".join(f"{int(x)}\n" for x in block)
    elif fmt == "csv":
        yield "ts,key,bytes\n"
        for lo in range(0, len(ids), WRITE_CHUNK):
            block = ids[lo:lo + WRITE_CHUNK].tolist()
            yield "".join(f"{lo + i},obj{int(x)},{_fake_bytes(int(x))}\n"
                          for i, x in enumerate(block))
    else:
        raise ValueError(f"unknown format {fmt!r}; known: 'keys', 'csv'")


def write_trace_file(ids: np.ndarray, path: Path, fmt: str,
                     compress: bool = False) -> Path:
    """Serialise a request array into one of the loader's formats,
    chunk-written (peak memory stays O(chunk), not O(file))."""
    path.parent.mkdir(parents=True, exist_ok=True)
    if compress:
        # mtime=0: byte-identical output per input (committable/diffable)
        with open(path, "wb") as f:
            with gzip.GzipFile(fileobj=f, mode="wb", mtime=0) as gz:
                for text in _iter_text(ids, fmt):
                    gz.write(text.encode("utf-8"))
    else:
        with open(path, "wb") as f:
            for text in _iter_text(ids, fmt):
                f.write(text.encode("utf-8"))
    return path


def write_samples(out_dir: Path = SAMPLES_DIR) -> list:
    paths = []
    for spec in SAMPLES:
        ids = get_trace(spec["generator"], spec["n"], seed=spec["seed"],
                        **spec["kwargs"])
        p = write_trace_file(ids, out_dir / spec["out"], spec["fmt"],
                             compress=True)
        info = tracefiles.load_trace_file(
            p, key_column="key" if spec["fmt"] == "csv" else 0,
            cache=False, with_info=True)[1]
        print(f"  wrote {p.relative_to(REPO) if p.is_relative_to(REPO) else p}"
              f"  ({info.n_requests} requests, {info.n_unique} unique, "
              f"top-1% share {info.top1pct_share:.3f})")
        paths.append(p)
    return paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    src = ap.add_mutually_exclusive_group()
    src.add_argument("--generator", choices=TRACES,
                     help="serialise a synthetic generator")
    src.add_argument("--input", help="convert an existing log file "
                                     "(any loader-readable shape)")
    src.add_argument("--samples", action="store_true",
                     help=f"regenerate the committed sample logs in "
                          f"{SAMPLES_DIR.relative_to(REPO)}")
    ap.add_argument("--n", type=int, default=60_000,
                    help="generator request count (default 60000)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kw", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="extra generator kwarg (repeatable), e.g. "
                         "--kw catalog=50000 --kw alpha=1.2")
    ap.add_argument("--input-format", choices=("keys", "csv"), default=None,
                    help="--input parse format (default: infer from suffix)")
    ap.add_argument("--key-column", default="0",
                    help="--input CSV key column: index or name (default 0)")
    ap.add_argument("--format", choices=("keys", "csv"), default="keys",
                    help="output shape (default keys)")
    ap.add_argument("--gzip", action="store_true", help="compress the output")
    ap.add_argument("-o", "--out", help="output path")
    args = ap.parse_args(argv)

    if args.samples:
        write_samples()
        return 0
    if not args.out:
        ap.error("-o/--out is required (unless --samples)")
    if args.generator:
        kwargs = {}
        for kv in args.kw:
            k, sep, v = kv.partition("=")
            if not sep or not k:
                ap.error(f"--kw expects KEY=VALUE, got {kv!r}")
            try:
                kwargs[k] = int(v)
            except ValueError:
                try:
                    kwargs[k] = float(v)
                except ValueError:
                    ap.error(f"--kw {k}: generator knobs are numeric, "
                             f"got {v!r}")
        ids = get_trace(args.generator, args.n, seed=args.seed, **kwargs)
    elif args.input:
        key_column = (int(args.key_column) if args.key_column.isdigit()
                      else args.key_column)
        ids = tracefiles.load_trace_file(
            args.input, fmt=args.input_format, key_column=key_column,
            cache=False)
    else:
        ap.error("pass --generator, --input, or --samples")
    p = write_trace_file(ids, Path(args.out), args.format,
                         compress=args.gzip)
    info = tracefiles.trace_info(ids, path=str(p), fmt=args.format)
    print(f"wrote {p}: {info.n_requests} requests, {info.n_unique} unique, "
          f"top-1% share {info.top1pct_share:.3f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
