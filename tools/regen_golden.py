"""Regenerate the golden differential files in ``tests/golden/``.

Every scenario in ``repro.cachesim.scenarios.GOLDEN_SCENARIOS`` pins a
small, fixed sub-grid (``Scenario.golden_grid()``): this script runs that
grid on the REFERENCE engine — the bit-exact per-request oracle — and
writes one JSON file per scenario holding the exact ``SimResult`` of
every (trace, cell, policy).  ``tests/test_golden_scenarios.py`` then
asserts the FAST engine reproduces each file bit-for-bit, so fast-path
parity and scenario semantics are pinned for every future change.

Usage::

    PYTHONPATH=src python tools/regen_golden.py            # rewrite all
    PYTHONPATH=src python tools/regen_golden.py fig4_gradle
    PYTHONPATH=src python tools/regen_golden.py --check    # exit 1 if stale

Golden files are deterministic: pure NumPy float64 + Python floats, JSON
with sorted keys — regenerating on any platform must produce an
identical byte stream (CI regenerates and fails on any diff).  If a
change legitimately alters simulator semantics, rerun this script and
commit the new files WITH the change, explaining the drift in the PR.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

from repro.cachesim.scenarios import GOLDEN_SCENARIOS, get_scenario  # noqa: E402
from repro.cachesim.sweep import cell_label, run_grid  # noqa: E402

GOLDEN_DIR = REPO / "tests" / "golden"


def _jsonable(v):
    return list(v) if isinstance(v, tuple) else v


def result_payload(res) -> dict:
    """Every raw accumulator of a result dataclass, pinned exactly (no
    rounding).  Works for flat ``SimResult`` and topology ``TopoResult``
    cells alike — whatever dataclass the grid returns is what's pinned."""
    return {f.name: _jsonable(getattr(res, f.name))
            for f in dataclasses.fields(res)}


def golden_payload(name: str) -> dict:
    """Run one scenario's golden sub-grid on the reference engine."""
    sc = get_scenario(name)
    traces, values = sc.golden_grid()
    base = sc.config(engine="reference", **sc.golden_base)
    grid = run_grid(traces, base, sc.axis, values,
                    policies=sc.policies, share_system=False)
    cells = []
    for value in values:          # deterministic order: values, then traces
        label = cell_label(sc.axis, value)
        for trace_name in traces:
            for policy, res in grid[(trace_name, label)].items():
                cells.append({
                    "trace": trace_name,
                    "label": _jsonable(label),
                    "policy": policy,
                    "result": result_payload(res),
                })
    return {
        "scenario": sc.name,
        "engine": "reference",
        "axis": sc.axis,
        "n_requests": sc.golden_n_requests,
        "seed": sc.seed,
        "golden_base": {k: _jsonable(v) for k, v in sc.golden_base.items()},
        "policies": list(sc.policies),
        "regenerate_with": "PYTHONPATH=src python tools/regen_golden.py",
        "cells": cells,
    }


def render(payload: dict) -> str:
    return json.dumps(payload, indent=1, sort_keys=True) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("scenarios", nargs="*", default=[],
                    help=f"subset to regenerate (default: all of "
                         f"{', '.join(GOLDEN_SCENARIOS)})")
    ap.add_argument("--check", action="store_true",
                    help="don't write; exit 1 if any file is stale/missing")
    args = ap.parse_args(argv)
    names = args.scenarios or list(GOLDEN_SCENARIOS)
    unknown = [n for n in names if n not in GOLDEN_SCENARIOS]
    if unknown:
        # a file outside GOLDEN_SCENARIOS would fail test_golden_coverage
        # and never be freshness-checked — refuse to create one
        ap.error(f"not golden scenario(s): {', '.join(unknown)} "
                 f"(golden: {', '.join(GOLDEN_SCENARIOS)}; add the name to "
                 f"repro.cachesim.scenarios.GOLDEN_SCENARIOS first)")

    GOLDEN_DIR.mkdir(parents=True, exist_ok=True)
    stale = []
    for name in names:
        path = GOLDEN_DIR / f"{name}.json"
        text = render(golden_payload(name))
        on_disk = path.read_text() if path.exists() else None
        if text == on_disk:
            print(f"  ok     {path.relative_to(REPO)}")
            continue
        if args.check:
            stale.append(path.relative_to(REPO))
            print(f"  STALE  {path.relative_to(REPO)}")
        else:
            path.write_text(text)
            print(f"  wrote  {path.relative_to(REPO)}")
    if stale:
        print(f"\n{len(stale)} golden file(s) out of date; regenerate with\n"
              f"  PYTHONPATH=src python tools/regen_golden.py")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
