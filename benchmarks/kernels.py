"""Kernel micro-benchmarks (interpret mode on CPU — wall-clock here is NOT
the TPU number; the derived column reports the work per call so the
roofline section can translate to TPU time analytically)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(f, *args, iters=3):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = f(*args)
        jax.block_until_ready(out)
    return (time.time() - t0) / iters


def run_kernel_benches(full: bool, interpret: bool = None):
    """``interpret=None`` auto-selects Pallas interpret mode from the JAX
    backend (compiled on TPU, interpret elsewhere); pass True/False to
    force it (``benchmarks.run --interpret``)."""
    from repro.kernels.bloom import bloom_probe, build_indicator
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ssd import ssd_scan

    out = []
    rng = jax.random.PRNGKey(0)

    # bloom: B keys x n caches
    n, mbytes, k, bkeys = 4, 2048, 10, 1024
    member = jnp.arange(500)
    bits = jnp.stack([build_indicator(member, mbytes * 8, k, seed=j)
                      for j in range(n)])
    keys = jnp.arange(bkeys, dtype=jnp.int32)
    dt = _time(lambda b_, k_: bloom_probe(b_, k_, k=k, interpret=interpret),
               bits, keys)
    probes = bkeys * n * k
    out.append(("kernel_bloom_probe", dt / bkeys * 1e6, probes))

    # flash attention fwd
    b, s, hq, hkv, d = (2, 1024, 8, 2, 64) if full else (1, 512, 4, 2, 64)
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, s, hq, d), jnp.float32)
    kk = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32)
    dt = _time(lambda *a: flash_attention(*a), q, kk, v, iters=1)
    flops = 4.0 * b * hq * s * s * d
    out.append(("kernel_flash_attention", dt * 1e6, flops))

    # ssd
    b, s, h, p, nstate = (2, 1024, 4, 64, 64) if full else (1, 512, 2, 64, 64)
    ks = jax.random.split(rng, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dts = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)) - 1.0)
    A = -jnp.exp(jax.random.uniform(ks[2], (h,), minval=-1.0, maxval=1.0))
    B = jax.random.normal(ks[3], (b, s, nstate), jnp.float32)
    C = jax.random.normal(ks[4], (b, s, nstate), jnp.float32)
    dt = _time(lambda *a: ssd_scan(*a, chunk=128), x, dts, A, B, C, iters=1)
    out.append(("kernel_ssd_scan", dt * 1e6, b * s * h * p * nstate))
    return out
