"""Paper-figure pipeline: scenarios -> grid runs -> artifacts -> curves.

End-to-end reproduction of the paper's evaluation figures, driven by the
declarative scenario registry (``repro.cachesim.scenarios``).  Every
figure is one or more named scenarios; each scenario runs through the
shared-SystemTrace grid runner (``repro.cachesim.sweep``) and lands as

  * ``artifacts/figs/<scenario>.json`` — run metadata + flat per-
    (trace, cell, policy) records + per-policy cost curves;
  * ``artifacts/figs/<scenario>.csv``  — the same records, flat;
  * ``artifacts/figs/<scenario>.png``  — cost-vs-axis curves (one panel
    per trace, one line per policy), when matplotlib is available.

CLI::

    python -m benchmarks.paper_figs --list
    python -m benchmarks.paper_figs --scenario fig4_gradle --json
    python -m benchmarks.paper_figs --scenario all --smoke --json --csv
    python -m benchmarks.paper_figs --figure fig4 --plot

``--smoke`` runs each scenario at golden scale (seconds, CI-friendly);
``--full`` at paper scale (1M requests).  The legacy per-figure entry
points (``FIGS`` / :func:`run_fig`) remain for ``benchmarks/run.py`` and
now simply execute the figure's scenarios and derive the same headline
scalars as before.
"""
from __future__ import annotations

import argparse
import csv
import dataclasses
import json
import os
import sys
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from repro.cachesim.scenarios import (
    GOLDEN_SCENARIOS,
    Scenario,
    get_scenario,
    list_scenarios,
    run_scenario,
)
from repro.cachesim.sweep import axis_column, hashable_label

ART = Path(__file__).resolve().parent.parent / "artifacts" / "bench"
FIGS_DIR = Path(__file__).resolve().parent.parent / "artifacts" / "figs"

# fixed policy -> style assignment (identity, never cycled); categorical
# slots 1-4 of the skill-validated reference palette, and the PI lower
# bound drawn as a neutral dashed baseline rather than a series hue.
# Markers double as a CVD-safe secondary encoding.
POLICY_STYLE = {
    "fna":     dict(color="#2a78d6", marker="o", label="CS$_{FNA}$"),
    "fna_cal": dict(color="#eb6834", marker="s", label="CS$_{FNA}$-cal"),
    "fno":     dict(color="#1baf7a", marker="^", label="CS$_{FNO}$"),
    "hocs":    dict(color="#eda100", marker="D", label="HoCS"),
    "pi":      dict(color="#52514e", marker="", linestyle="--", label="PI"),
}


def _scale(full: bool):
    """(n_requests, cache_size, base_update_interval) — the reduced/full
    scale pair benchmarks/run.py normalises us_per_call against."""
    return (1_000_000, 10_000, 1_000) if full else (60_000, 2_000, 200)


def _n_requests(sc: Scenario, full: bool) -> int:
    return sc.n_requests_full if full else sc.n_requests


# ---------------------------------------------------------------------------
# Record shaping
# ---------------------------------------------------------------------------

def pivot_cells(records: Sequence[dict], axis: str) -> List[dict]:
    """Group flat per-policy records into one dict per (scenario, trace,
    cell): ``{"trace", axis, "cost": {policy: mean_cost}, ...}``.  Cells
    keep first-seen order (the grid's sweep order); the scenario enters
    the key because a multi-scenario figure (e.g. Fig. 5's two
    cadences) revisits the same (trace, axis-value) pairs.  ``axis`` is
    resolved through :func:`repro.cachesim.sweep.axis_column`, so callers
    pass the scenario's axis name even when its records carry the
    collision-prefixed column."""
    axis = axis_column(axis)
    cells: Dict[tuple, dict] = {}
    for r in records:
        key = (r.get("scenario"), r["trace"], hashable_label(r[axis]))
        cell = cells.setdefault(key, {
            "scenario": r.get("scenario"), "trace": r["trace"],
            axis: r[axis], "cost": {},
            "hit_ratio": {}, "neg_accesses": {},
            "fn_ratio": r["fn_ratio"], "fp_ratio": r["fp_ratio"],
        })
        cell["cost"][r["policy"]] = r["mean_cost"]
        cell["hit_ratio"][r["policy"]] = r["hit_ratio"]
        cell["neg_accesses"][r["policy"]] = r["neg_accesses"]
    return list(cells.values())


def normalised(cell: dict) -> Dict[str, float]:
    """Per-policy cost normalised by the PI lower bound (paper y-axis)."""
    pi = cell["cost"].get("pi")
    if not pi:
        return dict(cell["cost"])
    return {p: c / pi for p, c in cell["cost"].items()}


def curves(records: Sequence[dict], axis: str) -> Dict[str, Dict[str, list]]:
    """``{trace: {policy: [[x, mean_cost], ...]}}`` — the per-policy cost
    curves the JSON artifact carries (x is the axis label; per-cache
    tuples serialise as lists)."""
    axis = axis_column(axis)
    out: Dict[str, Dict[str, list]] = {}
    for cell in pivot_cells(records, axis):
        tr = out.setdefault(cell["trace"], {})
        for policy, cost in cell["cost"].items():
            tr.setdefault(policy, []).append([cell[axis], cost])
    return out


# ---------------------------------------------------------------------------
# Derived headline scalars (one per paper figure)
# ---------------------------------------------------------------------------

def derived_fig1(records, axis="update_interval") -> float:
    """Max observed FN ratio (paper: '>10% at interval >= 1K')."""
    return max(r["fn_ratio"] for r in records)


def derived_fig3(records, axis="miss_penalty") -> float:
    """Worst normalised FNO-FNA gap across (trace, M)."""
    gap = 0.0
    for cell in pivot_cells(records, axis):
        nc = normalised(cell)
        gap = max(gap, nc["fno"] - nc["fna"])
    return gap


def derived_fig4(records, axis="update_interval") -> float:
    """Bandwidth-equivalence factor: the largest interval ratio
    i_fna / i_fno at which calibrated FNA still matches FNO's cost at the
    SMALL interval (paper: 'x16 less bandwidth')."""
    best = 1.0
    cells = pivot_cells(records, axis)
    for grp in {(c["scenario"], c["trace"]) for c in cells}:
        sub = sorted((c for c in cells
                      if (c["scenario"], c["trace"]) == grp),
                     key=lambda c: c[axis])
        for lo in sub:
            for hi in sub:
                if hi[axis] < lo[axis]:
                    continue
                if normalised(hi)["fna_cal"] <= normalised(lo)["fno"] * 1.02:
                    best = max(best, hi[axis] / lo[axis])
    return best


def derived_fig5(records, axis="bpe") -> float:
    """Largest FNO cost INCREASE from growing the indicator (the paper's
    anomaly: more bits can hurt an FN-oblivious policy)."""
    worst = 0.0
    cells = pivot_cells(records, axis)
    for grp in {(c["scenario"], c["trace"]) for c in cells}:
        sub = sorted((c for c in cells
                      if (c["scenario"], c["trace"]) == grp),
                     key=lambda c: c[axis])
        for a, b in zip(sub, sub[1:]):
            worst = max(worst, normalised(b)["fno"] - normalised(a)["fno"])
    return worst


def derived_fig6(records, axis="cache_size") -> float:
    """Capacity equivalence: calibrated-FNA cost at the smallest cache
    over FNO cost at the largest (paper: FNA@4K beats FNO@32K => < 1)."""
    cells = sorted(pivot_cells(records, axis), key=lambda c: c[axis])
    return cells[0]["cost"]["fna_cal"] / cells[-1]["cost"]["fno"]


def derived_fig7(records, axis="n_caches") -> float:
    """Worst normalised FNO-FNA gap across cache counts."""
    gap = 0.0
    for cell in pivot_cells(records, axis):
        nc = normalised(cell)
        gap = max(gap, nc["fno"] - nc["fna"])
    return gap


def derived_advert(records, axis="advert_bandwidth") -> float:
    """Cost of starving advertisement (the arXiv:2104.01386 Pareto
    trade-off): FNA cost at the tightest bandwidth budget over the most
    generous one (> 1 — staleness costs surface as the self-adjusting
    policy's token bucket runs dry)."""
    cells = sorted(pivot_cells(records, axis), key=lambda c: c[axis])
    return cells[0]["cost"]["fna"] / cells[-1]["cost"]["fna"]


#: legacy figure name -> (scenario names, derived metric)
FIG_SCENARIOS: Dict[str, Tuple[Tuple[str, ...], object]] = {
    "fig1_fn_ratio": (("fig1_staleness", "fig1_staleness_tight"),
                      derived_fig1),
    "fig3_miss_penalty": (("fig3_penalty",), derived_fig3),
    "fig4_update_interval": (("fig4_gradle", "fig4_wiki"), derived_fig4),
    "fig5_indicator_size": (("fig5_indicator_size",
                             "fig5_indicator_size_fresh"), derived_fig5),
    "fig6_cache_size": (("fig6_cache_size",), derived_fig6),
    "fig7_num_caches": (("fig7_num_caches",), derived_fig7),
    "advert_bandwidth": (("advert_budget",), derived_advert),
}


def _run_fig_records(name: str, full: bool) -> Tuple[List[dict], float]:
    scenario_names, derive = FIG_SCENARIOS[name]
    records: List[dict] = []
    axis = None
    for sc_name in scenario_names:
        sc = get_scenario(sc_name)
        axis = sc.axis
        records.extend(run_scenario(sc, n_requests=_n_requests(sc, full)))
    # one ROW per (scenario, trace, cell) — the per-config granularity the
    # legacy figure functions reported, so benchmarks/run.py's
    # us-per-request normalisation (n_requests * len(rows)) stays
    # comparable across PRs rather than inflating with the policy count
    return pivot_cells(records, axis), float(derive(records, axis=axis))


def run_fig(name: str, full: bool = False) -> Tuple[List[dict], float, float]:
    """Legacy entry point (benchmarks/run.py 'paper' section): run the
    figure's scenarios, write artifacts/bench/<name>.json, return
    (records, derived headline scalar, seconds)."""
    t0 = time.time()
    rows, derived = _run_fig_records(name, full)
    dt = time.time() - t0
    ART.mkdir(parents=True, exist_ok=True)
    (ART / f"{name}.json").write_text(json.dumps(
        {"rows": rows, "derived": derived, "seconds": dt}, indent=1))
    return rows, derived, dt


# legacy alias: benchmarks/run.py iterates these names and calls run_fig
FIGS = FIG_SCENARIOS


# ---------------------------------------------------------------------------
# Scenario pipeline (CLI)
# ---------------------------------------------------------------------------

def plot_scenario(sc: Scenario, records: Sequence[dict], path: Path) -> bool:
    """Cost-vs-axis curves: one panel per trace, one line per policy
    (fixed palette slots; PI as a neutral dashed baseline).  Returns
    False when matplotlib is unavailable."""
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        return False
    col = axis_column(sc.axis)
    cells = pivot_cells(records, col)
    traces = list(dict.fromkeys(c["trace"] for c in cells))
    fig, axes = plt.subplots(1, len(traces),
                             figsize=(4.6 * len(traces), 3.4),
                             squeeze=False, sharey=True)
    for ax, tr in zip(axes[0], traces):
        sub = [c for c in cells if c["trace"] == tr]
        xs = [c[col] for c in sub]
        categorical = any(isinstance(x, (tuple, list)) for x in xs)
        pos = list(range(len(xs))) if categorical else xs
        for policy in sc.policies:
            ys = [c["cost"].get(policy) for c in sub]
            style = dict(POLICY_STYLE.get(policy, {"label": policy}))
            label = style.pop("label", policy)
            ax.plot(pos, ys, linewidth=2, markersize=6,
                    label=label, **style)
        if categorical:
            ax.set_xticks(pos)
            ax.set_xticklabels([str(x) for x in xs], fontsize=7)
        elif len(xs) > 1 and xs[0] > 0 and xs[-1] / max(xs[0], 1e-9) >= 16:
            ax.set_xscale("log", base=2)
        ax.set_title(tr, fontsize=10)
        ax.set_xlabel(sc.axis.replace("_", " "))
        ax.grid(True, linewidth=0.5, alpha=0.35)
        ax.spines[["top", "right"]].set_visible(False)
    axes[0][0].set_ylabel("mean service cost")
    axes[0][-1].legend(fontsize=8, frameon=False)
    fig.suptitle(sc.name, fontsize=11)
    fig.tight_layout()
    fig.savefig(path, dpi=150)
    plt.close(fig)
    return True


def _rebind_traces(sc: Scenario, trace_file: str,
                   trace_format: Optional[str],
                   key_column: Optional[str]) -> Scenario:
    """Point a scenario at an external log file: its workloads become the
    single ``file:<path>`` trace (loader kwargs from the CLI flags), the
    grid/axis/policies stay as declared.  Golden trace pins are dropped —
    they refer to the declared workloads."""
    spec = f"file:{trace_file}"
    kw: Dict[str, object] = {}
    if trace_format:
        kw["fmt"] = trace_format
    if key_column is not None:
        kw["key_column"] = (int(key_column) if key_column.isdigit()
                            else key_column)
    return dataclasses.replace(sc, traces=(spec,), golden_traces=None,
                               trace_kwargs={spec: kw})


def run_scenario_pipeline(name: str, *, smoke: bool = False,
                          full: bool = False,
                          n_requests: Optional[int] = None,
                          out_dir: Path = FIGS_DIR,
                          write_json: bool = False, write_csv: bool = False,
                          write_plot: bool = False,
                          engine: str = "fast",
                          trace_file: Optional[str] = None,
                          trace_format: Optional[str] = None,
                          key_column: Optional[str] = None,
                          store=None, workers: int = 0,
                          chunk_size: Optional[int] = None) -> dict:
    """Run one scenario end-to-end and write the requested artifacts.
    Returns ``{"scenario", "records", "seconds", "paths"}``.

    ``trace_file`` replays the scenario's grid on an external request log
    (wiki/CDN shape; see ``repro.cachesim.tracefiles``) instead of the
    declared workloads; ``trace_format``/``key_column`` are its loader
    knobs.  ``store``/``workers``/``chunk_size`` are the artifact-store
    root, phase-1 process-pool size and streaming phase-1 slice length
    passed to the grid runner (see ``repro.cachesim.store`` and
    ``docs/engine.md`` §Streaming phase 1)."""
    sc = get_scenario(name)
    if trace_file is not None:
        sc = _rebind_traces(sc, trace_file, trace_format, key_column)
    if n_requests is not None:
        n_req = n_requests
    elif smoke:
        n_req = sc.golden_n_requests
    else:
        n_req = _n_requests(sc, full)
    t0 = time.time()
    # smoke runs the golden sub-grid: it is sized to stay non-degenerate
    # at a few thousand requests, where the display grid's long cadences
    # would produce all-miss cells
    records = run_scenario(sc, n_requests=n_req, engine=engine, golden=smoke,
                           store=store, workers=workers,
                           chunk_size=chunk_size)
    dt = time.time() - t0
    # loader catalog/working-set stats (Sec. V-B) of any file-backed
    # workloads, at the subsample length that actually ran — the run
    # above warmed the .npz cache, and only the JSON artifact carries
    # them, so skip the reload entirely otherwise
    info_names = sc.golden_trace_names() if smoke else sc.traces
    file_infos = sc.file_trace_infos(n_req, names=info_names) \
        if write_json else {}
    # a file-backed trace shorter than the requested length loads (and
    # simulates) its full content: report what actually ran, keeping the
    # original request when it differs so artifacts never self-contradict
    n_run = max((r["n"] for r in records), default=n_req)
    paths: Dict[str, str] = {}
    out_dir.mkdir(parents=True, exist_ok=True)
    if write_json:
        p = out_dir / f"{sc.name}.json"
        p.write_text(json.dumps({
            "meta": {
                "scenario": sc.name, "figure": sc.figure,
                "description": sc.description, "axis": sc.axis,
                "policies": list(sc.policies), "n_requests": n_run,
                **({"n_requests_requested": n_req} if n_run != n_req else {}),
                "grid": "golden" if smoke else "display",
                "engine": engine, "seed": sc.seed, "seconds": round(dt, 3),
                **({"trace_info": file_infos} if file_infos else {}),
            },
            "records": records,
            "curves": curves(records, sc.axis),
        }, indent=1, default=list))
        paths["json"] = str(p)
    if write_csv:
        p = out_dir / f"{sc.name}.csv"
        fieldnames = list(dict.fromkeys(k for r in records for k in r))
        with open(p, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=fieldnames)
            w.writeheader()
            for r in records:
                w.writerow({k: (list(v) if isinstance(v, tuple) else v)
                            for k, v in r.items()})
        paths["csv"] = str(p)
    if write_plot:
        p = out_dir / f"{sc.name}.png"
        if plot_scenario(sc, records, p):
            paths["png"] = str(p)
        else:
            print(f"[paper_figs] matplotlib unavailable; skipped {p.name}",
                  file=sys.stderr)
    return {"scenario": sc.name, "records": records, "seconds": dt,
            "paths": paths}


def _summary_line(out: dict, axis: str) -> str:
    cells = pivot_cells(out["records"], axis)
    polys = sorted({p for c in cells for p in c["cost"]})
    parts = []
    for p in polys:
        vals = [c["cost"][p] for c in cells if p in c["cost"]]
        parts.append(f"{p}={min(vals):.2f}..{max(vals):.2f}")
    arts = ",".join(sorted(out["paths"])) or "no artifacts"
    return (f"{out['scenario']}: {len(cells)} cells in "
            f"{out['seconds']:.1f}s [{arts}]  " + " ".join(parts))


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="benchmarks.paper_figs",
        description="Scenario-driven paper-figure pipeline")
    ap.add_argument("--scenario", action="append", default=[],
                    help="scenario name (repeatable), or 'all'")
    ap.add_argument("--figure", action="append", default=[],
                    help="run every scenario of a figure (fig1..fig7, beyond)")
    ap.add_argument("--list", action="store_true",
                    help="list scenarios and exit")
    ap.add_argument("--smoke", action="store_true",
                    help="run each scenario's golden sub-grid "
                         "(seconds, non-degenerate; CI smoke)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale runs (1M requests)")
    ap.add_argument("--n", type=int, default=None,
                    help="override n_requests explicitly")
    ap.add_argument("--json", action="store_true", help="write JSON artifact")
    ap.add_argument("--csv", action="store_true", help="write CSV artifact")
    ap.add_argument("--plot", action="store_true", help="write PNG curves")
    ap.add_argument("--out", default=str(FIGS_DIR), help="artifact directory")
    ap.add_argument("--engine", choices=("fast", "reference"), default="fast")
    ap.add_argument("--trace-file", default=None, metavar="PATH",
                    help="replay the selected scenarios' grids on an "
                         "external request log (wiki/CDN shape; gzip "
                         "transparent) instead of their declared workloads")
    ap.add_argument("--trace-format", choices=("keys", "csv"), default=None,
                    help="--trace-file parse format "
                         "(default: infer from suffix)")
    ap.add_argument("--key-column", default=None, metavar="COL",
                    help="--trace-file CSV key column: 0-based index or "
                         "header name (default 0)")
    ap.add_argument("--store", default=os.environ.get("REPRO_STORE") or None,
                    metavar="DIR",
                    help="content-addressed artifact store root: sweeps/"
                         "decision tables persist here and repeated runs "
                         "hydrate instead of recomputing (default: the "
                         "REPRO_STORE environment variable)")
    ap.add_argument("--workers", type=int, default=0, metavar="N",
                    help="compute independent system-key groups' sweeps "
                         "in an N-process pool (bit-identical to serial)")
    ap.add_argument("--chunk-size", type=int, default=None, metavar="N",
                    help="stream every phase-1 system sweep through "
                         "N-request trace slices (bit-identical to the "
                         "one-shot sweep, bounded working set; see "
                         "docs/engine.md)")
    args = ap.parse_args(argv)
    if args.chunk_size is not None and args.chunk_size < 1:
        ap.error("--chunk-size must be >= 1")
    if args.store:
        # trace parse caches join the same root (tracefiles reads the env)
        os.environ["REPRO_STORE"] = args.store
    if args.trace_file is None and (args.trace_format or args.key_column):
        ap.error("--trace-format/--key-column require --trace-file")

    if args.list:
        for sc in list_scenarios():
            golden = " [golden]" if sc.name in GOLDEN_SCENARIOS else ""
            print(f"{sc.name:24s} {sc.figure:7s} axis={sc.axis:16s} "
                  f"traces={','.join(sc.traces)}{golden}")
            print(f"{'':24s} {sc.description}")
        return 0

    names: List[str] = []
    known_figures = {sc.figure for sc in list_scenarios()}
    for f in args.figure:
        if f not in known_figures:
            ap.error(f"unknown figure {f!r}; known: {sorted(known_figures)}")
        names.extend(sc.name for sc in list_scenarios(figure=f))
    if "all" in args.scenario:
        names.extend(sc.name for sc in list_scenarios())
    else:
        known = {sc.name for sc in list_scenarios()}
        bad = [n for n in args.scenario if n not in known]
        if bad:
            ap.error(f"unknown scenario(s) {', '.join(bad)}; "
                     f"see --list for the registry")
        names.extend(args.scenario)
    if not names:
        ap.error("nothing to run: pass --scenario/--figure (or --list)")
    seen = list(dict.fromkeys(names))

    for name in seen:
        out = run_scenario_pipeline(
            name, smoke=args.smoke, full=args.full, n_requests=args.n,
            out_dir=Path(args.out), write_json=args.json,
            write_csv=args.csv, write_plot=args.plot, engine=args.engine,
            trace_file=args.trace_file, trace_format=args.trace_format,
            key_column=args.key_column, store=args.store,
            workers=args.workers, chunk_size=args.chunk_size)
        print(_summary_line(out, get_scenario(name).axis))
    return 0


if __name__ == "__main__":
    sys.exit(main())
