"""Benchmarks reproducing the paper's tables/figures on synthetic traces.

One function per figure.  Each returns (rows, derived) where rows are
dicts (written to artifacts/bench/*.json) and ``derived`` is the headline
scalar used in the run.py CSV.  ``full=True`` uses paper-scale parameters
(1M requests, 10K caches); the default is a faithful reduced-scale sweep
that finishes on one CPU core in minutes (same qualitative regimes: the
update interval and cache size scale together, keeping interval/capacity
ratios identical to the paper's).
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Dict, List, Tuple

import numpy as np

from repro.cachesim import SimConfig, Simulator, get_trace
from repro.cachesim.simulator import run_policies

ART = Path(__file__).resolve().parent.parent / "artifacts" / "bench"


def _scale(full: bool):
    """(n_requests, cache_size, base_update_interval)."""
    return (1_000_000, 10_000, 1_000) if full else (60_000, 2_000, 200)


# ---------------------------------------------------------------------------
# Fig. 1: false-negative ratio vs update interval (per bpe, per trace)
# ---------------------------------------------------------------------------

def fig1_fn_ratio(full: bool = False) -> Tuple[List[Dict], float]:
    n_req, csize, _ = _scale(full)
    intervals = [16, 64, 256, 1024, 4096, 8192] if full else [16, 64, 256, 1024, 2048]
    rows = []
    for trace_name in ("wiki", "gradle"):
        trace = get_trace(trace_name, n_req, seed=1)
        for bpe in (4.0, 14.0):
            for interval in intervals:
                cfg = SimConfig(cache_size=csize, update_interval=interval,
                                bpe=bpe, policy="fno")
                res = Simulator(cfg).run(trace)
                rows.append({"trace": trace_name, "bpe": bpe,
                             "update_interval": interval,
                             "fn_ratio": res.fn_ratio, "fp_ratio": res.fp_ratio})
    # headline: max observed FN ratio (paper: ">10% at interval >= 1K")
    derived = max(r["fn_ratio"] for r in rows)
    return rows, derived


# ---------------------------------------------------------------------------
# Fig. 3: normalized cost vs miss penalty, 4 traces
# ---------------------------------------------------------------------------

def fig3_miss_penalty(full: bool = False) -> Tuple[List[Dict], float]:
    n_req, csize, interval = _scale(full)
    rows = []
    worst_gap = 0.0
    for trace_name in ("wiki", "gradle", "scarab", "f2"):
        trace = get_trace(trace_name, n_req, seed=1)
        for M in (50.0, 100.0, 500.0):
            base = SimConfig(cache_size=csize, update_interval=interval,
                             miss_penalty=M)
            res = run_policies(trace, base, policies=("fna", "fna_cal", "fno", "pi"))
            pi = res["pi"].mean_cost
            row = {"trace": trace_name, "M": M,
                   "fna_norm": res["fna"].mean_cost / pi,
                   "fna_cal_norm": res["fna_cal"].mean_cost / pi,
                   "fno_norm": res["fno"].mean_cost / pi,
                   "pi_cost": pi}
            rows.append(row)
            worst_gap = max(worst_gap, row["fno_norm"] - row["fna_norm"])
    return rows, worst_gap


# ---------------------------------------------------------------------------
# Fig. 4: normalized cost vs update interval
# ---------------------------------------------------------------------------

def fig4_update_interval(full: bool = False) -> Tuple[List[Dict], float]:
    n_req, csize, _ = _scale(full)
    intervals = [16, 128, 512, 1024, 4096, 8192] if full else [16, 128, 512, 2048]
    rows = []
    for trace_name in ("wiki", "gradle"):
        trace = get_trace(trace_name, n_req, seed=1)
        for interval in intervals:
            base = SimConfig(cache_size=csize, update_interval=interval)
            res = run_policies(trace, base, policies=("fna", "fna_cal", "fno", "pi"))
            pi = res["pi"].mean_cost
            rows.append({"trace": trace_name, "update_interval": interval,
                         "fna_norm": res["fna"].mean_cost / pi,
                         "fna_cal_norm": res["fna_cal"].mean_cost / pi,
                         "fno_norm": res["fno"].mean_cost / pi,
                         "fna_neg_accesses": res["fna"].neg_accesses})
    # headline: bandwidth-equivalence factor — largest interval where FNA
    # still beats FNO at the SMALLEST interval (paper: "x16 less bandwidth")
    derived = _bandwidth_equivalence(rows)
    return rows, derived


def _bandwidth_equivalence(rows) -> float:
    """Largest interval ratio i_fna/i_fno such that FNA(cal) at the LARGE
    interval still matches FNO at the small one (paper: "x16 less
    bandwidth")."""
    best = 1.0
    for tr in {r["trace"] for r in rows}:
        sub = sorted((r for r in rows if r["trace"] == tr),
                     key=lambda r: r["update_interval"])
        for lo in sub:
            for hi in sub:
                if hi["update_interval"] < lo["update_interval"]:
                    continue
                if hi["fna_cal_norm"] <= lo["fno_norm"] * 1.02:
                    best = max(best, hi["update_interval"] / lo["update_interval"])
    return best


# ---------------------------------------------------------------------------
# Fig. 5: normalized cost vs indicator size (bpe)
# ---------------------------------------------------------------------------

def fig5_indicator_size(full: bool = False) -> Tuple[List[Dict], float]:
    n_req, csize, interval = _scale(full)
    rows = []
    for trace_name in ("wiki", "gradle"):
        trace = get_trace(trace_name, n_req, seed=1)
        for bpe in (2.0, 4.0, 8.0, 14.0, 22.0):
            for mult in (1, 4):
                base = SimConfig(cache_size=csize, bpe=bpe,
                                 update_interval=interval * mult)
                res = run_policies(trace, base, policies=("fna", "fna_cal", "fno", "pi"))
                pi = res["pi"].mean_cost
                rows.append({"trace": trace_name, "bpe": bpe,
                             "update_interval": interval * mult,
                             "fna_norm": res["fna"].mean_cost / pi,
                             "fna_cal_norm": res["fna_cal"].mean_cost / pi,
                             "fno_norm": res["fno"].mean_cost / pi})
    # headline: does FNO ever DEGRADE with a larger indicator? (paper's anomaly)
    derived = 0.0
    for tr in ("wiki", "gradle"):
        for ui_rows in [[r for r in rows if r["trace"] == tr and
                         r["update_interval"] == interval * m] for m in (1, 4)]:
            ui_rows.sort(key=lambda r: r["bpe"])
            for a, b in zip(ui_rows, ui_rows[1:]):
                derived = max(derived, b["fno_norm"] - a["fno_norm"])
    return rows, derived


# ---------------------------------------------------------------------------
# Fig. 6: actual mean cost vs cache size
# ---------------------------------------------------------------------------

def fig6_cache_size(full: bool = False) -> Tuple[List[Dict], float]:
    n_req = 300_000 if full else 80_000
    sizes = (1_000, 4_000, 8_000, 16_000, 32_000) if full else (500, 1_000, 2_000, 4_000)
    trace = get_trace("wiki", n_req, seed=2)
    rows = []
    for size in sizes:
        for interval in (max(size // 8, 16), max(size // 2, 64)):
            base = SimConfig(cache_size=size, update_interval=interval)
            res = run_policies(trace, base, policies=("fna", "fna_cal", "fno", "pi"))
            rows.append({"cache_size": size, "update_interval": interval,
                         "fna_cost": res["fna"].mean_cost,
                         "fna_cal_cost": res["fna_cal"].mean_cost,
                         "fno_cost": res["fno"].mean_cost,
                         "pi_cost": res["pi"].mean_cost})
    # headline: capacity-equivalence — cost of FNA at smallest size vs FNO at
    # largest (paper: FNA@4K beats FNO@32K)
    small_fna = [r for r in rows if r["cache_size"] == sizes[0]]
    big_fno = [r for r in rows if r["cache_size"] == sizes[-1]]
    derived = min(r["fna_cal_cost"] for r in small_fna) / min(r["fno_cost"] for r in big_fno)
    return rows, derived


# ---------------------------------------------------------------------------
# Fig. 7: number of caches (homogeneous costs = 2)
# ---------------------------------------------------------------------------

def fig7_num_caches(full: bool = False) -> Tuple[List[Dict], float]:
    n_req, csize, interval = _scale(full)
    trace = get_trace("gradle", n_req, seed=1)
    rows = []
    worst_gap = 0.0
    for n in (2, 3, 5, 7):
        for mult in (1, 4):
            base = SimConfig(n_caches=n, costs=tuple([2.0] * n), cache_size=csize,
                             update_interval=interval * mult)
            res = run_policies(trace, base, policies=("fna", "fna_cal", "fno", "pi"))
            pi = res["pi"].mean_cost
            row = {"n_caches": n, "update_interval": interval * mult,
                   "fna_norm": res["fna"].mean_cost / pi,
                   "fna_cal_norm": res["fna_cal"].mean_cost / pi,
                   "fno_norm": res["fno"].mean_cost / pi}
            rows.append(row)
            worst_gap = max(worst_gap, row["fno_norm"] - row["fna_norm"])
    return rows, worst_gap


FIGS = {
    "fig1_fn_ratio": fig1_fn_ratio,
    "fig3_miss_penalty": fig3_miss_penalty,
    "fig4_update_interval": fig4_update_interval,
    "fig5_indicator_size": fig5_indicator_size,
    "fig6_cache_size": fig6_cache_size,
    "fig7_num_caches": fig7_num_caches,
}


def run_fig(name: str, full: bool = False) -> Tuple[List[Dict], float, float]:
    t0 = time.time()
    rows, derived = FIGS[name](full)
    dt = time.time() - t0
    ART.mkdir(parents=True, exist_ok=True)
    (ART / f"{name}.json").write_text(json.dumps(
        {"rows": rows, "derived": derived, "seconds": dt}, indent=1))
    return rows, derived, dt
