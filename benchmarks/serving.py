"""Serving benchmark: the FNA prefix-cache router end to end (paper
technique on the serving path), host wall-clock.

``run_replay_benches`` (section ``router_replay``) drives the
concurrent-client replay harness (``repro.serving.replay``): threaded
clients against each scenario-defined cluster regime, reporting
sustained throughput (derived = reqs/s) plus p50/p99 decision latency
in the extras, and a batch-size sweep on the heterogeneous regime.  The
CI bench-smoke job merges these rows into BENCH_sim.json, so the
routing tier's latency trajectory accumulates per commit next to the
simulator's."""
from __future__ import annotations

import dataclasses
import time

#: replay regimes the bench covers (>= 2 per the PR-9 acceptance bar)
REPLAY_REGIMES = ("hetero_tiers", "staggered_adverts", "delayed_view")
REPLAY_BATCHES = (1, 4, 16)


def run_serving_bench(full: bool):
    import numpy as np
    from repro.cachesim.traces import recency_trace
    from repro.serving import ClusterConfig, PrefixServeCluster

    n = 20_000 if full else 6_000
    stream = recency_trace(n, p_new=0.2, window=512, seed=7)
    out = []
    base = ClusterConfig(n_nodes=4, node_capacity=256, update_interval=128)
    results = {}
    for policy in ("fno", "fna", "fna_cal", "pi"):
        cluster = PrefixServeCluster(dataclasses.replace(base, policy=policy))
        t0 = time.time()
        for p in stream:
            cluster.request(int(p))
        dt = time.time() - t0
        results[policy] = cluster.stats
        out.append((f"serving_router_{policy}", dt / n * 1e6,
                    cluster.stats.mean_cost))
    # headline sanity row: cost reduction of fna_cal vs fno
    out.append(("serving_fna_cal_vs_fno_cost_ratio", 0.0,
                results["fna_cal"].mean_cost / results["fno"].mean_cost))
    return out


def _replay_extras(r) -> dict:
    return {"regime": r.regime, "policy": r.policy,
            "n_clients": r.n_clients, "batch_size": r.batch_size,
            "requests": r.requests, "p50_us": round(r.p50_us, 2),
            "p99_us": round(r.p99_us, 2),
            "mean_cost": round(r.mean_cost, 4),
            "hit_ratio": round(r.hit_ratio, 4)}


def run_replay_benches(full: bool):
    """Concurrent-client replay rows (section ``router_replay``); see the
    module docstring.  us_per_call = wall-clock per routed request under
    contention; derived = achieved reqs/s."""
    from repro.serving.replay import batch_sweep, replay

    n = 12_000 if full else 4_000
    clients = 8 if full else 4
    out = []
    for regime in REPLAY_REGIMES:
        r = replay(regime, policy="fna_cal", n_requests=n,
                   n_clients=clients, batch_size=1, mode="threads", seed=0)
        out.append((f"replay_{regime}", r.wall_s / max(r.requests, 1) * 1e6,
                    r.achieved_rps, _replay_extras(r)))
    # router-turn amortisation under contention: same load per batch size
    for r in batch_sweep("hetero_tiers", policy="fna_cal",
                         batch_sizes=REPLAY_BATCHES, n_requests=n,
                         n_clients=clients, mode="threads", seed=0):
        out.append((f"replay_hetero_tiers_b{r.batch_size}",
                    r.wall_s / max(r.requests, 1) * 1e6,
                    r.achieved_rps, _replay_extras(r)))
    return out
