"""Serving benchmark: the FNA prefix-cache router end to end (paper
technique on the serving path), host wall-clock."""
from __future__ import annotations

import dataclasses
import time


def run_serving_bench(full: bool):
    import numpy as np
    from repro.cachesim.traces import recency_trace
    from repro.serving import ClusterConfig, PrefixServeCluster

    n = 20_000 if full else 6_000
    stream = recency_trace(n, p_new=0.2, window=512, seed=7)
    out = []
    base = ClusterConfig(n_nodes=4, node_capacity=256, update_interval=128)
    results = {}
    for policy in ("fno", "fna", "fna_cal", "pi"):
        cluster = PrefixServeCluster(dataclasses.replace(base, policy=policy))
        t0 = time.time()
        for p in stream:
            cluster.request(int(p))
        dt = time.time() - t0
        results[policy] = cluster.stats
        out.append((f"serving_router_{policy}", dt / n * 1e6,
                    cluster.stats.mean_cost))
    # headline sanity row: cost reduction of fna_cal vs fno
    out.append(("serving_fna_cal_vs_fno_cost_ratio", 0.0,
                results["fna_cal"].mean_cost / results["fno"].mean_cost))
    return out
