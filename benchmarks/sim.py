"""Simulator throughput benchmarks: requests/sec per policy x trace on the
fast engine, the headline fast-vs-reference comparisons
(``sim_speedup_fna_gradle``, ``sim_speedup_fna_cal_gradle``), and the
shared-SystemTrace amortisation rows: multi-policy runs
(``sweep_amortisation``) and decision-side grid cells
(``sweep_amortisation_decision`` — the Fig. 3 miss-penalty axis as one
sweep + stacked tables + replays vs per-cell full runs).

CSV columns: us_per_call = wall-clock per simulated request; derived =
requests/sec (or the speedup/amortisation factor for the ``sim_speedup`` /
``sweep_amortisation*`` rows).  Speedup/amortisation rows attach an
extras dict (JSON only) recording the workload shape behind the ratio —
request counts, and for table-build rows the (cells x versions x
patterns) row counts — so a perf trajectory across commits can tell a
regression from a workload change.

``run_jax_benches`` (section ``sim_jax``) covers the jitted table core:
the stacked (Fig. 3 penalty-grid-shaped) decision-table build on the
JAX backend vs the per-cell NumPy mirror (``sim_tables_jax_speedup`` —
CI gates this >= 1), the device-sharding efficiency of the same build
(``sweep_shard_efficiency``), and the Pallas subset-DP kernel in
interpret mode with an inline bit-exactness assert against the NumPy
oracle (``sim_subsetdp_pallas_interpret``).

``run_store_benches`` (section ``sim_store``) covers the artifact-store
perf tier (``repro.cachesim.store``): ``sweep_store_warm_speedup`` — the
Fig. 3 penalty grid cold vs warm-store, with an inline bit-identity
assert between the two grids (CI gates this >= 5) — and
``sweep_parallel_speedup`` — a 4-group system axis serial vs
``run_grid(workers=4)``, fresh store per measurement (recorded, not
gated: spawn + import overhead makes it machine-dependent).

``run_ingest_benches`` (section ``sim_ingest``) covers the streaming
trace-ingestion tier (``repro.cachesim.tracefiles``): a 10M-request
synthetic wiki log is generated chunk-written by ``tools/
make_trace_file.py`` in a scratch directory, then statted twice in
SEPARATE child processes — one-shot (``parse_trace_file`` +
``trace_info``, the full array materialised) vs streaming
(``stream_trace_info``, O(chunk + catalog) memory) — with an inline
equality assert between the two :class:`TraceInfo` results.  Each child
reports its own ``ru_maxrss`` process high-water, so the
``ingest_peak_rss_ratio`` row (streaming / one-shot peak RSS; CI gates
this <= 0.5) measures the paths in isolation rather than whichever
allocator high-water the bench process accumulated first.

``run_topology_benches`` (section ``sim_topology``) covers the
hierarchical-topology tier (``repro.cachesim.topology``):
``sim_topology_tree`` — requests/sec through a 3-level fanout-2 tree on
the Fig. 3 workload — and ``topology_sweep_amortisation`` — the same
tree swept along a decision-side ``hop_penalty`` axis with one shared
:class:`SweepPool` vs per-cell recompute, with an inline bit-identity
assert between the two grids (CI gates this >= 2: cross-cell tier-sweep
sharing must at least halve the grid's wall-clock).

``run_advert_benches`` (section ``sim_advert``) covers the
advertisement-event subsystem (``repro.cachesim.advert``): per-bandwidth
``advert_pareto_bw*`` rows compare the self-adjusting policy's cost
against a fixed-cadence baseline advertising the SAME per-cache event
count (equal bytes-on-wire budget — both send full bitmaps), and the
``advert_bandwidth_pareto`` summary row records the worst ratio across
the bandwidth grid (CI gates this >= 1: drift-triggered advertisement
must not lose to uniform cadence at equal budget).
"""
from __future__ import annotations

import time

HEADLINE_REQUESTS = 200_000      # the acceptance benchmark (gradle)
POLICIES = ("fna", "fno", "pi", "hocs", "fna_cal")
SWEEP_POLICIES = POLICIES
#: the decision-axis amortisation grid (miss_penalty is decision-side:
#: every cell shares one SystemTrace per trace)
DECISION_PENALTIES = (25.0, 50.0, 75.0, 100.0, 150.0, 250.0, 500.0, 1000.0)
DECISION_POLICIES = ("fna", "fno", "pi")


def _run_once(cfg, trace):
    from repro.cachesim import Simulator
    t0 = time.time()
    Simulator(cfg).run(trace)
    return time.time() - t0


def run_sim_benches(full: bool):
    from repro.cachesim import SimConfig, get_trace
    from repro.cachesim.simulator import run_policies
    from repro.cachesim.traces import TRACES

    out = []
    # --- headline: fast vs reference, 200k-request gradle trace ---------
    # (fna exercises the table replay, fna_cal the speculative segmented
    # replay — the acceptance thresholds track both)
    trace = get_trace("gradle", HEADLINE_REQUESTS, seed=0)
    n_ref = HEADLINE_REQUESTS if full else HEADLINE_REQUESTS // 5
    for policy in ("fna", "fna_cal"):
        fast_cfg = SimConfig(engine="fast", policy=policy)
        _run_once(fast_cfg, trace)       # warm numpy/XLA caches
        dt_fast = min(_run_once(fast_cfg, trace) for _ in range(2))
        dt_ref = _run_once(
            SimConfig(engine="reference", policy=policy), trace[:n_ref])
        rps_fast = HEADLINE_REQUESTS / dt_fast
        rps_ref = n_ref / dt_ref
        out.append((f"sim_throughput_fast_{policy}_gradle",
                    dt_fast / HEADLINE_REQUESTS * 1e6, rps_fast))
        out.append((f"sim_throughput_ref_{policy}_gradle",
                    dt_ref / n_ref * 1e6, rps_ref))
        out.append((f"sim_speedup_{policy}_gradle",
                    dt_fast / HEADLINE_REQUESTS * 1e6, rps_fast / rps_ref,
                    {"n_requests": HEADLINE_REQUESTS,
                     "n_requests_ref": n_ref}))

    # --- shared-SystemTrace amortisation: 1 sweep + P replays vs P full
    # runs over the same (trace, system config); min-of-2 on both sides
    # like the headline rows, so a load spike can't skew the ratio --------
    n_amort = HEADLINE_REQUESTS if full else 150_000
    tr = get_trace("gradle", n_amort, seed=0)
    base = SimConfig(engine="fast", costs=(2.0, 2.0, 2.0))
    run_policies(tr, base, policies=SWEEP_POLICIES)          # warm

    def _time_policies(**kw):
        t0 = time.time()
        run_policies(tr, base, policies=SWEEP_POLICIES, **kw)
        return time.time() - t0

    dt_shared = min(_time_policies() for _ in range(2))
    dt_indep = min(_time_policies(share_system=False) for _ in range(2))
    out.append(("sweep_amortisation",
                dt_shared / (n_amort * len(SWEEP_POLICIES)) * 1e6,
                dt_indep / dt_shared,
                {"n_requests": n_amort, "policies": len(SWEEP_POLICIES)}))

    # --- decision-side cross-cell sharing: a miss-penalty grid (the
    # Fig. 3 axis) computes ONE SystemTrace for all its cells and stacks
    # the ds_pgm tables into one batched call, vs per-cell full runs ----
    from repro.cachesim.sweep import run_grid
    n_dec = 100_000 if full else 50_000
    grid_traces = {"gradle": get_trace("gradle", n_dec, seed=0)}
    dec_base = SimConfig(engine="fast", update_interval=200)

    def _time_grid(shared: bool) -> float:
        t0 = time.time()
        run_grid(grid_traces, dec_base, "miss_penalty", DECISION_PENALTIES,
                 policies=DECISION_POLICIES, share_system=shared)
        return time.time() - t0

    _time_grid(True)                                         # warm
    dt_dec_shared = min(_time_grid(True) for _ in range(2))
    dt_dec_indep = min(_time_grid(False) for _ in range(2))
    cells = len(DECISION_PENALTIES) * len(DECISION_POLICIES)
    out.append(("sweep_amortisation_decision",
                dt_dec_shared / (n_dec * cells) * 1e6,
                dt_dec_indep / dt_dec_shared,
                {"n_requests": n_dec, "cells": len(DECISION_PENALTIES),
                 "policies": len(DECISION_POLICIES)}))

    # --- requests/sec per policy x trace (fast engine) ------------------
    n_req = 100_000 if full else 30_000
    for trace_name in TRACES:
        tr = get_trace(trace_name, n_req, seed=0)
        for policy in POLICIES:
            costs = (2.0, 2.0, 2.0) if policy == "hocs" else (1.0, 2.0, 3.0)
            cfg = SimConfig(policy=policy, costs=costs, engine="fast")
            dt = _run_once(cfg, tr)
            out.append((f"sim_{policy}_{trace_name}", dt / n_req * 1e6,
                        n_req / dt))
    return out


def run_jax_benches(full: bool):
    """JAX/Pallas table-core rows (section ``sim_jax``); see the module
    docstring.  Runs entirely on host/CPU (the Pallas row uses interpret
    mode), so the CI smoke job covers every row."""
    import numpy as np

    from repro.cachesim import SimConfig, Simulator, get_trace
    from repro.cachesim.systemstate import SystemTrace
    from repro.core.batched import (
        _subset_dp,
        selection_tables,
        selection_tables_cells_jax,
    )
    from repro.kernels.subsetdp import subset_dp
    from repro.launch.mesh import make_sweep_mesh

    out = []
    # --- the Fig. 3 grid shape: a real SystemTrace view history, every
    # (penalty x fna/fno) decision cell stacked — jitted build vs the
    # per-cell NumPy mirror (the fast engine's two table backends) -------
    n_req = 100_000 if full else 50_000
    trace = get_trace("gradle", n_req, seed=0)
    cfg = SimConfig(engine="fast", update_interval=200)
    st = SystemTrace.compute(Simulator(cfg), trace)
    pi_v, nu_v = st.pi_v, st.nu_v
    v, n = pi_v.shape
    k = 1 << n
    cells = [(np.asarray(cfg.costs, np.float64), m, f)
             for m in DECISION_PENALTIES for f in (False, True)]
    c = len(cells)
    rows = c * v * k
    costs_cells = np.stack([j[0] for j in cells])
    penalties = np.asarray([j[1] for j in cells])
    fno_cells = np.asarray([j[2] for j in cells])

    def _numpy_build():
        t0 = time.time()
        for costs, m, f in cells:
            selection_tables(costs, pi_v, nu_v, m, fno=f, backend="numpy")
        return time.time() - t0

    def _jax_build(mesh=None):
        t0 = time.time()
        selection_tables_cells_jax(costs_cells, pi_v, nu_v, penalties,
                                   fno_cells, mesh=mesh)
        return time.time() - t0

    _jax_build()                                  # compile + warm
    dt_np = min(_numpy_build() for _ in range(3))
    dt_jax = min(_jax_build() for _ in range(3))
    out.append(("sim_tables_jax_speedup", dt_jax / rows * 1e6,
                dt_np / dt_jax,
                {"rows": rows, "cells": c, "versions": v, "patterns": k}))

    # --- device sharding: same stacked build over the sweep mesh; the
    # efficiency is (t_single / t_sharded) / devices, 1.0 on one device --
    mesh = make_sweep_mesh()
    devices = 1 if mesh is None else int(mesh.size)
    if mesh is None:
        dt_sharded, eff = dt_jax, 1.0
    else:
        _jax_build(mesh)                          # compile + warm
        dt_sharded = min(_jax_build(mesh) for _ in range(3))
        eff = (dt_jax / dt_sharded) / devices
    out.append(("sweep_shard_efficiency", dt_sharded / rows * 1e6, eff,
                {"rows": rows, "devices": devices}))

    # --- Pallas subset-DP kernel, interpret mode (CPU CI): throughput in
    # table rows/sec, with an inline bit-exactness assert vs the oracle --
    rng = np.random.default_rng(0)
    n_dp = 8
    b_dp = 4096 if full else 1024
    dp_costs = rng.uniform(0.05, 5.0, n_dp)
    dp_rhos = rng.uniform(0.0, 1.0, (b_dp, n_dp))
    ref = _subset_dp(dp_costs, dp_rhos, 100.0)
    got = subset_dp(dp_costs, dp_rhos, 100.0, backend="pallas",
                    interpret=True)
    assert got.tobytes() == ref.tobytes(), \
        "Pallas subset-DP drifted off the NumPy oracle"
    t0 = time.time()
    iters = 3
    for _ in range(iters):
        subset_dp(dp_costs, dp_rhos, 100.0, backend="pallas",
                  interpret=True)
    dt = (time.time() - t0) / iters
    out.append(("sim_subsetdp_pallas_interpret", dt / b_dp * 1e6,
                b_dp / dt, {"rows": b_dp, "n_caches": n_dp}))
    return out


#: the cost-vs-advertisement-bandwidth Pareto grid (bytes per insertion)
ADVERT_BANDWIDTHS = (1.0, 4.0, 16.0)


def run_advert_benches(full: bool):
    """Advert-subsystem rows (section ``sim_advert``); see the module
    docstring.  The fixed-cadence baseline is MATCHED per bandwidth: its
    per-cache ``update_interval`` is chosen so it advertises the same
    number of (full-bitmap) events the self-adjusting run actually made,
    i.e. both sides spend the same wire budget — the comparison isolates
    WHEN to advertise, the axis arXiv:2104.01386 optimises."""
    from repro.cachesim import SimConfig, Simulator, get_trace

    out = []
    n_req = 100_000 if full else 50_000
    trace = get_trace("gradle", n_req, seed=0)
    system = dict(cache_size=2_000, est_interval=50)
    ratios = []
    for bw in ADVERT_BANDWIDTHS:
        cfg = SimConfig(engine="fast", policy="fna",
                        advert_policy="self_adjusting",
                        advert_bandwidth=bw, advert_threshold=0.05,
                        **system)
        sim = Simulator(cfg)
        t0 = time.time()
        res_sa = sim.run(trace)
        dt = time.time() - t0
        nodes = sim.last_system.final_state["nodes"]
        events = [len(nd["adv_ins"]) for nd in nodes]
        n_ins = [nd["n_ins"] for nd in nodes]
        # same per-cache event count on a uniform cadence (insertion
        # dynamics are advert-independent, so n_ins carries over exactly)
        upd = tuple(max(1, n // max(e, 1))
                    for n, e in zip(n_ins, events))
        res_fx = Simulator(SimConfig(engine="fast", policy="fna",
                                     update_interval=upd,
                                     **system)).run(trace)
        ratio = res_fx.mean_cost / res_sa.mean_cost
        ratios.append(ratio)
        out.append((f"advert_pareto_bw{bw:g}", dt / n_req * 1e6, ratio,
                    {"bandwidth": bw,
                     "advert_events": int(res_sa.advert_events),
                     "advert_bytes": float(res_sa.advert_bytes),
                     "mean_cost_self_adjusting": res_sa.mean_cost,
                     "mean_cost_fixed": res_fx.mean_cost,
                     "baseline_update_interval": list(upd),
                     "baseline_advert_events": int(res_fx.advert_events),
                     "n_requests": n_req}))
    out.append(("advert_bandwidth_pareto", 0.0, min(ratios),
                {"bandwidths": list(ADVERT_BANDWIDTHS),
                 "ratios": [round(r, 4) for r in ratios],
                 "n_requests": n_req}))
    return out


#: the streaming-ingestion benchmark log (the ISSUE/CI acceptance size)
INGEST_REQUESTS = 10_000_000
#: catalog of the synthetic wiki log — kept moderate so the token -> id
#: dict (paid by BOTH paths) doesn't drown the array memory the
#: streaming path exists to avoid
INGEST_CATALOG = 100_000
#: streaming child's chunk size — the knob that bounds its peak memory
INGEST_CHUNK = 1 << 16

# child payloads for the two measured ingestion paths; each prints one
# JSON object {wall_s, maxrss_kb, info} and nothing else
_INGEST_ONESHOT = """\
import json, resource, sys, time
from repro.cachesim.tracefiles import parse_trace_file, trace_info
path = sys.argv[1]
t0 = time.perf_counter()
ids = parse_trace_file(path, fmt="keys")
info = trace_info(ids, path=path, fmt="keys")
wall = time.perf_counter() - t0
print(json.dumps({"wall_s": wall,
                  "maxrss_kb": resource.getrusage(
                      resource.RUSAGE_SELF).ru_maxrss,
                  "info": info.to_dict()}))
"""
_INGEST_STREAM = """\
import json, resource, sys, time
from repro.cachesim.tracefiles import stream_trace_info
path, chunk = sys.argv[1], int(sys.argv[2])
t0 = time.perf_counter()
info = stream_trace_info(path, fmt="keys", chunk_size=chunk)
wall = time.perf_counter() - t0
print(json.dumps({"wall_s": wall,
                  "maxrss_kb": resource.getrusage(
                      resource.RUSAGE_SELF).ru_maxrss,
                  "info": info.to_dict()}))
"""


def run_ingest_benches(full: bool):
    """Streaming-ingestion rows (section ``sim_ingest``); see the module
    docstring.  Linux ``ru_maxrss`` is in KB; the extras record MB."""
    import json
    import os
    import shutil
    import subprocess
    import sys
    import tempfile
    from pathlib import Path

    repo = Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [str(repo / "src")] + ([env["PYTHONPATH"]]
                               if env.get("PYTHONPATH") else []))

    def _child(code: str, *argv: str) -> dict:
        proc = subprocess.run([sys.executable, "-c", code, *argv],
                              env=env, capture_output=True, text=True)
        if proc.returncode != 0:
            raise RuntimeError(f"ingest child failed:\n{proc.stderr}")
        return json.loads(proc.stdout)

    out = []
    n = INGEST_REQUESTS
    tmp = tempfile.mkdtemp(prefix="repro-bench-ingest-")
    try:
        log = Path(tmp) / "wiki_10m.log"
        t0 = time.time()
        subprocess.run(
            [sys.executable, str(repo / "tools" / "make_trace_file.py"),
             "--generator", "wiki", "--n", str(n), "--seed", "0",
             "--kw", f"catalog={INGEST_CATALOG}",
             "--format", "keys", "-o", str(log)],
            env=env, check=True, capture_output=True, text=True)
        dt_gen = time.time() - t0
        out.append(("ingest_make_log_10m", dt_gen / n * 1e6, n / dt_gen,
                    {"n_requests": n, "bytes": log.stat().st_size}))

        one = _child(_INGEST_ONESHOT, str(log))
        stream = _child(_INGEST_STREAM, str(log), str(INGEST_CHUNK))
        assert stream["info"] == one["info"], \
            f"streaming TraceInfo drifted: {stream['info']} vs {one['info']}"
        for name, r in (("ingest_oneshot_10m", one),
                        ("ingest_stream_10m", stream)):
            out.append((name, r["wall_s"] / n * 1e6, n / r["wall_s"],
                        {"n_requests": n,
                         "maxrss_mb": round(r["maxrss_kb"] / 1024, 1),
                         "n_unique": r["info"]["n_unique"],
                         "top1pct_share": r["info"]["top1pct_share"]}))
        ratio = stream["maxrss_kb"] / one["maxrss_kb"]
        out.append(("ingest_peak_rss_ratio", 0.0, ratio,
                    {"n_requests": n, "chunk_size": INGEST_CHUNK,
                     "stream_maxrss_mb": round(stream["maxrss_kb"] / 1024, 1),
                     "oneshot_maxrss_mb": round(one["maxrss_kb"] / 1024, 1)}))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return out


def run_store_benches(full: bool):
    """Artifact-store rows (section ``sim_store``); see the module
    docstring.  Both rows use throwaway store roots, so the benchmark
    never reads from — or pollutes — a developer's ``REPRO_STORE``."""
    import os
    import shutil
    import tempfile

    from repro.cachesim import ArtifactStore, SimConfig, get_trace
    from repro.cachesim.sweep import run_grid

    out = []
    # --- warm-store speedup on the Fig. 3 penalty axis over a 6-cache
    # fleet (the Fig. 7 scale): one sweep + one stacked 2^6-pattern
    # table build cold, pure hydrate + replay warm.  The CI gate (>= 5x)
    # is the acceptance criterion for the store actually paying for
    # itself; locally this lands >= 12x, so the gate has headroom for
    # shared-runner noise -----------------------------------------------
    n_req = 100_000 if full else 50_000
    traces = {"gradle": get_trace("gradle", n_req, seed=0)}
    base = SimConfig(engine="fast", update_interval=200, n_caches=6,
                     costs=(2.0,) * 6)
    policies = ("fna", "fno")

    def _time_grid(store=None):
        t0 = time.time()
        grid = run_grid(traces, base, "miss_penalty", DECISION_PENALTIES,
                        policies=policies, store=store)
        return time.time() - t0, grid

    _time_grid()                                              # warm caches
    dt_cold, grid_cold = min((_time_grid() for _ in range(2)),
                             key=lambda r: r[0])
    root = tempfile.mkdtemp(prefix="repro-bench-store-")
    try:
        store = ArtifactStore(root)
        _time_grid(store)                                     # populate
        dt_warm, grid_warm = min((_time_grid(store) for _ in range(2)),
                                 key=lambda r: r[0])
        assert grid_warm == grid_cold, \
            "store-hydrated grid drifted off cold compute"
        cells = len(DECISION_PENALTIES) * len(policies)
        out.append(("sweep_store_warm_speedup",
                    dt_warm / (n_req * cells) * 1e6, dt_cold / dt_warm,
                    {"n_requests": n_req, "cells": len(DECISION_PENALTIES),
                     "policies": len(policies),
                     "sweep_hits": store.stats["sweep_hits"],
                     "sweep_misses": store.stats["sweep_misses"],
                     "table_hits": store.stats["table_hits"],
                     "table_misses": store.stats["table_misses"]}))
    finally:
        shutil.rmtree(root, ignore_errors=True)

    # --- parallel phase-1 farm: 4 independent system-key groups, serial
    # vs a 4-process spawn pool; every measurement gets a FRESH store so
    # both sides always compute all 4 sweeps ----------------------------
    n_par = 100_000 if full else 50_000
    par_traces = {"gradle": get_trace("gradle", n_par, seed=0)}
    intervals = (100, 200, 400, 800)
    # floor 2 so the spawn-pool path always runs (on a 1-core box the
    # row then records the farm's overhead, which is the honest number)
    workers = max(2, min(4, os.cpu_count() or 1))

    def _time_parallel(w: int):
        root = tempfile.mkdtemp(prefix="repro-bench-par-")
        try:
            t0 = time.time()
            grid = run_grid(par_traces, base, "update_interval", intervals,
                            policies=policies, store=ArtifactStore(root),
                            workers=w)
            return time.time() - t0, grid
        finally:
            shutil.rmtree(root, ignore_errors=True)

    dt_ser, grid_ser = min((_time_parallel(0) for _ in range(2)),
                           key=lambda r: r[0])
    dt_par, grid_par = min((_time_parallel(workers) for _ in range(2)),
                           key=lambda r: r[0])
    assert grid_par == grid_ser, "parallel grid drifted off serial"
    out.append(("sweep_parallel_speedup",
                dt_par / (n_par * len(intervals)) * 1e6, dt_ser / dt_par,
                {"n_requests": n_par, "groups": len(intervals),
                 "workers": workers}))
    return out


def run_topology_benches(full: bool):
    """Hierarchical-topology rows (section ``sim_topology``); see the
    module docstring."""
    from repro.cachesim import SimConfig, get_trace
    from repro.cachesim.topology import TopoConfig, run_topo_grid, run_topology

    out = []
    n_req = 100_000 if full else 40_000
    traces = {"gradle": get_trace("gradle", n_req, seed=0)}
    base = TopoConfig(
        base=SimConfig(engine="fast", update_interval=200),
        kind="tree", depth=3, fanout=2,
        tiers=(dict(cache_size=2_000, update_interval=100,
                    tier_latency=1.0),
               dict(cache_size=6_000, update_interval=200,
                    tier_latency=4.0),
               dict(cache_size=12_000, update_interval=400,
                    tier_latency=16.0)),
        origin_latency=64.0)

    # --- tree throughput: one 3-level fanout-2 cell, full policy panel
    policies = ("fna", "fna_cal", "fno", "pi")
    t0 = time.time()
    run_topology(traces["gradle"], base, policies)   # warm caches
    t0 = time.time()
    run_topology(traces["gradle"], base, policies)
    dt = time.time() - t0
    out.append(("sim_topology_tree", dt / n_req * 1e6, n_req / dt,
                {"n_requests": n_req, "depth": base.depth,
                 "fanout": base.fanout, "policies": len(policies)}))

    # --- cross-cell sweep amortisation: hop_penalty is decision-side
    # (outside every tier's system key), so the shared pool computes the
    # 7 tier sweeps ONCE for the whole axis and replays per cell, while
    # share_system=False recomputes them per cell.  fna + pi keep the
    # replay side cheap so the ratio isolates the sweep sharing
    amort_policies = ("fna", "pi")
    penalties = (0.0, 2.0, 8.0, 32.0)

    def _time_axis(share: bool):
        t0 = time.time()
        grid = run_topo_grid(traces, base, "hop_penalty", penalties,
                             policies=amort_policies, share_system=share)
        return time.time() - t0, grid

    _time_axis(True)                                 # warm caches
    dt_shared, grid_shared = min((_time_axis(True) for _ in range(2)),
                                 key=lambda r: r[0])
    dt_cold, grid_cold = min((_time_axis(False) for _ in range(2)),
                             key=lambda r: r[0])
    assert grid_shared == grid_cold, \
        "shared-pool topology grid drifted off per-cell recompute"
    out.append(("topology_sweep_amortisation",
                dt_shared / (n_req * len(penalties)) * 1e6,
                dt_cold / dt_shared,
                {"n_requests": n_req, "cells": len(penalties),
                 "policies": len(amort_policies), "depth": base.depth,
                 "fanout": base.fanout}))
    return out
