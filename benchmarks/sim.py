"""Simulator throughput benchmarks: requests/sec per policy x trace on the
fast engine, plus the headline fast-vs-reference comparison
(``sim_throughput_*`` / ``sim_speedup_fna_gradle``).

CSV columns: us_per_call = wall-clock per simulated request; derived =
requests/sec (or the speedup factor for the ``sim_speedup`` row).
"""
from __future__ import annotations

import time

HEADLINE_REQUESTS = 200_000      # the acceptance benchmark (gradle, fna)
POLICIES = ("fna", "fno", "pi", "hocs")


def _run_once(cfg, trace):
    from repro.cachesim import Simulator
    t0 = time.time()
    Simulator(cfg).run(trace)
    return time.time() - t0


def run_sim_benches(full: bool):
    from repro.cachesim import SimConfig, get_trace
    from repro.cachesim.traces import TRACES

    out = []
    # --- headline: fast vs reference, 200k-request gradle trace, fna ----
    trace = get_trace("gradle", HEADLINE_REQUESTS, seed=0)
    fast_cfg = SimConfig(engine="fast")
    _run_once(fast_cfg, trace)       # warm numpy/XLA caches
    dt_fast = min(_run_once(fast_cfg, trace) for _ in range(2))
    n_ref = HEADLINE_REQUESTS if full else HEADLINE_REQUESTS // 5
    dt_ref = _run_once(SimConfig(engine="reference"), trace[:n_ref])
    rps_fast = HEADLINE_REQUESTS / dt_fast
    rps_ref = n_ref / dt_ref
    out.append(("sim_throughput_fast_fna_gradle",
                dt_fast / HEADLINE_REQUESTS * 1e6, rps_fast))
    out.append(("sim_throughput_ref_fna_gradle",
                dt_ref / n_ref * 1e6, rps_ref))
    out.append(("sim_speedup_fna_gradle",
                dt_fast / HEADLINE_REQUESTS * 1e6, rps_fast / rps_ref))

    # --- requests/sec per policy x trace (fast engine) ------------------
    n_req = 100_000 if full else 30_000
    for trace_name in TRACES:
        tr = get_trace(trace_name, n_req, seed=0)
        for policy in POLICIES:
            costs = (2.0, 2.0, 2.0) if policy == "hocs" else (1.0, 2.0, 3.0)
            cfg = SimConfig(policy=policy, costs=costs, engine="fast")
            dt = _run_once(cfg, tr)
            out.append((f"sim_{policy}_{trace_name}", dt / n_req * 1e6,
                        n_req / dt))
    return out
