"""Simulator throughput benchmarks: requests/sec per policy x trace on the
fast engine, the headline fast-vs-reference comparisons
(``sim_speedup_fna_gradle``, ``sim_speedup_fna_cal_gradle``), and the
shared-SystemTrace amortisation rows: multi-policy runs
(``sweep_amortisation``) and decision-side grid cells
(``sweep_amortisation_decision`` — the Fig. 3 miss-penalty axis as one
sweep + stacked tables + replays vs per-cell full runs).

CSV columns: us_per_call = wall-clock per simulated request; derived =
requests/sec (or the speedup/amortisation factor for the ``sim_speedup`` /
``sweep_amortisation*`` rows).
"""
from __future__ import annotations

import time

HEADLINE_REQUESTS = 200_000      # the acceptance benchmark (gradle)
POLICIES = ("fna", "fno", "pi", "hocs", "fna_cal")
SWEEP_POLICIES = POLICIES
#: the decision-axis amortisation grid (miss_penalty is decision-side:
#: every cell shares one SystemTrace per trace)
DECISION_PENALTIES = (25.0, 50.0, 75.0, 100.0, 150.0, 250.0, 500.0, 1000.0)
DECISION_POLICIES = ("fna", "fno", "pi")


def _run_once(cfg, trace):
    from repro.cachesim import Simulator
    t0 = time.time()
    Simulator(cfg).run(trace)
    return time.time() - t0


def run_sim_benches(full: bool):
    from repro.cachesim import SimConfig, get_trace
    from repro.cachesim.simulator import run_policies
    from repro.cachesim.traces import TRACES

    out = []
    # --- headline: fast vs reference, 200k-request gradle trace ---------
    # (fna exercises the table replay, fna_cal the speculative segmented
    # replay — the acceptance thresholds track both)
    trace = get_trace("gradle", HEADLINE_REQUESTS, seed=0)
    n_ref = HEADLINE_REQUESTS if full else HEADLINE_REQUESTS // 5
    for policy in ("fna", "fna_cal"):
        fast_cfg = SimConfig(engine="fast", policy=policy)
        _run_once(fast_cfg, trace)       # warm numpy/XLA caches
        dt_fast = min(_run_once(fast_cfg, trace) for _ in range(2))
        dt_ref = _run_once(
            SimConfig(engine="reference", policy=policy), trace[:n_ref])
        rps_fast = HEADLINE_REQUESTS / dt_fast
        rps_ref = n_ref / dt_ref
        out.append((f"sim_throughput_fast_{policy}_gradle",
                    dt_fast / HEADLINE_REQUESTS * 1e6, rps_fast))
        out.append((f"sim_throughput_ref_{policy}_gradle",
                    dt_ref / n_ref * 1e6, rps_ref))
        out.append((f"sim_speedup_{policy}_gradle",
                    dt_fast / HEADLINE_REQUESTS * 1e6, rps_fast / rps_ref))

    # --- shared-SystemTrace amortisation: 1 sweep + P replays vs P full
    # runs over the same (trace, system config); min-of-2 on both sides
    # like the headline rows, so a load spike can't skew the ratio --------
    n_amort = HEADLINE_REQUESTS if full else 150_000
    tr = get_trace("gradle", n_amort, seed=0)
    base = SimConfig(engine="fast", costs=(2.0, 2.0, 2.0))
    run_policies(tr, base, policies=SWEEP_POLICIES)          # warm

    def _time_policies(**kw):
        t0 = time.time()
        run_policies(tr, base, policies=SWEEP_POLICIES, **kw)
        return time.time() - t0

    dt_shared = min(_time_policies() for _ in range(2))
    dt_indep = min(_time_policies(share_system=False) for _ in range(2))
    out.append(("sweep_amortisation",
                dt_shared / (n_amort * len(SWEEP_POLICIES)) * 1e6,
                dt_indep / dt_shared))

    # --- decision-side cross-cell sharing: a miss-penalty grid (the
    # Fig. 3 axis) computes ONE SystemTrace for all its cells and stacks
    # the ds_pgm tables into one batched call, vs per-cell full runs ----
    from repro.cachesim.sweep import run_grid
    n_dec = 100_000 if full else 50_000
    grid_traces = {"gradle": get_trace("gradle", n_dec, seed=0)}
    dec_base = SimConfig(engine="fast", update_interval=200)

    def _time_grid(shared: bool) -> float:
        t0 = time.time()
        run_grid(grid_traces, dec_base, "miss_penalty", DECISION_PENALTIES,
                 policies=DECISION_POLICIES, share_system=shared)
        return time.time() - t0

    _time_grid(True)                                         # warm
    dt_dec_shared = min(_time_grid(True) for _ in range(2))
    dt_dec_indep = min(_time_grid(False) for _ in range(2))
    cells = len(DECISION_PENALTIES) * len(DECISION_POLICIES)
    out.append(("sweep_amortisation_decision",
                dt_dec_shared / (n_dec * cells) * 1e6,
                dt_dec_indep / dt_dec_shared))

    # --- requests/sec per policy x trace (fast engine) ------------------
    n_req = 100_000 if full else 30_000
    for trace_name in TRACES:
        tr = get_trace(trace_name, n_req, seed=0)
        for policy in POLICIES:
            costs = (2.0, 2.0, 2.0) if policy == "hocs" else (1.0, 2.0, 3.0)
            cfg = SimConfig(policy=policy, costs=costs, engine="fast")
            dt = _run_once(cfg, tr)
            out.append((f"sim_{policy}_{trace_name}", dt / n_req * 1e6,
                        n_req / dt))
    return out
