"""Benchmark harness: one entry per paper figure + framework micro-benches.

Prints ``name,us_per_call,derived`` CSV (one line per benchmark):
  * paper figures:  us_per_call = simulated-request latency; derived =
    the figure's headline scalar (see benchmarks/paper_figs.py).
  * router/kernel micro-benches: us_per_call = wall-clock per call on this
    host; derived = the relevant throughput/quality scalar.

``python -m benchmarks.run [--full] [--only section[,section...]]
[--interpret auto|on|off] [--json PATH]``

``--json`` additionally writes every record as a JSON list of
``{"name", "us_per_call", "derived"}`` objects (plus any per-row context
fields a section attaches — table row counts, device counts) — the CI
bench-smoke job
uploads it as the ``BENCH_sim.json`` artifact so the perf trajectory
accumulates per commit, and gates on the headline speedups.

Every JSON record also carries ``ru_maxrss`` — the harness process's
peak RSS in KB (``getrusage(RUSAGE_SELF)``, Linux semantics) sampled
right after the row ran.  It is a process HIGH-WATER mark, monotone
across rows within one run; rows that need per-path isolation (the
``sim_ingest`` section) measure in child processes and report their own
numbers in the extras.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

try:
    import resource
except ImportError:                      # non-POSIX host
    resource = None


def _ru_maxrss() -> int:
    """Peak RSS of this process in KB (0 where getrusage is missing)."""
    if resource is None:
        return 0
    return int(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss)


def paper_fig_benches(full: bool):
    from benchmarks.paper_figs import FIGS, _scale, run_fig

    out = []
    for name in FIGS:
        rows, derived, dt = run_fig(name, full)
        reqs = _scale(full)[0] * max(len(rows), 1)
        us = dt / max(reqs, 1) * 1e6
        out.append((name, us, derived))
    return out


def router_bench(full: bool):
    """Batched FNA router (paper technique on the serving path): wall-clock
    per routed request, JAX jitted on this host — once on a synthetic
    16-cache fleet, once on a scenario-registry configuration
    (``hetero_tiers``: cheap-small/expensive-large tiers) whose (q, FP,
    FN) views and indication patterns come from a short simulator run."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.batched import cs_fna_batched

    def _time_router(costs, q, fp, fn, ind, miss_penalty):
        f = jax.jit(lambda i: cs_fna_batched(i, costs, q, fp, fn,
                                             miss_penalty))
        f(ind).block_until_ready()
        iters = 50 if full else 20
        t0 = time.time()
        for _ in range(iters):
            f(ind).block_until_ready()
        dt = (time.time() - t0) / iters
        return dt / ind.shape[0] * 1e6, float(np.asarray(f(ind)).mean())

    out = []
    n, b = 16, 4096
    rng = np.random.default_rng(0)
    us, mean = _time_router(
        jnp.asarray(rng.uniform(1, 3, n), jnp.float32),
        jnp.asarray(rng.uniform(0.2, 0.8, n), jnp.float32),
        jnp.asarray(rng.uniform(0.001, 0.05, n), jnp.float32),
        jnp.asarray(rng.uniform(0.0, 0.4, n), jnp.float32),
        jnp.asarray(rng.random((b, n)) < 0.3, jnp.int32), 100.0)
    out.append(("router_cs_fna_batched", us, mean))

    # registry-defined heterogeneous regime (scenario hetero_tiers): the
    # router's views are the END-OF-RUN estimates of a short fast-engine
    # run, its request batch the run's actual indication patterns.  The
    # stale-advertisement grid cell (update_interval=512, 20k requests)
    # is the paper's FN-heavy regime — the views are informative and the
    # router genuinely trades positive vs negative accesses (a fresher
    # cell degenerates to all-empty selections)
    from repro.cachesim import Simulator, get_scenario, get_trace
    sc = get_scenario("hetero_tiers")
    cfg = sc.config(policy="fna", update_interval=512)
    trace = get_trace(sc.traces[0], 20_000, seed=sc.seed)
    sim = Simulator(cfg)
    sim.run(trace)
    st = sim.last_system
    us, mean = _time_router(
        jnp.asarray(cfg.costs, jnp.float32),
        jnp.asarray([s["q"] for s in st.final_state["q"]], jnp.float32),
        jnp.asarray(st.fp_v[-1], jnp.float32),
        jnp.asarray(st.fn_v[-1], jnp.float32),
        jnp.asarray(st.ind_all[-b:].astype(np.int32)),
        cfg.miss_penalty)
    out.append(("router_cs_fna_hetero_tiers", us, mean))
    return out


def kernel_benches(full: bool, interpret=None):
    out = []
    try:
        from benchmarks.kernels import run_kernel_benches
        out.extend(run_kernel_benches(full, interpret=interpret))
    except ImportError:
        pass
    return out


def sim_benches(full: bool):
    """Trace-simulator throughput (fast engine per policy x trace, plus the
    fast-vs-reference speedup on the 200k gradle headline)."""
    from benchmarks.sim import run_sim_benches
    return run_sim_benches(full)


def sim_jax_benches(full: bool):
    """JAX/Pallas table-core rows: jitted (and device-sharded) decision
    table builds vs the NumPy mirror on the Fig. 3 grid shape."""
    from benchmarks.sim import run_jax_benches
    return run_jax_benches(full)


def sim_store_benches(full: bool):
    """Artifact-store perf tier: warm-store speedup on the Fig. 3 grid
    (CI-gated >= 5x) and the parallel phase-1 farm speedup (recorded)."""
    from benchmarks.sim import run_store_benches
    return run_store_benches(full)


def sim_advert_benches(full: bool):
    """Advertisement-event subsystem: cost-vs-bandwidth Pareto rows for
    the self-adjusting policy vs a budget-matched fixed cadence (the
    ``advert_bandwidth_pareto`` summary is CI-gated >= 1)."""
    from benchmarks.sim import run_advert_benches
    return run_advert_benches(full)


def sim_topology_benches(full: bool):
    """Hierarchical topologies (``repro.cachesim.topology``): 3-level
    tree throughput on the Fig. 3 workload plus the
    ``topology_sweep_amortisation`` ratio — shared per-tier sweeps vs
    per-cell recompute across a topology axis (CI-gated >= 2x)."""
    from benchmarks.sim import run_topology_benches
    return run_topology_benches(full)


def sim_ingest_benches(full: bool):
    """Streaming trace ingestion: 10M-request log generation, one-shot vs
    streaming statistics in isolated child processes, and the
    ``ingest_peak_rss_ratio`` row (CI-gated <= 0.5)."""
    from benchmarks.sim import run_ingest_benches
    return run_ingest_benches(full)


def serving_bench(full: bool):
    out = []
    try:
        from benchmarks.serving import run_serving_bench
        out.extend(run_serving_bench(full))
    except ImportError:
        pass
    return out


def router_replay_bench(full: bool):
    """Concurrent-client router replay: throughput + p50/p99 decision
    latency per scenario-defined regime, plus a batch-size sweep."""
    from benchmarks.serving import run_replay_benches
    return run_replay_benches(full)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale parameters")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of sections to run")
    ap.add_argument("--interpret", choices=("auto", "on", "off"), default="auto",
                    help="Pallas interpret mode for kernel benches "
                         "(auto = from JAX backend: compiled on TPU)")
    ap.add_argument("--json", default="",
                    help="also write records to this path as JSON")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None
    interpret = {"auto": None, "on": True, "off": False}[args.interpret]

    sections = {
        "paper": paper_fig_benches,
        "router": router_bench,
        "kernels": lambda full: kernel_benches(full, interpret=interpret),
        "sim": sim_benches,
        "sim_jax": sim_jax_benches,
        "sim_store": sim_store_benches,
        "sim_advert": sim_advert_benches,
        "sim_topology": sim_topology_benches,
        "sim_ingest": sim_ingest_benches,
        "serving": serving_bench,
        "router_replay": router_replay_bench,
    }
    if only:
        unknown = sorted(only - set(sections))
        if unknown:
            # a typo'd --only used to run NOTHING and exit 0 — fail loudly
            ap.error(f"unknown --only section(s): {', '.join(unknown)} "
                     f"(valid: {', '.join(sections)})")
    records = []
    print("name,us_per_call,derived")
    for sec, fn in sections.items():
        if only and sec not in only:
            continue
        # rows are (name, us, derived[, extras]); extras is an optional
        # dict of context fields (row counts, device counts, ...) merged
        # into the JSON record — the CSV stays 3 columns
        for name, us, derived, *rest in fn(args.full):
            print(f"{name},{us:.3f},{derived:.6g}")
            sys.stdout.flush()
            rec = {"name": name, "us_per_call": us,
                   "derived": float(derived), "ru_maxrss": _ru_maxrss()}
            if rest:
                rec.update(rest[0])
            records.append(rec)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1)


if __name__ == "__main__":
    main()
