"""Roofline report generator: reads artifacts/dryrun/*.json, emits the
EXPERIMENTS.md tables (and a machine-readable summary).

  PYTHONPATH=src python -m benchmarks.roofline [--mesh single] [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
from pathlib import Path

ART = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


def load(mesh: str):
    rows = []
    for p in sorted(glob.glob(str(ART / f"*__{mesh}.json"))):
        d = json.load(open(p))
        rows.append(d)
    return rows


def fmt_table(rows, md: bool = False):
    hdr = ["arch", "shape", "t_compute(s)", "t_memory(s)", "t_collective(s)",
           "bottleneck", "useful_flops", "roofline_mfu", "temp_GB/dev"]
    out = []
    if md:
        out.append("| " + " | ".join(hdr) + " |")
        out.append("|" + "---|" * len(hdr))
    else:
        out.append(f"{'arch':22s} {'shape':12s} {'t_comp':>9s} {'t_mem':>9s} "
                   f"{'t_coll':>9s} {'bneck':>10s} {'useful':>7s} {'mfu':>7s} {'tmpGB':>6s}")
    for d in rows:
        r = d["roofline"]
        temp = d["memory_analysis"].get("temp_size_in_bytes", 0) / 1e9
        vals = [d["arch"], d["shape"], f"{r['t_compute_s']:.4f}",
                f"{r['t_memory_s']:.4f}", f"{r['t_collective_s']:.4f}",
                r["bottleneck"], f"{r['useful_flops_ratio']:.3f}",
                f"{r['mfu_at_roofline']:.4f}", f"{temp:.1f}"]
        if md:
            out.append("| " + " | ".join(vals) + " |")
        else:
            out.append(f"{vals[0]:22s} {vals[1]:12s} {vals[2]:>9s} {vals[3]:>9s} "
                       f"{vals[4]:>9s} {vals[5]:>10s} {vals[6]:>7s} {vals[7]:>7s} {vals[8]:>6s}")
    return "\n".join(out)


def dominant_summary(rows):
    from collections import Counter
    c = Counter(d["roofline"]["bottleneck"] for d in rows)
    return dict(c)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    rows = load(args.mesh)
    print(fmt_table(rows, md=args.md))
    print()
    print("bottleneck histogram:", dominant_summary(rows))


if __name__ == "__main__":
    main()
