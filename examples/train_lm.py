"""Train an assigned-architecture LM on the synthetic pipeline.

Default (CPU-friendly): reduced SmolLM, 200 steps, loss visibly dropping.
The REAL 135M configuration is one flag away (omit --reduced) and the same
entry point scales to the production mesh via repro.launch.train --mesh.

  PYTHONPATH=src python examples/train_lm.py [--arch smollm-135m] [--steps 200]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full-size", action="store_true",
                    help="use the real config (hours on CPU; meant for pods)")
    args = ap.parse_args()
    argv = ["--arch", args.arch, "--steps", str(args.steps),
            "--batch", "8", "--seq", "128", "--lr", "3e-3",
            "--ckpt-dir", "/tmp/repro_train_lm", "--ckpt-interval", "100"]
    if not args.full_size:
        argv.append("--reduced")
    sys.exit(train_main(argv))


if __name__ == "__main__":
    main()
