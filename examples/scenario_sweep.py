"""Run a named scenario and print its policy grid as a table.

The scenario registry (``repro.cachesim.scenarios``) names the paper's
figure setups plus heterogeneous beyond-paper regimes; this example runs
one of them at a small scale and tabulates mean service cost per
(trace, cell, policy) — the quickest way to eyeball a new regime before
promoting it to the figure pipeline (``benchmarks/paper_figs.py``).

    PYTHONPATH=src python examples/scenario_sweep.py [scenario] [n_requests]

Defaults: ``hetero_tiers`` (cheap-small vs expensive-large cache tiers)
at 20k requests.
"""
import sys
from pathlib import Path

_REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_REPO))          # benchmarks.* (figure pipeline)
sys.path.insert(0, str(_REPO / "src"))  # repro.*

from repro.cachesim import get_scenario, run_scenario  # noqa: E402
from benchmarks.paper_figs import pivot_cells, normalised  # noqa: E402


def main() -> None:
    name = sys.argv[1] if len(sys.argv) > 1 else "hetero_tiers"
    n_req = int(sys.argv[2]) if len(sys.argv) > 2 else 20_000
    sc = get_scenario(name)
    print(f"scenario {sc.name} ({sc.figure}): {sc.description}\n"
          f"axis={sc.axis}  traces={','.join(sc.traces)}  "
          f"n_requests={n_req}\n")
    records = run_scenario(sc, n_requests=n_req)
    cells = pivot_cells(records, sc.axis)
    policies = [p for p in sc.policies]
    head = f"{'trace':>8s} {sc.axis:>18s}" + "".join(
        f" {p:>9s}" for p in policies) + "   (cost / PI-normalised)"
    print(head)
    print("-" * len(head))
    for cell in cells:
        norm = normalised(cell)
        row = f"{cell['trace']:>8s} {str(cell[sc.axis]):>18s}"
        for p in policies:
            row += f" {cell['cost'][p]:9.3f}"
        row += "   " + " ".join(f"{p}={norm[p]:.2f}" for p in policies
                                if p != "pi")
        print(row)


if __name__ == "__main__":
    main()
