"""END-TO-END DRIVER (serving): FNA-routed distributed prefix-KV cache.

  PYTHONPATH=src python examples/serve_prefix_cache.py [--requests 300]

A reduced SmolLM serves batched requests.  Prompts share prefixes (system
prompts / few-shot headers) whose prefill KV caches live on 4 cache nodes
advertising stale Bloom indicators.  The router decides which nodes to
probe with the paper's false-negative-aware policy; misses pay REAL
prefill compute on this host.  We report service cost AND wall-clock for
FNA vs FNO routing.
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.cachesim.traces import recency_trace
from repro.configs import get_config
from repro.serving import ClusterConfig, PrefixServeCluster, ServeEngine

PREFIX_LEN = 24
DECODE_STEPS = 4


def run(policy: str, n_requests: int, engine: ServeEngine, prefixes, stream):
    cfg = ClusterConfig(n_nodes=4, node_capacity=64, update_interval=32,
                        miss_penalty=40.0, policy=policy)
    cluster = PrefixServeCluster(cfg, seed=1)
    t0 = time.time()
    prefill_s = 0.0
    for i in range(n_requests):
        pid = int(stream[i])
        tokens = prefixes[pid % len(prefixes)]

        def make_kv():
            nonlocal prefill_s
            t1 = time.time()
            _, cache = engine.prefill(tokens, max_len=PREFIX_LEN + DECODE_STEPS + 2)
            prefill_s += time.time() - t1
            return cache

        kv, cost = cluster.request(pid, make_kv=make_kv)
        # decode a few tokens from the (hit or freshly built) prefix KV
        first = jnp.zeros((tokens.shape[0],), jnp.int32)
        engine.decode(kv, first, DECODE_STEPS)
    wall = time.time() - t0
    return cluster.stats, wall, prefill_s


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=200)
    args = ap.parse_args()

    cfg = get_config("smollm-135m").reduced()
    engine = ServeEngine(cfg)
    rng = np.random.default_rng(0)
    prefixes = [rng.integers(0, cfg.vocab, (1, PREFIX_LEN)).astype(np.int32)
                for _ in range(256)]
    stream = recency_trace(args.requests, p_new=0.15, window=96, seed=2)

    print(f"{args.requests} requests, reduced {cfg.name}, 4 cache nodes, "
          f"update interval 32 insertions\n")
    print("policy    mean-cost  hit-ratio  prefills  neg-probes  wall-s  prefill-s")
    for policy in ("fno", "fna", "fna_cal", "pi"):
        stats, wall, prefill_s = run(policy, args.requests, engine, prefixes, stream)
        print(f"{policy:9s} {stats.mean_cost:8.2f} {stats.hit_ratio:9.3f} "
              f"{stats.prefills:9d} {stats.neg_probes:10d} {wall:7.1f} {prefill_s:8.1f}")
    print("\nLower mean-cost == fewer prefill recomputes for the same "
          "indicator bandwidth (the paper's claim, on a live serving path).")


if __name__ == "__main__":
    main()
