"""Quickstart: the paper's FNA cache selection in 60 seconds.

  PYTHONPATH=src python examples/quickstart.py

1. Builds a 3-cache system with stale Bloom-filter indicators.
2. Replays a recency-biased trace (the staleness-hostile regime).
3. Compares the paper's CS_FNA, our calibrated FNA, the FNO baseline,
   and the perfect-information lower bound.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.cachesim import SimConfig, get_trace
from repro.cachesim.simulator import run_policies


def main():
    trace = get_trace("gradle", 40_000, seed=0)
    base = SimConfig(n_caches=3, cache_size=2_000, costs=(1.0, 2.0, 3.0),
                     miss_penalty=100.0, bpe=14.0, update_interval=512)
    print("policy      mean-cost   vs-PI   hit-ratio   negative-accesses")
    res = run_policies(trace, base, policies=("pi", "fno", "fna", "fna_cal"))
    pi_cost = res["pi"].mean_cost
    for name in ("pi", "fno", "fna", "fna_cal"):
        r = res[name]
        print(f"{name:10s} {r.mean_cost:9.3f} {r.mean_cost / pi_cost:7.3f}"
              f" {r.hit_ratio:10.3f} {r.neg_accesses:15d}")
    print("\nfna  = the paper's Algorithm 2 (Eqs. 7-9 estimation)")
    print("fna_cal = + empirical exclusion-probability feedback (ours)")


if __name__ == "__main__":
    main()
