"""Fault-tolerance drill: preemption -> checkpoint -> elastic restart.

  PYTHONPATH=src python examples/elastic_restart.py

1. Trains a reduced model, killing it (SIGTERM semantics) at step 12.
2. Restarts from the atomic checkpoint and finishes.
3. Verifies the final loss equals an uninterrupted run bit-for-bit
   (the data pipeline is a pure function of the step counter).
"""
import subprocess
import sys
import tempfile
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
ENV = {"PYTHONPATH": "src"}


def run(args, check=True):
    import os
    env = dict(os.environ, **ENV)
    r = subprocess.run([sys.executable, "-m", "repro.launch.train", *args],
                       cwd=ROOT, env=env, capture_output=True, text=True)
    if check and r.returncode not in (0, 42):
        print(r.stdout, r.stderr)
        raise SystemExit(1)
    return r


def final_loss(stdout: str) -> float:
    for line in reversed(stdout.splitlines()):
        if "final loss" in line:
            return float(line.rsplit(" ", 1)[-1])
    raise ValueError("no final loss in output")


def main():
    common = ["--arch", "smollm-135m", "--reduced", "--steps", "25",
              "--batch", "4", "--seq", "64", "--ckpt-interval", "5"]
    with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
        print("== uninterrupted run ==")
        r_ref = run(common + ["--ckpt-dir", d1])
        ref = final_loss(r_ref.stdout)
        print(f"   final loss {ref}")

        print("== preempted at step 12 ==")
        r1 = run(common + ["--ckpt-dir", d2, "--kill-at", "12"])
        assert r1.returncode == 42, r1.returncode
        print("   exit 42 (checkpointed)")

        print("== elastic restart ==")
        r2 = run(common + ["--ckpt-dir", d2, "--resume"])
        got = final_loss(r2.stdout)
        print(f"   final loss {got}")
        assert got == ref, (got, ref)
        print("OK: restart is bit-exact")


if __name__ == "__main__":
    main()
