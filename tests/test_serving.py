"""Serving-layer tests: engine decode and the FNA prefix-cache router."""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.serving import ClusterConfig, PrefixServeCluster, ServeEngine
from repro.cachesim.traces import recency_trace, zipf_trace


def _drive(cluster: PrefixServeCluster, stream):
    for p in stream:
        cluster.request(int(p))
    return cluster.stats


def _prefix_stream(n=6000, seed=0):
    """Prefix popularity: churning working set (new system prompts appear,
    get reused heavily for a while, fade) — the staleness-hostile regime."""
    return recency_trace(n, p_new=0.2, window=512, seed=seed)


def test_fna_router_beats_fno_under_staleness():
    base = ClusterConfig(n_nodes=4, node_capacity=256, update_interval=128)
    stream = _prefix_stream()
    res = {}
    for policy in ("fna", "fno", "pi"):
        cluster = PrefixServeCluster(dataclasses.replace(base, policy=policy))
        res[policy] = _drive(cluster, stream)
    assert res["pi"].mean_cost <= res["fna"].mean_cost + 1e-9
    assert res["fna"].mean_cost < res["fno"].mean_cost, (
        res["fna"].to_dict(), res["fno"].to_dict())
    assert res["fna"].neg_probes > 0  # it actually uses negative accesses


def test_router_hit_ratio_reasonable():
    cfg = ClusterConfig(n_nodes=4, node_capacity=512, update_interval=32,
                        policy="fna")
    cluster = PrefixServeCluster(cfg)
    stats = _drive(cluster, _prefix_stream())
    assert stats.hit_ratio > 0.3
    assert stats.requests == 6000


def test_engine_decode_shapes():
    cfg = get_config("smollm-135m").reduced()
    eng = ServeEngine(cfg)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (2, 16))
    logits, cache = eng.prefill(prompts, max_len=24)
    assert logits.shape == (2, cfg.vocab_padded)
    first = np.argmax(np.asarray(logits)[:, :cfg.vocab], axis=-1).astype(np.int32)
    import jax.numpy as jnp
    toks, cache = eng.decode(cache, jnp.asarray(first), n_steps=4)
    assert toks.shape == (2, 4)
    assert (toks >= 0).all() and (toks < cfg.vocab).all()


def test_engine_prefix_reuse_consistency():
    """Decoding from a cached prefill KV == decoding after re-prefilling."""
    cfg = get_config("smollm-135m").reduced()
    eng = ServeEngine(cfg)
    prompts = np.random.default_rng(1).integers(0, cfg.vocab, (1, 12))
    import jax
    import jax.numpy as jnp
    logits1, cache1 = eng.prefill(prompts, max_len=20)
    logits2, cache2 = eng.prefill(prompts, max_len=20)
    first = jnp.argmax(logits1[:, :cfg.vocab], axis=-1).astype(jnp.int32)
    t1, _ = eng.decode(jax.tree.map(lambda a: a, cache1), first, 4)
    t2, _ = eng.decode(cache2, first, 4)
    np.testing.assert_array_equal(t1, t2)
