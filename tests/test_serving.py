"""Serving-layer tests: engine decode and the FNA prefix-cache router."""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.serving import ClusterConfig, PrefixServeCluster, ServeEngine
from repro.cachesim.traces import recency_trace, zipf_trace


def _drive(cluster: PrefixServeCluster, stream):
    for p in stream:
        cluster.request(int(p))
    return cluster.stats


def _prefix_stream(n=6000, seed=0):
    """Prefix popularity: churning working set (new system prompts appear,
    get reused heavily for a while, fade) — the staleness-hostile regime."""
    return recency_trace(n, p_new=0.2, window=512, seed=seed)


def test_fna_router_beats_fno_under_staleness():
    base = ClusterConfig(n_nodes=4, node_capacity=256, update_interval=128)
    stream = _prefix_stream()
    res = {}
    for policy in ("fna", "fno", "pi"):
        cluster = PrefixServeCluster(dataclasses.replace(base, policy=policy))
        res[policy] = _drive(cluster, stream)
    assert res["pi"].mean_cost <= res["fna"].mean_cost + 1e-9
    assert res["fna"].mean_cost < res["fno"].mean_cost, (
        res["fna"].to_dict(), res["fno"].to_dict())
    assert res["fna"].neg_probes > 0  # it actually uses negative accesses


def test_router_hit_ratio_reasonable():
    cfg = ClusterConfig(n_nodes=4, node_capacity=512, update_interval=32,
                        policy="fna")
    cluster = PrefixServeCluster(cfg)
    stats = _drive(cluster, _prefix_stream())
    assert stats.hit_ratio > 0.3
    assert stats.requests == 6000


def test_engine_decode_shapes():
    cfg = get_config("smollm-135m").reduced()
    eng = ServeEngine(cfg)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (2, 16))
    logits, cache = eng.prefill(prompts, max_len=24)
    assert logits.shape == (2, cfg.vocab_padded)
    first = np.argmax(np.asarray(logits)[:, :cfg.vocab], axis=-1).astype(np.int32)
    import jax.numpy as jnp
    toks, cache = eng.decode(cache, jnp.asarray(first), n_steps=4)
    assert toks.shape == (2, 4)
    assert (toks >= 0).all() and (toks < cfg.vocab).all()


def test_engine_prefix_reuse_consistency():
    """Decoding from a cached prefill KV == decoding after re-prefilling."""
    cfg = get_config("smollm-135m").reduced()
    eng = ServeEngine(cfg)
    prompts = np.random.default_rng(1).integers(0, cfg.vocab, (1, 12))
    import jax
    import jax.numpy as jnp
    logits1, cache1 = eng.prefill(prompts, max_len=20)
    logits2, cache2 = eng.prefill(prompts, max_len=20)
    first = jnp.argmax(logits1[:, :cfg.vocab], axis=-1).astype(jnp.int32)
    t1, _ = eng.decode(jax.tree.map(lambda a: a, cache1), first, 4)
    t2, _ = eng.decode(cache2, first, 4)
    np.testing.assert_array_equal(t1, t2)


# ---------------------------------------------------------------------------
# Per-node cluster configuration (heterogeneous fleets)
# ---------------------------------------------------------------------------

def test_cluster_config_per_node_broadcast():
    cfg = ClusterConfig(n_nodes=3, node_capacity=128,
                        update_interval=(32, 128, 512), est_interval=8)
    assert cfg.node_capacities == (128, 128, 128)
    assert cfg.update_intervals == (32, 128, 512)
    assert cfg.est_intervals == (8, 8, 8)
    cluster = PrefixServeCluster(cfg)
    assert [nd.update_interval for nd in cluster.nodes] == [32, 128, 512]
    assert [nd.lru.capacity for nd in cluster.nodes] == [128, 128, 128]


def test_cluster_config_per_node_wrong_length():
    cfg = ClusterConfig(n_nodes=3, node_capacity=(64, 192))
    with pytest.raises(ValueError, match="node_capacity"):
        cfg.node_capacities


# ---------------------------------------------------------------------------
# Concurrent-client replay harness
# ---------------------------------------------------------------------------

def test_replay_regimes_cover_scenario_shapes():
    from repro.serving import REGIMES, regime_config
    assert {"hetero_tiers", "staggered_adverts", "delayed_view"} <= set(REGIMES)
    cfg = regime_config("hetero_tiers", policy="fno")
    assert cfg.policy == "fno"
    assert len(set(cfg.node_capacities)) > 1      # genuinely tiered
    with pytest.raises(KeyError):
        regime_config("no_such_regime")


def test_replay_sequential_deterministic():
    """Fixed seed, sequential mode: two runs produce identical routing
    outcomes — costs, hits, probe counts — down to the raw stats."""
    from repro.serving import replay
    kw = dict(policy="fna", n_requests=900, n_clients=3, batch_size=2,
              mode="sequential", seed=5)
    a = replay("staggered_adverts", **kw)
    b = replay("staggered_adverts", **kw)
    assert a.stats == b.stats
    assert a.mean_cost == b.mean_cost
    assert a.hit_ratio == b.hit_ratio
    assert a.requests == b.requests == 900
    assert 0 < a.p50_us <= a.p99_us


def test_replay_threads_aggregate_stats():
    """Threaded clients behind the router lock: arrival order is
    scheduler-dependent but the aggregate accounting must balance."""
    from repro.serving import replay
    r = replay("delayed_view", policy="fna_cal", n_requests=800,
               n_clients=4, batch_size=4, mode="threads", seed=1)
    assert r.requests == r.stats["requests"] == 800
    # every request either hit a probed KV or paid a prefill
    assert round(r.hit_ratio * r.requests) + r.stats["prefills"] == 800
    assert 0.0 <= r.hit_ratio <= 1.0
    assert r.achieved_rps > 0
    assert 0 < r.p50_us <= r.p99_us


def test_replay_batch_sweep_smoke():
    from repro.serving import batch_sweep
    reports = batch_sweep("hetero_tiers", policy="fna",
                          batch_sizes=(1, 4), n_requests=400,
                          n_clients=2, mode="sequential", seed=0)
    assert [r.batch_size for r in reports] == [1, 4]
    # same total load per batch size (fresh cluster each)
    assert len({r.requests for r in reports}) == 1
    for r in reports:
        d = r.to_dict()
        assert d["regime"] == "hetero_tiers"
        assert d["p50_us"] <= d["p99_us"]


def test_replay_validation():
    from repro.serving import replay
    with pytest.raises(ValueError):
        replay("hetero_tiers", batch_size=0)
    with pytest.raises(ValueError):
        replay("hetero_tiers", mode="warp")
    from repro.serving.replay import client_streams
    with pytest.raises(ValueError):
        client_streams(100, 0)


def test_serve_main_replay_argv(tmp_path, capsys):
    """The --replay launcher path end to end (model-free: no engine or
    JAX construction), including the JSON report artifact."""
    from repro.launch.serve import main
    out = tmp_path / "replay.json"
    rc = main(["--replay", "--mode", "sequential", "--regime",
               "delayed_view", "--requests", "300", "--clients", "3",
               "--batch-sizes", "1,2", "--json", str(out)])
    assert rc == 0
    import json
    reports = json.loads(out.read_text())
    assert [r["batch_size"] for r in reports] == [1, 2]
    assert all(r["regime"] == "delayed_view" for r in reports)
    assert "[replay]" in capsys.readouterr().out
