"""Checkpointing: atomic commit, GC, async, restore, resume contract."""
import json
import os
import threading
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore, save
from repro.checkpoint.ckpt import all_steps


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)), "b": jnp.zeros((16,))},
        "m": {"w": jnp.ones((8, 16)), "b": jnp.zeros((16,))},
        "step": jnp.int32(7),
    }


def test_save_restore_roundtrip(tmp_path):
    st = _state()
    save(st, tmp_path, step=7)
    abs_st = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), st)
    got = restore(tmp_path, abs_st)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_no_partial_checkpoints(tmp_path):
    st = _state()
    save(st, tmp_path, step=1)
    # a straggling .tmp dir must be invisible to discovery
    (tmp_path / "step_9.tmp").mkdir()
    assert all_steps(tmp_path) == [1]
    assert latest_step(tmp_path) == 1


def test_gc_keeps_newest(tmp_path):
    st = _state()
    for s in (1, 2, 3, 4, 5):
        save(st, tmp_path, step=s, keep=2)
    assert all_steps(tmp_path) == [4, 5]


def test_async_save(tmp_path):
    st = _state()
    t = save(st, tmp_path, step=3, async_=True)
    assert isinstance(t, threading.Thread)
    t.join(timeout=30)
    assert latest_step(tmp_path) == 3


def test_manager_interval_and_force(tmp_path):
    st = _state()
    mgr = CheckpointManager(str(tmp_path), interval=10, keep=5, async_=False)
    assert not mgr.maybe_save(st, 3)
    assert mgr.maybe_save(st, 10)
    assert mgr.maybe_save(st, 17, force=True)
    mgr.wait()
    assert set(all_steps(tmp_path)) == {10, 17}


def test_restore_dtype_cast(tmp_path):
    """Elastic restore may change precision policy (e.g. bf16 serving)."""
    st = _state()
    save(st, tmp_path, step=1)
    abs_st = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, jnp.bfloat16
                                       if a.dtype == jnp.float32 else a.dtype), st)
    got = restore(tmp_path, abs_st)
    assert got["params"]["w"].dtype == jnp.bfloat16
