"""Calibrated-policy fast engine + shared-SystemTrace tests.

The speculative segmented replay (``repro.cachesim.fna_cal_fast``) must
be a BIT-EXACT twin of the reference scalar loop for ``fna_cal`` across
workloads and calibration settings, and ``run_policies`` must compute the
policy-independent system sweep exactly once while leaving every result
unchanged.
"""
import dataclasses

import numpy as np
import pytest

from repro.cachesim import SimConfig, Simulator, SystemTrace, get_trace
from repro.cachesim.simulator import run_policies
from repro.cachesim.sweep import run_sweep, sweep_records
from repro.cachesim.traces import TRACES
import repro.cachesim.systemstate as systemstate

N = 8_000
ALL_POLICIES = ("fna", "fno", "pi", "hocs", "fna_cal")


def _assert_results_identical(ref, fast):
    assert fast.to_dict() == ref.to_dict()
    assert fast.total_cost == ref.total_cost
    for f in ("n_requests", "hits", "pos_accesses", "neg_accesses",
              "fn_events", "fn_opportunities", "fp_events",
              "fp_opportunities", "resident"):
        assert getattr(fast, f) == getattr(ref, f), f


def _run_pair(trace, **cfg_kw):
    base = SimConfig(cache_size=1_000, policy="fna_cal", **cfg_kw)
    ref = Simulator(dataclasses.replace(base, engine="reference")).run(trace)
    fast = Simulator(dataclasses.replace(base, engine="fast")).run(trace)
    return ref, fast


@pytest.mark.parametrize("trace_name", TRACES)
def test_fna_cal_fast_reference_parity(trace_name):
    trace = get_trace(trace_name, N, seed=7)
    ref, fast = _run_pair(trace, update_interval=200, est_interval=25)
    _assert_results_identical(ref, fast)


@pytest.mark.parametrize("trace_name", ("gradle", "wiki"))
@pytest.mark.parametrize("cfg_kw", [
    dict(update_interval=1_000, est_interval=50, cal_epsilon=0.005),
    dict(update_interval=64, est_interval=16, cal_epsilon=0.05,
         cal_min_obs=5),
    dict(update_interval=200, est_interval=25, cal_epsilon=0.0,
         cal_min_obs=1_000_000),   # pure-model blend: never leaves warmup
])
def test_fna_cal_parity_across_settings(trace_name, cfg_kw):
    """Exactness must hold from fresh to very stale indicators, across
    exploration rates, and in both blend regimes (the all-empirical steady
    state AND the model-blended warmup that never ends)."""
    trace = get_trace(trace_name, N, seed=3)
    ref, fast = _run_pair(trace, **cfg_kw)
    _assert_results_identical(ref, fast)


@pytest.mark.parametrize("n_caches", (3, 4))
def test_fna_cal_exhaustive_runs_fast_engine(n_caches):
    """The segmented engine's verification pass now has an exhaustive
    twin (the batched 2^n-subset enumeration), so ``alg="exhaustive"``
    runs the fast engine for n <= 8 — bit-exactly."""
    trace = get_trace("gradle", 3_000, seed=2)
    base = SimConfig(n_caches=n_caches, cache_size=1_000, policy="fna_cal",
                     alg="exhaustive", update_interval=200)
    ref = Simulator(dataclasses.replace(base, engine="reference")).run(trace)
    sim = Simulator(dataclasses.replace(base, engine="fast"))
    fast = sim.run(trace)
    _assert_results_identical(ref, fast)
    # the speculative replay really ran: the shared artifact is published
    assert isinstance(sim.last_system, SystemTrace)


def test_fna_cal_exhaustive_many_caches_stays_fast():
    """The chunked subset DP raised the exhaustive budget to the full
    table cap (n <= 12): a 9-cache calibrated+exhaustive run — which used
    to fall back to the reference loop — now runs the segmented fast path
    with identical results and a shared SystemTrace artifact.  (Past the
    cap, n > 12 still dispatches to None — pinned in
    ``tests/test_engine_providers.py::test_registry_dispatch``.)"""
    trace = get_trace("gradle", 1_500, seed=2)
    base = SimConfig(n_caches=9, cache_size=200, policy="fna_cal",
                     alg="exhaustive", update_interval=100)
    ref = Simulator(dataclasses.replace(base, engine="reference")).run(trace)
    sim = Simulator(dataclasses.replace(base, engine="fast"))
    fast = sim.run(trace)
    _assert_results_identical(ref, fast)
    assert getattr(sim, "last_system", None) is not None


def test_run_policies_single_sweep():
    """A multi-policy comparison performs EXACTLY ONE system sweep, and
    sharing changes no result: every policy matches both its independent
    fast run and the reference loop."""
    trace = get_trace("gradle", N, seed=7)
    base = SimConfig(cache_size=1_000, costs=(2.0, 2.0, 2.0),
                     update_interval=200, est_interval=25)
    before = systemstate.SWEEPS_COMPUTED
    shared = run_policies(trace, base, policies=ALL_POLICIES)
    assert systemstate.SWEEPS_COMPUTED - before == 1
    before = systemstate.SWEEPS_COMPUTED
    independent = run_policies(trace, base, policies=ALL_POLICIES,
                               share_system=False)
    assert systemstate.SWEEPS_COMPUTED - before == len(ALL_POLICIES)
    reference = run_policies(
        trace, dataclasses.replace(base, engine="reference"),
        policies=ALL_POLICIES)
    for p in ALL_POLICIES:
        _assert_results_identical(independent[p], shared[p])
        _assert_results_identical(reference[p], shared[p])


def test_system_trace_install_state_parity():
    """A simulator that consumes a shared SystemTrace finishes in exactly
    the end-of-run system state of the simulator that computed it."""
    trace = get_trace("gradle", N, seed=3)
    base = SimConfig(cache_size=1_000, update_interval=200, policy="fna")
    donor = Simulator(base)
    donor.run(trace)
    other = Simulator(dataclasses.replace(base, policy="fno"))
    other.run(trace, system=donor.last_system)
    for dn, on in zip(donor.nodes, other.nodes):
        assert list(dn.lru.keys()) == list(on.lru.keys())
        assert np.array_equal(dn.ind.cbf.counters, on.ind.cbf.counters)
        assert np.array_equal(dn.ind.stale, on.ind.stale)
        assert dn.ind.fp_est == on.ind.fp_est
        assert dn.ind.fn_est == on.ind.fn_est
        assert dn.version == on.version
        assert (dn._since_adv, dn._since_est) == \
            (on._since_adv, on._since_est)
    for dq, oq in zip(donor.q_est, other.q_est):
        assert (dq.q, dq.version, dq._count, dq._positives) == \
            (oq.q, oq.version, oq._count, oq._positives)


def test_system_trace_rejects_mismatches():
    trace = get_trace("gradle", 2_000, seed=1)
    base = SimConfig(cache_size=500, update_interval=200)
    donor = Simulator(base)
    donor.run(trace)
    st = donor.last_system
    # different system config
    with pytest.raises(ValueError):
        st.install(Simulator(dataclasses.replace(base, cache_size=100)),
                   trace)
    # different trace
    with pytest.raises(ValueError):
        st.install(Simulator(base), trace[:-1])
    # non-fresh target
    used = Simulator(base)
    used.run(trace)
    with pytest.raises(ValueError):
        st.install(used, trace)


def test_run_sweep_grid_matches_reference():
    """The sweep runner's grid cells equal independent reference runs."""
    trace = get_trace("gradle", 5_000, seed=4)
    base = SimConfig(cache_size=1_000)
    grid = run_sweep({"gradle": trace}, base, update_intervals=(100, 800),
                     policies=("fna", "fno", "fna_cal"))
    assert set(grid) == {("gradle", 100), ("gradle", 800)}
    for (name, interval), cell in grid.items():
        ref_cfg = dataclasses.replace(base, engine="reference",
                                      update_interval=interval)
        for p, res in cell.items():
            ref = Simulator(
                dataclasses.replace(ref_cfg, policy=p)).run(trace)
            _assert_results_identical(ref, res)
    recs = sweep_records(grid)
    assert len(recs) == 6
    assert {r["update_interval"] for r in recs} == {100, 800}


def test_ewma_path_matches_scalar_recurrence():
    from repro.core.estimator import ewma_path
    rng = np.random.default_rng(0)
    outcomes = (rng.random(500) < 0.4).astype(np.float64)
    g = 0.05
    e = 0.9
    path = ewma_path(e, outcomes, g)
    for t, a in enumerate(outcomes.tolist()):
        e = (1 - g) * e + g * a
        assert path[t] == e    # bit-identical, not approximately


def test_rho_selection_tables_matches_scalar_and_jax():
    """The NumPy float64 verification path agrees with both the scalar
    DS_PGM and the JAX batched path on random rho matrices."""
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.core.batched import ds_pgm_batched, rho_selection_tables
    from repro.core.policies import ds_pgm

    rng = np.random.default_rng(5)
    costs = [1.0, 2.0, 3.0, 1.5]
    rhos = rng.uniform(0.0, 1.0, (257, 4))
    m = 100.0
    mask = rho_selection_tables(costs, rhos, m)
    for i in range(rhos.shape[0]):
        assert sorted(np.nonzero(mask[i])[0]) == \
            ds_pgm(costs, rhos[i].tolist(), m), i
    with enable_x64():
        jmask = np.asarray(ds_pgm_batched(
            jnp.asarray(np.asarray(costs, np.float64)),
            jnp.asarray(rhos), m))
    assert np.array_equal(mask, jmask)


def test_recency_trace_vectorisation_bit_identical():
    from repro.cachesim.traces import _recency_trace_ref, recency_trace
    for n, seed, kw in ((1, 0, {}), (4_000, 7, {}),
                        (6_000, 1, dict(p_new=0.35, window=2048)),
                        (3_000, 9, dict(p_new=0.05, window=512)),
                        (3_000, 2, dict(p_new=0.9, window=128))):
        assert np.array_equal(recency_trace(n, seed=seed, **kw),
                              _recency_trace_ref(n, seed=seed, **kw)), \
            (n, seed, kw)
