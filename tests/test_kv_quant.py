"""int8 KV cache (§Perf lever C3): decode parity within quantisation error."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import get_model, make_concrete_batch


@pytest.mark.parametrize("arch", ["smollm-135m", "granite-3-2b"])
def test_int8_kv_decode_close_to_fp(arch):
    cfg = get_config(arch).reduced()
    cfg_q = dataclasses.replace(cfg, kv_quant=True)
    model, model_q = get_model(cfg), get_model(cfg_q)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_concrete_batch(cfg, 2, 24, jax.random.PRNGKey(1), with_labels=False)

    logits_f, cache_f = jax.jit(lambda p, b: model.prefill(p, b, max_len=32))(params, batch)
    logits_q, cache_q = jax.jit(lambda p, b: model_q.prefill(p, b, max_len=32))(params, batch)
    # prefill last-token logits identical (quantisation happens on the stored
    # cache, not the prefill forward)
    np.testing.assert_allclose(np.asarray(logits_q), np.asarray(logits_f),
                               rtol=1e-5, atol=1e-5)
    assert cache_q["k"].dtype == jnp.int8 and "k_s" in cache_q

    tok = jnp.asarray(np.argmax(np.asarray(logits_f)[:, :cfg.vocab], -1), jnp.int32)
    lf, _ = jax.jit(model.decode_step)(params, cache_f, tok)
    lq, _ = jax.jit(model_q.decode_step)(params, cache_q, tok)
    # int8 per-(token, head) absmax: logits agree to quantisation tolerance
    lq_np, lf_np = np.asarray(lq), np.asarray(lf)
    np.testing.assert_allclose(lq_np, lf_np, rtol=0.1, atol=0.15)
    # argmax may only flip where the float-path top-2 gap is within the
    # quantisation noise (random-init logits are nearly tied)
    for i in range(lf_np.shape[0]):
        if lq_np[i].argmax() != lf_np[i].argmax():
            top2 = np.sort(lf_np[i])[-2:]
            assert top2[1] - top2[0] < 0.2, (i, top2)


def test_int8_cache_is_half_the_bytes():
    cfg = dataclasses.replace(get_config("granite-3-2b"), kv_quant=True)
    model = get_model(cfg)
    spec = model.cache_spec(128, 32768)
    int8_bytes = sum(np.prod(s.shape) * s.dtype.itemsize
                     for s in (spec["k"], spec["v"], spec["k_s"], spec["v_s"]))
    bf16_bytes = 2 * 2 * np.prod(spec["k"].shape)
    assert int8_bytes < 0.6 * bf16_bytes
