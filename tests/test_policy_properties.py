"""Property-based policy invariants (hypothesis).

Analytic properties of the selection layer, checked on random small
instances (n <= 6) rather than fixed fixtures:

  * DS_PGM is EXACTLY the best prefix of the potential-gain order
    (including the empty prefix) — its by-construction guarantee, which
    holds unconditionally;
  * against the exact Eq. (10) minimiser it is never better than
    ``exhaustive`` and, in the paper's operating regime (unit-scale
    access costs, miss penalty orders of magnitude larger), never worse
    than the log(M) approximation factor.  The multiplicative factor is
    a REGIME bound, not universal: with access costs far below 1 or M
    comparable to a single access cost, adversarial instances exceed it
    (a cheap useless cache can head the potential-gain order and block
    the one good prefix), which is why the draws below mirror the
    paper's cost normalisation;
  * Theorem-7 degeneracy: with FN = 0 the false-negative-AWARE selector
    collapses onto the false-negative-OBLIVIOUS one (nu = 1, so
    negative-indication caches can never pay for themselves).

The bitmask twins (``ds_pgm_mask`` / ``exhaustive_mask``) are asserted
decision-identical to their list-returning originals on the same draws —
they are the scalar inner loop of the calibrated fast engine.

The module also carries the decision-plan layer's provider parity
properties: the exact batched HOCS mirror
(``repro.core.batched.hocs_fna_batched`` / ``hocs_selection_tables``)
against the scalar Algorithm-1 version loop it replaced, and the
calibrated engine's batched bridge tables (``selection_tables``
backend="numpy" / ``exhaustive_tables``) against per-pattern scalar
``mask_fn`` rows, across random (costs, rhos, M).  Seeded-random
backstops that run without hypothesis live in
``tests/test_engine_providers.py``.
"""
import math

import numpy as np
import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.batched import (  # noqa: E402
    exhaustive_tables,
    hocs_fna_batched,
    hocs_selection_tables,
    selection_tables,
)
from repro.core.model import EPS, CacheView, service_cost  # noqa: E402
from repro.core.policies import (  # noqa: E402
    cs_fna,
    cs_fno,
    ds_pgm,
    ds_pgm_mask,
    exhaustive,
    exhaustive_mask,
    hocs_fna,
)

MAX_N = 6

rhos_st = st.floats(0.0, 1.0, allow_nan=False, allow_infinity=False)


@st.composite
def instances(draw, cost_lo=0.05, cost_hi=5.0, m_lo=1.5, m_hi=1_000.0):
    n = draw(st.integers(1, MAX_N))
    cost_st = st.floats(cost_lo, cost_hi, allow_nan=False,
                        allow_infinity=False)
    costs = draw(st.lists(cost_st, min_size=n, max_size=n))
    rhos = draw(st.lists(rhos_st, min_size=n, max_size=n))
    M = draw(st.floats(m_lo, m_hi, allow_nan=False, allow_infinity=False))
    return costs, rhos, M


def _mask(sel) -> int:
    m = 0
    for j in sel:
        m |= 1 << j
    return m


@settings(max_examples=300, deadline=None)
@given(instances())
def test_ds_pgm_is_best_prefix(inst):
    """Unconditional, exact: DS_PGM returns the cheapest prefix of the
    potential-gain order (empty prefix included), it never beats the
    exhaustive optimum, and the optimum never beats skipping every
    cache."""
    costs, rhos, M = inst
    order = sorted(range(len(costs)),
                   key=lambda j: costs[j] /
                   -math.log(min(max(rhos[j], EPS), 1.0 - EPS)))
    best_prefix = min([M] + [service_cost(costs, rhos, M, order[:i + 1])
                             for i in range(len(order))])
    pgm = service_cost(costs, rhos, M, ds_pgm(costs, rhos, M))
    opt = service_cost(costs, rhos, M, exhaustive(costs, rhos, M))
    assert abs(pgm - best_prefix) <= 1e-9
    assert opt <= pgm + 1e-9
    assert opt <= M + 1e-9


@settings(max_examples=300, deadline=None)
@given(instances(cost_lo=1.0, cost_hi=5.0, m_lo=50.0, m_hi=1_000.0))
def test_ds_pgm_within_paper_bound_of_exhaustive(inst):
    """In the paper's regime — access costs on the unit scale, miss
    penalty orders of magnitude larger (Sec. V uses costs 1..3 against
    M = 50..500) — the prefix scan stays within the log(M) factor of
    the exact minimiser (empirical worst over 10^6 random draws: ~1.9x
    vs a 1 + ln M >= 4.9 budget)."""
    costs, rhos, M = inst
    opt = service_cost(costs, rhos, M, exhaustive(costs, rhos, M))
    pgm = service_cost(costs, rhos, M, ds_pgm(costs, rhos, M))
    assert pgm <= opt * (1.0 + math.log(M)) + 1e-9, (costs, rhos, M, pgm, opt)


@settings(max_examples=300, deadline=None)
@given(instances())
def test_mask_variants_decision_identical(inst):
    """The overhead-stripped bitmask twins pick the same subsets."""
    costs, rhos, M = inst
    assert ds_pgm_mask(costs, rhos, M) == _mask(ds_pgm(costs, rhos, M))
    assert exhaustive_mask(costs, rhos, M) == _mask(exhaustive(costs, rhos, M))


@st.composite
def zero_fn_views(draw):
    n = draw(st.integers(1, MAX_N))
    views = [CacheView(cost=draw(st.floats(0.05, 5.0)),
                       fp=draw(st.floats(0.0, 0.6)),
                       fn=0.0,
                       q=draw(st.floats(0.0, 0.95)))
             for _ in range(n)]
    inds = [draw(st.booleans()) for _ in range(n)]
    M = draw(st.floats(1.5, 1_000.0, allow_nan=False, allow_infinity=False))
    return views, inds, M


# ---------------------------------------------------------------------------
# Decision-plan providers: batched builders == the scalar loops they
# replaced (the fast engine's table layer, see repro.cachesim.engine)
#
# The batched builders carry the engine's documented near-tie caveat
# (float64 argmin / 1-ulp log differences vs the scalar EPS dead-band).
# Data-derived estimates never land in that measure-zero region, but
# hypothesis hunts for it with exact "nice" fractions — so each draw
# ASSUMEs away instances whose decision margin is inside the caveat
# (< 1e-9), and asserts EXACT parity on everything else.
# ---------------------------------------------------------------------------

def _geo_boundary_safe(m_eff: float, rho: float) -> bool:
    """The _argmin_geometric candidate shortlist {0, 1, floor(r*),
    ceil(r*), r_max} is log-derived; a continuous optimum within 1e-6 of
    an integer could flip floor/ceil under a 1-ulp log difference."""
    if rho <= EPS or rho >= 1.0 - EPS:
        return True                    # branch uses exact comparisons only
    l = math.log(1.0 / rho)
    r_cont = math.log(max(m_eff * l, EPS)) / l
    return abs(r_cont - round(r_cont)) > 1e-6


def _hocs_instance_safe(n: int, pi: float, nu: float, M: float) -> bool:
    if not _geo_boundary_safe(M, pi):
        return False
    for x in range(n + 1):
        r1 = hocs_fna(x, n, pi, nu, M)[1]
        residual = M * pi ** r1
        if residual > 1.0 and not _geo_boundary_safe(residual, nu):
            return False
    return True


@settings(max_examples=300, deadline=None)
@given(st.integers(1, 9), rhos_st, rhos_st,
       st.floats(1.5, 1_000.0, allow_nan=False, allow_infinity=False))
def test_hocs_fna_batched_matches_scalar_version_loop(n, pi, nu, M):
    """The float64 NumPy mirror reproduces the scalar Algorithm 1
    EXACTLY over every positive-indication count — it is the fast
    engine's HOCS table builder, so near-enough is not enough."""
    hyp.assume(_hocs_instance_safe(n, pi, nu, M))
    nx = np.arange(n + 1, dtype=np.int64)
    r0b, r1b = hocs_fna_batched(nx, n, pi, nu, M)
    for x in range(n + 1):
        assert (int(r0b[x]), int(r1b[x])) == hocs_fna(x, n, pi, nu, M), \
            (n, pi, nu, M, x)


@st.composite
def view_histories(draw, max_n=5, max_v=4):
    n = draw(st.integers(1, max_n))
    v = draw(st.integers(1, max_v))
    rows = st.lists(rhos_st, min_size=n, max_size=n)
    pi_v = draw(st.lists(rows, min_size=v, max_size=v))
    nu_v = draw(st.lists(rows, min_size=v, max_size=v))
    M = draw(st.floats(1.5, 1_000.0, allow_nan=False, allow_infinity=False))
    return np.asarray(pi_v), np.asarray(nu_v), M


@settings(max_examples=150, deadline=None)
@given(view_histories())
def test_hocs_selection_tables_match_scalar_version_loop(case):
    """Row (v, p) of the batched HOCS build == the scalar version loop
    the fast engine used to run: left-to-right pooled means,
    per-popcount (r0*, r1*), then the r1* cheapest positive plus r0*
    cheapest negative caches."""
    pi_v, nu_v, M = case
    v, n = pi_v.shape
    for vi in range(v):
        hyp.assume(_hocs_instance_safe(
            n, sum(pi_v[vi].tolist()) / n, sum(nu_v[vi].tolist()) / n, M))
    tab = hocs_selection_tables(pi_v, nu_v, M)
    for vi in range(v):
        pi_h = sum(pi_v[vi].tolist()) / n
        nu_h = sum(nu_v[vi].tolist()) / n
        r_by_nx = [hocs_fna(x, n, pi_h, nu_h, M) for x in range(n + 1)]
        for p in range(1 << n):
            pos = [j for j in range(n) if (p >> j) & 1]
            neg = [j for j in range(n) if not (p >> j) & 1]
            r0, r1 = r_by_nx[len(pos)]
            want = 0
            for j in pos[:r1] + neg[:r0]:
                want |= 1 << j
            assert tab[vi, p] == want, (vi, p)


def _clip(r: float) -> float:
    return min(max(r, EPS), 1.0 - EPS)


def _ds_pgm_row_safe(costs, rhos, M) -> bool:
    """Potential-gain keys separated (order stable under 1-ulp log
    drift) and a unique Eq. (10) winner by > 1e-9 (outside both the
    scalar dead-band and the batched evaluation error)."""
    n = len(costs)
    keys = sorted(costs[j] / -math.log(_clip(rhos[j])) for j in range(n))
    for a, b in zip(keys, keys[1:]):
        if 0.0 < b - a <= 1e-9 * max(abs(a), 1.0):
            return False
    order = sorted(range(n), key=lambda j: costs[j] / -math.log(_clip(rhos[j])))
    vals = [M]
    run_c, run_p = 0.0, 1.0
    for j in order:
        run_c += costs[j]
        run_p *= rhos[j]
        vals.append(run_c + M * run_p)
    vals = sorted(vals)
    return vals[1] - vals[0] > 1e-9


def _exhaustive_row_safe(costs, rhos, M) -> bool:
    """Unique-or-exactly-tied Eq. (10) minimum: subset values are
    evaluated IEEE-identically by the batched DP, so exact ties resolve
    to the same lowest mask on both sides; only near-ties inside the
    dead-band can diverge."""
    n = len(costs)
    vals = [M]
    for mask in range(1, 1 << n):
        c, p = 0.0, M
        for j in range(n):
            if mask >> j & 1:
                c += costs[j]
                p *= rhos[j]
        vals.append(c + p)
    vals = sorted(vals)
    gap = vals[1] - vals[0]
    return gap == 0.0 or gap > 1e-9


@st.composite
def bridge_instances(draw, max_n=4):
    n = draw(st.integers(1, max_n))
    cost_st = st.floats(0.05, 5.0, allow_nan=False, allow_infinity=False)
    costs = draw(st.lists(cost_st, min_size=n, max_size=n))
    rp = draw(st.lists(rhos_st, min_size=n, max_size=n))
    rn = draw(st.lists(rhos_st, min_size=n, max_size=n))
    M = draw(st.floats(1.5, 1_000.0, allow_nan=False, allow_infinity=False))
    return costs, rp, rn, M


@settings(max_examples=300, deadline=None)
@given(bridge_instances())
def test_batched_fna_cal_bridge_tables_match_scalar_mask_rows(inst):
    """The calibrated engine's batched speculation/bridge tables
    row-match the per-pattern scalar ``mask_fn`` calls they replaced,
    for both subroutines."""
    costs, rp, rn, M = inst
    n = len(costs)
    rows = []
    for p in range(1 << n):
        rhos = [rp[j] if (p >> j) & 1 else rn[j] for j in range(n)]
        hyp.assume(_ds_pgm_row_safe(costs, rhos, M))
        hyp.assume(_exhaustive_row_safe(costs, rhos, M))
        rows.append(rhos)
    pow2 = (1 << np.arange(n)).astype(np.int64)
    ds_tab = (selection_tables(costs, [rp], [rn], M, backend="numpy")
              .reshape(-1, n) @ pow2)
    ex_tab = exhaustive_tables(costs, [rp], [rn], M).reshape(-1)
    for p, rhos in enumerate(rows):
        assert ds_tab[p] == ds_pgm_mask(costs, rhos, M), (p, inst)
        assert ex_tab[p] == exhaustive_mask(costs, rhos, M), (p, inst)


@st.composite
def rho_matrix_instances(draw, max_n=5, max_b=6):
    n = draw(st.integers(1, max_n))
    b = draw(st.integers(1, max_b))
    cost_st = st.floats(0.05, 5.0, allow_nan=False, allow_infinity=False)
    costs = draw(st.lists(cost_st, min_size=n, max_size=n))
    rows = st.lists(rhos_st, min_size=n, max_size=n)
    rhos = draw(st.lists(rows, min_size=b, max_size=b))
    allowed = draw(st.lists(st.lists(st.booleans(), min_size=n, max_size=n),
                            min_size=b, max_size=b))
    M = draw(st.floats(1.5, 1_000.0, allow_nan=False, allow_infinity=False))
    return costs, rhos, allowed, M


def _restricted_row_safe(costs, rhos, allowed, M) -> bool:
    sub = [j for j in range(len(costs)) if allowed[j]]
    if not sub:
        return True                    # empty candidate set: both pick {}
    return _ds_pgm_row_safe([costs[j] for j in sub],
                            [rhos[j] for j in sub], M)


@settings(max_examples=200, deadline=None)
@given(rho_matrix_instances())
def test_rho_selection_tables_matches_ds_pgm_batched_x64(inst):
    """The NumPy float64 mirror and the jitted x64 ``ds_pgm_batched``
    agree EXACTLY on every row away from the ~1e-12 near-tie dead-band —
    the contract that lets the fast engine route any table build through
    either backend.  Checked with and without the CS_FNO candidate
    restriction (``allowed`` mask vs ``fno_mask``)."""
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.core.batched import ds_pgm_batched, rho_selection_tables
    costs, rhos, allowed, M = inst
    for row, arow in zip(rhos, allowed):
        hyp.assume(_ds_pgm_row_safe(costs, row, M))
        hyp.assume(_restricted_row_safe(costs, row, arow, M))
    costs_a = np.asarray(costs, np.float64)
    rhos_a = np.asarray(rhos, np.float64)
    allow_a = np.asarray(allowed, bool)
    with enable_x64():
        free = np.asarray(ds_pgm_batched(
            jnp.asarray(costs_a), jnp.asarray(rhos_a), float(M)))
        restricted = np.asarray(ds_pgm_batched(
            jnp.asarray(costs_a), jnp.asarray(rhos_a), float(M),
            fno_mask=jnp.asarray(allow_a.astype(np.int64))))
    assert np.array_equal(
        rho_selection_tables(costs_a, rhos_a, M), free), inst
    assert np.array_equal(
        rho_selection_tables(costs_a, rhos_a, M, allowed=allow_a),
        restricted), inst


@settings(max_examples=300, deadline=None)
@given(zero_fn_views())
def test_cs_fna_degenerates_to_cs_fno_without_false_negatives(case):
    """With FN = 0 every negative indication is truthful, nu = 1, and
    Algorithm 2's extra candidates can never reduce Eq. (10): CS_FNA's
    selection equals CS_FNO's on every instance (both subroutines)."""
    views, inds, M = case
    for alg in (ds_pgm, exhaustive):
        fna = cs_fna(views, inds, M, alg=alg)
        fno = cs_fno(views, inds, M, alg=alg)
        assert fna == fno, (views, inds, M, alg.__name__)
        # and the selection only ever touches positive-indication caches
        assert all(inds[j] for j in fna)
