"""Property-based policy invariants (hypothesis).

Analytic properties of the selection layer, checked on random small
instances (n <= 6) rather than fixed fixtures:

  * DS_PGM is EXACTLY the best prefix of the potential-gain order
    (including the empty prefix) — its by-construction guarantee, which
    holds unconditionally;
  * against the exact Eq. (10) minimiser it is never better than
    ``exhaustive`` and, in the paper's operating regime (unit-scale
    access costs, miss penalty orders of magnitude larger), never worse
    than the log(M) approximation factor.  The multiplicative factor is
    a REGIME bound, not universal: with access costs far below 1 or M
    comparable to a single access cost, adversarial instances exceed it
    (a cheap useless cache can head the potential-gain order and block
    the one good prefix), which is why the draws below mirror the
    paper's cost normalisation;
  * Theorem-7 degeneracy: with FN = 0 the false-negative-AWARE selector
    collapses onto the false-negative-OBLIVIOUS one (nu = 1, so
    negative-indication caches can never pay for themselves).

The bitmask twins (``ds_pgm_mask`` / ``exhaustive_mask``) are asserted
decision-identical to their list-returning originals on the same draws —
they are the scalar inner loop of the calibrated fast engine.
"""
import math

import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.model import EPS, CacheView, service_cost  # noqa: E402
from repro.core.policies import (  # noqa: E402
    cs_fna,
    cs_fno,
    ds_pgm,
    ds_pgm_mask,
    exhaustive,
    exhaustive_mask,
)

MAX_N = 6

rhos_st = st.floats(0.0, 1.0, allow_nan=False, allow_infinity=False)


@st.composite
def instances(draw, cost_lo=0.05, cost_hi=5.0, m_lo=1.5, m_hi=1_000.0):
    n = draw(st.integers(1, MAX_N))
    cost_st = st.floats(cost_lo, cost_hi, allow_nan=False,
                        allow_infinity=False)
    costs = draw(st.lists(cost_st, min_size=n, max_size=n))
    rhos = draw(st.lists(rhos_st, min_size=n, max_size=n))
    M = draw(st.floats(m_lo, m_hi, allow_nan=False, allow_infinity=False))
    return costs, rhos, M


def _mask(sel) -> int:
    m = 0
    for j in sel:
        m |= 1 << j
    return m


@settings(max_examples=300, deadline=None)
@given(instances())
def test_ds_pgm_is_best_prefix(inst):
    """Unconditional, exact: DS_PGM returns the cheapest prefix of the
    potential-gain order (empty prefix included), it never beats the
    exhaustive optimum, and the optimum never beats skipping every
    cache."""
    costs, rhos, M = inst
    order = sorted(range(len(costs)),
                   key=lambda j: costs[j] /
                   -math.log(min(max(rhos[j], EPS), 1.0 - EPS)))
    best_prefix = min([M] + [service_cost(costs, rhos, M, order[:i + 1])
                             for i in range(len(order))])
    pgm = service_cost(costs, rhos, M, ds_pgm(costs, rhos, M))
    opt = service_cost(costs, rhos, M, exhaustive(costs, rhos, M))
    assert abs(pgm - best_prefix) <= 1e-9
    assert opt <= pgm + 1e-9
    assert opt <= M + 1e-9


@settings(max_examples=300, deadline=None)
@given(instances(cost_lo=1.0, cost_hi=5.0, m_lo=50.0, m_hi=1_000.0))
def test_ds_pgm_within_paper_bound_of_exhaustive(inst):
    """In the paper's regime — access costs on the unit scale, miss
    penalty orders of magnitude larger (Sec. V uses costs 1..3 against
    M = 50..500) — the prefix scan stays within the log(M) factor of
    the exact minimiser (empirical worst over 10^6 random draws: ~1.9x
    vs a 1 + ln M >= 4.9 budget)."""
    costs, rhos, M = inst
    opt = service_cost(costs, rhos, M, exhaustive(costs, rhos, M))
    pgm = service_cost(costs, rhos, M, ds_pgm(costs, rhos, M))
    assert pgm <= opt * (1.0 + math.log(M)) + 1e-9, (costs, rhos, M, pgm, opt)


@settings(max_examples=300, deadline=None)
@given(instances())
def test_mask_variants_decision_identical(inst):
    """The overhead-stripped bitmask twins pick the same subsets."""
    costs, rhos, M = inst
    assert ds_pgm_mask(costs, rhos, M) == _mask(ds_pgm(costs, rhos, M))
    assert exhaustive_mask(costs, rhos, M) == _mask(exhaustive(costs, rhos, M))


@st.composite
def zero_fn_views(draw):
    n = draw(st.integers(1, MAX_N))
    views = [CacheView(cost=draw(st.floats(0.05, 5.0)),
                       fp=draw(st.floats(0.0, 0.6)),
                       fn=0.0,
                       q=draw(st.floats(0.0, 0.95)))
             for _ in range(n)]
    inds = [draw(st.booleans()) for _ in range(n)]
    M = draw(st.floats(1.5, 1_000.0, allow_nan=False, allow_infinity=False))
    return views, inds, M


@settings(max_examples=300, deadline=None)
@given(zero_fn_views())
def test_cs_fna_degenerates_to_cs_fno_without_false_negatives(case):
    """With FN = 0 every negative indication is truthful, nu = 1, and
    Algorithm 2's extra candidates can never reduce Eq. (10): CS_FNA's
    selection equals CS_FNO's on every instance (both subroutines)."""
    views, inds, M = case
    for alg in (ds_pgm, exhaustive):
        fna = cs_fna(views, inds, M, alg=alg)
        fno = cs_fno(views, inds, M, alg=alg)
        assert fna == fno, (views, inds, M, alg.__name__)
        # and the selection only ever touches positive-indication caches
        assert all(inds[j] for j in fna)
