"""Per-architecture smoke tests on REDUCED configs (deliverable f).

For every assigned architecture: instantiate a tiny same-family config,
run one forward + one train step on CPU, assert output shapes and no NaNs,
and check decode-vs-forward logit parity (KV/state-cache correctness).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import get_model, make_concrete_batch
from repro.optim import OptConfig, init_train_state, make_train_step

S = 32  # smoke sequence length
B = 2


def _reduced(arch):
    cfg = get_config(arch).reduced()
    return cfg


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch, rng):
    cfg = _reduced(arch)
    model = get_model(cfg)
    params = model.init(rng)
    n_params = sum(np.prod(p.shape) for p in jax.tree.leaves(params))
    assert n_params > 0
    batch = make_concrete_batch(cfg, B, S, jax.random.PRNGKey(1))

    logits = jax.jit(model.forward)(params, batch)
    s_out = S - cfg.n_patches if cfg.family == "vlm" else S
    if cfg.family == "vlm":
        assert logits.shape == (B, S, cfg.vocab)  # patches + text positions
    else:
        assert logits.shape == (B, s_out, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    step = jax.jit(make_train_step(model, OptConfig(lr=1e-3, warmup_steps=2, total_steps=10)))
    state = init_train_state(params, OptConfig())
    state2, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(state2["step"]) == 1
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(state["params"]), jax.tree.leaves(state2["params"]))
    )
    assert moved
    # second step: loss changes and stays finite
    state3, m3 = step(state2, batch)
    assert bool(jnp.isfinite(m3["loss"]))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch, rng):
    """Teacher-forced decode must reproduce full-forward logits."""
    cfg = _reduced(arch)
    model = get_model(cfg)
    params = model.init(rng)
    batch = make_concrete_batch(cfg, B, S, jax.random.PRNGKey(2), with_labels=False)
    full = jax.jit(model.forward)(params, batch)  # [B, S_total, V]

    if cfg.family == "encdec":
        # decode the token stream against the encoder output from scratch
        enc_out = jax.jit(model.encode)(params, batch["frames"])
        ck, cv = jax.jit(model.prefill_cross)(params, enc_out)
        cache = model.init_cache(B, S + 4, S)
        cache["ck"], cache["cv"] = ck, cv
        step = jax.jit(model.decode_step)
        for t in range(4):
            logits, cache = step(params, cache, batch["tokens"][:, t])
            np.testing.assert_allclose(
                np.asarray(logits), np.asarray(full[:, t]), rtol=2e-2, atol=2e-2)
        return

    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=S + 4)) \
        if cfg.family in ("dense", "moe", "vlm", "hybrid") else jax.jit(model.prefill)
    logits_p, cache = prefill(params, batch)
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(full[:, -1]),
                               rtol=2e-2, atol=2e-2)

    # one decode step on a fresh random token: compare against forward on S+1
    new_tok = jax.random.randint(jax.random.PRNGKey(3), (B,), 0, cfg.vocab, jnp.int32)
    step = jax.jit(model.decode_step)
    logits_d, cache = step(params, cache, new_tok)
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], new_tok[:, None]], axis=1)
    full2 = jax.jit(model.forward)(params, batch2)
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(full2[:, -1]),
                               rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_analytic(arch, rng):
    """Analytic param_count() must match the actual init tree."""
    cfg = _reduced(arch)
    model = get_model(cfg)
    params = jax.eval_shape(model.init, rng)
    actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    assert actual == cfg.param_count(), (actual, cfg.param_count())
