"""JAX batched router math == scalar reference policies."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CacheView, cs_fna, cs_fno, ds_pgm
from repro.core.batched import (
    cs_fna_batched,
    cs_fno_batched,
    ds_pgm_batched,
    exclusions,
    hit_from_q,
    hocs_fna_batched,
)
from repro.core.model import exclusion_probabilities, hit_ratio_from_q
from repro.core.policies import hocs_fna


def test_exclusions_match_scalar():
    rng = np.random.default_rng(0)
    h = rng.uniform(0.05, 0.9, 64)
    fp = rng.uniform(0.001, 0.3, 64)
    fn = rng.uniform(0.0, 0.5, 64)
    pi_b, nu_b = exclusions(jnp.asarray(h), jnp.asarray(fp), jnp.asarray(fn))
    for i in range(64):
        pi_s, nu_s = exclusion_probabilities(h[i], fp[i], fn[i])
        assert abs(float(pi_b[i]) - pi_s) < 1e-6
        assert abs(float(nu_b[i]) - nu_s) < 1e-6


def test_ds_pgm_batched_matches_scalar():
    rng = np.random.default_rng(1)
    n, b = 6, 128
    costs = rng.uniform(1, 3, n)
    rhos = rng.uniform(0.01, 0.99, (b, n))
    M = 100.0
    mask = np.asarray(ds_pgm_batched(jnp.asarray(costs), jnp.asarray(rhos), M))
    for i in range(b):
        sel = ds_pgm(list(costs), list(rhos[i]), M)
        got = sorted(np.nonzero(mask[i])[0].tolist())
        assert got == sel, (i, got, sel)


def test_cs_policies_batched_match_scalar():
    rng = np.random.default_rng(2)
    n, b = 5, 64
    costs = rng.uniform(1, 3, n)
    q = rng.uniform(0.1, 0.9, n)
    fp = rng.uniform(0.001, 0.2, n)
    fn = rng.uniform(0.0, 0.45, n)
    ind = (rng.random((b, n)) < 0.4).astype(np.int32)
    M = 100.0
    m_fna = np.asarray(cs_fna_batched(jnp.asarray(ind), jnp.asarray(costs),
                                      jnp.asarray(q), jnp.asarray(fp),
                                      jnp.asarray(fn), M))
    m_fno = np.asarray(cs_fno_batched(jnp.asarray(ind), jnp.asarray(costs),
                                      jnp.asarray(q), jnp.asarray(fp),
                                      jnp.asarray(fn), M))
    for i in range(b):
        views = [CacheView(cost=costs[j], fp=fp[j], fn=fn[j], q=q[j])
                 for j in range(n)]
        s_fna = cs_fna(views, list(ind[i]), M, alg=ds_pgm)
        s_fno = cs_fno(views, list(ind[i]), M, alg=ds_pgm)
        assert sorted(np.nonzero(m_fna[i])[0].tolist()) == s_fna
        assert sorted(np.nonzero(m_fno[i])[0].tolist()) == s_fno
    # FNO never accesses a negative-indication cache
    assert not np.any(m_fno.astype(bool) & (ind == 0))


def test_hocs_batched_matches_scalar():
    """The float64 NumPy mirror is decision-EXACT vs the scalar
    Algorithm 1 (it is the fast engine's table builder, so near-enough
    is not enough)."""
    rng = np.random.default_rng(3)
    n, M = 8, 100.0
    for _ in range(20):
        h, fp, fn = rng.uniform(0.1, 0.8), rng.uniform(0.001, 0.3), rng.uniform(0, 0.4)
        pi, nu = exclusion_probabilities(h, fp, fn)
        nx = rng.integers(0, n + 1, 16)
        r0_b, r1_b = hocs_fna_batched(nx, n, pi, nu, M)
        for i in range(16):
            assert (int(r0_b[i]), int(r1_b[i])) == \
                hocs_fna(int(nx[i]), n, pi, nu, M), (pi, nu, int(nx[i]))
