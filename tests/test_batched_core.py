"""JAX batched router math == scalar reference policies."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CacheView, cs_fna, cs_fno, ds_pgm
from repro.core.batched import (
    cs_fna_batched,
    cs_fno_batched,
    ds_pgm_batched,
    exclusions,
    hit_from_q,
    hocs_fna_batched,
    rho_selection_tables,
    selection_tables,
)
from repro.core.model import exclusion_probabilities, hit_ratio_from_q
from repro.core.policies import hocs_fna


def test_exclusions_match_scalar():
    rng = np.random.default_rng(0)
    h = rng.uniform(0.05, 0.9, 64)
    fp = rng.uniform(0.001, 0.3, 64)
    fn = rng.uniform(0.0, 0.5, 64)
    pi_b, nu_b = exclusions(jnp.asarray(h), jnp.asarray(fp), jnp.asarray(fn))
    for i in range(64):
        pi_s, nu_s = exclusion_probabilities(h[i], fp[i], fn[i])
        assert abs(float(pi_b[i]) - pi_s) < 1e-6
        assert abs(float(nu_b[i]) - nu_s) < 1e-6


def test_ds_pgm_batched_matches_scalar():
    rng = np.random.default_rng(1)
    n, b = 6, 128
    costs = rng.uniform(1, 3, n)
    rhos = rng.uniform(0.01, 0.99, (b, n))
    M = 100.0
    mask = np.asarray(ds_pgm_batched(jnp.asarray(costs), jnp.asarray(rhos), M))
    for i in range(b):
        sel = ds_pgm(list(costs), list(rhos[i]), M)
        got = sorted(np.nonzero(mask[i])[0].tolist())
        assert got == sel, (i, got, sel)


def test_cs_policies_batched_match_scalar():
    rng = np.random.default_rng(2)
    n, b = 5, 64
    costs = rng.uniform(1, 3, n)
    q = rng.uniform(0.1, 0.9, n)
    fp = rng.uniform(0.001, 0.2, n)
    fn = rng.uniform(0.0, 0.45, n)
    ind = (rng.random((b, n)) < 0.4).astype(np.int32)
    M = 100.0
    m_fna = np.asarray(cs_fna_batched(jnp.asarray(ind), jnp.asarray(costs),
                                      jnp.asarray(q), jnp.asarray(fp),
                                      jnp.asarray(fn), M))
    m_fno = np.asarray(cs_fno_batched(jnp.asarray(ind), jnp.asarray(costs),
                                      jnp.asarray(q), jnp.asarray(fp),
                                      jnp.asarray(fn), M))
    for i in range(b):
        views = [CacheView(cost=costs[j], fp=fp[j], fn=fn[j], q=q[j])
                 for j in range(n)]
        s_fna = cs_fna(views, list(ind[i]), M, alg=ds_pgm)
        s_fno = cs_fno(views, list(ind[i]), M, alg=ds_pgm)
        assert sorted(np.nonzero(m_fna[i])[0].tolist()) == s_fna
        assert sorted(np.nonzero(m_fno[i])[0].tolist()) == s_fno
    # FNO never accesses a negative-indication cache
    assert not np.any(m_fno.astype(bool) & (ind == 0))


def test_hocs_batched_matches_scalar():
    """The float64 NumPy mirror is decision-EXACT vs the scalar
    Algorithm 1 (it is the fast engine's table builder, so near-enough
    is not enough)."""
    rng = np.random.default_rng(3)
    n, M = 8, 100.0
    for _ in range(20):
        h, fp, fn = rng.uniform(0.1, 0.8), rng.uniform(0.001, 0.3), rng.uniform(0, 0.4)
        pi, nu = exclusion_probabilities(h, fp, fn)
        nx = rng.integers(0, n + 1, 16)
        r0_b, r1_b = hocs_fna_batched(nx, n, pi, nu, M)
        for i in range(16):
            assert (int(r0_b[i]), int(r1_b[i])) == \
                hocs_fna(int(nx[i]), n, pi, nu, M), (pi, nu, int(nx[i]))


def test_selection_tables_numpy_backend_supports_fno():
    """``backend="numpy"`` used to raise on ``fno=True``; the per-row
    ``allowed`` mask of ``rho_selection_tables`` now expresses the CS_FNO
    restriction, matching the JAX backend on every (version, pattern)
    row (seeded draws away from the near-tie dead-band)."""
    rng = np.random.default_rng(5)
    for _ in range(10):
        n = int(rng.integers(1, 7))
        v = int(rng.integers(1, 6))
        costs = rng.uniform(0.05, 5.0, n)
        pi = rng.uniform(0.0, 1.0, (v, n))
        nu = rng.uniform(0.0, 1.0, (v, n))
        M = float(rng.uniform(1.5, 1000.0))
        for fno in (False, True):
            a = selection_tables(costs, pi, nu, M, fno=fno, backend="numpy")
            b = selection_tables(costs, pi, nu, M, fno=fno, backend="jax")
            assert np.array_equal(a, b), (n, v, M, fno)
            if fno:
                # the restriction really bites: no mask ever selects a
                # negative-indication cache
                k = 1 << n
                pats = ((np.arange(k)[:, None] >> np.arange(n)[None, :])
                        & 1).astype(bool)
                assert not np.any(a & ~pats[None, :, :])


def test_rho_selection_tables_allowed_empty_rows():
    """An all-False ``allowed`` row (a pattern with no positive
    indications under CS_FNO) must yield the empty selection, not NaNs
    or a spurious pick."""
    costs = np.array([1.0, 2.0, 3.0])
    rhos = np.array([[0.5, 0.5, 0.5], [0.2, 0.9, 0.4]])
    allowed = np.array([[False, False, False], [True, False, True]])
    mask = rho_selection_tables(costs, rhos, 100.0, allowed=allowed)
    assert not mask[0].any()
    assert not mask[1, 1]


def test_hocs_batched_jax_backend_matches_numpy():
    """The jitted shortlist scan reproduces the NumPy mirror's integer
    (r0, r1) grid (seeded draws; dead-band divergence needs the
    continuous optimum within ~1 ulp of an integer, which these draws
    never hit)."""
    rng = np.random.default_rng(6)
    n = 9
    nx = rng.integers(0, n + 1, 256)
    pi = rng.uniform(0.0, 1.0, 256)
    nu = rng.uniform(0.0, 1.0, 256)
    m = rng.uniform(1.5, 1000.0, 256)
    r0a, r1a = hocs_fna_batched(nx, n, pi, nu, m)
    r0b, r1b = hocs_fna_batched(nx, n, pi, nu, m, backend="jax")
    assert np.array_equal(r0a, r0b)
    assert np.array_equal(r1a, r1b)
