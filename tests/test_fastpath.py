"""Fast-engine parity and batched-estimator equivalence tests.

The fast engine (``repro.cachesim.fastpath``) must be a BIT-EXACT twin of
the reference scalar loop for every model-based policy: same SimResult
(including the raw float/int accumulators, not just the rounded dict) and
the same end-of-run system state.
"""
import dataclasses

import numpy as np
import pytest

from repro.cachesim import SimConfig, Simulator, get_trace
from repro.cachesim.traces import TRACES
from repro.core.estimator import QEstimator

N = 8_000
POLICIES = ("fna", "fno", "pi", "hocs")


def _run_pair(policy, trace, **cfg_kw):
    costs = cfg_kw.pop("costs", (2.0, 2.0, 2.0) if policy == "hocs"
                       else (1.0, 2.0, 3.0))
    cfg_kw.setdefault("update_interval", 200)
    cfg_kw.setdefault("est_interval", 25)
    base = SimConfig(cache_size=1_000, costs=costs, policy=policy, **cfg_kw)
    ref_sim = Simulator(dataclasses.replace(base, engine="reference"))
    fast_sim = Simulator(dataclasses.replace(base, engine="fast"))
    return ref_sim, ref_sim.run(trace), fast_sim, fast_sim.run(trace)


def _assert_results_identical(ref, fast):
    assert fast.to_dict() == ref.to_dict()
    # stronger than to_dict: the raw accumulators are bit-identical
    assert fast.total_cost == ref.total_cost
    for f in ("n_requests", "hits", "pos_accesses", "neg_accesses",
              "fn_events", "fn_opportunities", "fp_events",
              "fp_opportunities", "resident"):
        assert getattr(fast, f) == getattr(ref, f), f


@pytest.mark.parametrize("trace_name", TRACES)
@pytest.mark.parametrize("policy", POLICIES)
def test_fast_reference_parity(policy, trace_name):
    trace = get_trace(trace_name, N, seed=7)
    _, ref, _, fast = _run_pair(policy, trace)
    _assert_results_identical(ref, fast)


def test_fast_reference_state_parity():
    """End-of-run SYSTEM state matches too: LRU contents and order, CBF
    counters, stale bitmaps, FP/FN estimates, q-estimates, versions."""
    trace = get_trace("gradle", N, seed=3)
    ref_sim, _, fast_sim, _ = _run_pair("fna", trace)
    for rn, fn_ in zip(ref_sim.nodes, fast_sim.nodes):
        assert list(rn.lru.keys()) == list(fn_.lru.keys())
        assert np.array_equal(rn.ind.cbf.counters, fn_.ind.cbf.counters)
        assert fn_.ind.cbf.counters.dtype == np.uint8
        assert np.array_equal(rn.ind.stale, fn_.ind.stale)
        assert rn.ind.fp_est == fn_.ind.fp_est
        assert rn.ind.fn_est == fn_.ind.fn_est
        assert rn.version == fn_.version
        assert (rn._since_adv, rn._since_est) == (fn_._since_adv, fn_._since_est)
    for rq, fq in zip(ref_sim.q_est, fast_sim.q_est):
        assert rq.q == fq.q
        assert rq.version == fq.version
        assert (rq._count, rq._positives) == (fq._count, fq._positives)


def test_hocs_parity_many_caches():
    """n_caches >= 8 exercises the pooled-estimate summation path where
    np.sum's pairwise accumulation would diverge from the reference
    loop's left-to-right Python sum in the last ulp."""
    trace = get_trace("gradle", 3_000, seed=13)
    _, ref, _, fast = _run_pair("hocs", trace, n_caches=9,
                                costs=(2.0,) * 9)
    _assert_results_identical(ref, fast)


@pytest.mark.parametrize("policy", ("fna", "fno"))
def test_fast_parity_with_exhaustive_subroutine(policy):
    """The batched 2^n-subset enumeration path (``exhaustive_tables``)
    must match the reference loop's scalar exhaustive calls for both the
    all-candidates and the positive-only policies."""
    trace = get_trace("gradle", 5_000, seed=11)
    _, ref, _, fast = _run_pair(policy, trace, alg="exhaustive")
    _assert_results_identical(ref, fast)


def test_fast_parity_exhaustive_four_caches():
    trace = get_trace("scarab", 4_000, seed=3)
    _, ref, _, fast = _run_pair("fna", trace, alg="exhaustive", n_caches=4,
                                costs=(1.0, 2.0, 3.0, 1.5))
    _assert_results_identical(ref, fast)


def test_exhaustive_tables_match_scalar_exhaustive():
    """The batched subset-DP tables are decision-identical to the scalar
    2^n enumeration over a (version x pattern) grid, including the
    CS_FNO candidate restriction, and the rho-matrix variant honours
    arbitrary ``allowed`` masks."""
    from repro.core.batched import exhaustive_tables, rho_exhaustive_tables
    from repro.core.policies import exhaustive

    rng = np.random.default_rng(2)
    n, v = 4, 9
    costs = rng.uniform(0.5, 5.0, n)
    pi = rng.uniform(0.0, 1.0, (v, n))
    nu = rng.uniform(0.0, 1.0, (v, n))
    m = 100.0
    fna_tab = exhaustive_tables(costs, pi, nu, m)
    fno_tab = exhaustive_tables(costs, pi, nu, m, fno=True)
    for vi in range(v):
        for p in range(1 << n):
            rhos = [pi[vi, j] if (p >> j) & 1 else nu[vi, j]
                    for j in range(n)]
            want = 0
            for j in exhaustive(costs, rhos, m):
                want |= 1 << j
            assert fna_tab[vi, p] == want, (vi, p)
            pos = [j for j in range(n) if (p >> j) & 1]
            want_fno = 0
            if pos:
                sub = exhaustive([costs[j] for j in pos],
                                 [pi[vi, j] for j in pos], m)
                for t in sub:
                    want_fno |= 1 << pos[t]
            assert fno_tab[vi, p] == want_fno, (vi, p)
    # rho-matrix variant: random rho rows, random allowed masks
    rhos = rng.uniform(0.0, 1.0, (301, n))
    allowed = rng.integers(0, 1 << n, 301, dtype=np.int64)
    pow2 = (1 << np.arange(n)).astype(np.int64)
    got = rho_exhaustive_tables(costs, rhos, m, allowed=allowed) @ pow2
    for i in range(rhos.shape[0]):
        best_mask, best_cost = 0, m
        for mask in range(1, 1 << n):
            if mask & ~int(allowed[i]):
                continue
            c = sum(costs[j] for j in range(n) if mask >> j & 1)
            pr = m
            for j in range(n):
                if mask >> j & 1:
                    pr *= rhos[i, j]
            if c + pr < best_cost - 1e-12:
                best_cost, best_mask = c + pr, mask
        assert got[i] == best_mask, i


def test_fast_parity_across_update_intervals():
    """Advertisement-epoch slicing must stay exact from fresh (tiny
    interval) to very stale indicators."""
    trace = get_trace("gradle", N, seed=5)
    for interval in (16, 100, 1_000, 5_000):
        _, ref, _, fast = _run_pair("fna", trace, update_interval=interval)
        _assert_results_identical(ref, fast)


def test_fna_cal_fast_parity_smoke():
    """fna_cal mutates its EWMAs per probe (no frozen-view invariant), so
    it replays via the speculative segmented engine
    (``repro.cachesim.fna_cal_fast``) — still bit-exact.  Full coverage
    lives in ``tests/test_fna_cal_fast.py``."""
    trace = get_trace("gradle", 5_000, seed=2)
    cfg = SimConfig(cache_size=1_000, update_interval=200, policy="fna_cal")
    ref = Simulator(dataclasses.replace(cfg, engine="reference")).run(trace)
    fast = Simulator(dataclasses.replace(cfg, engine="fast")).run(trace)
    _assert_results_identical(ref, fast)


def test_qestimator_batch_equivalence():
    """observe_batch over arbitrary chunkings == per-element observe."""
    rng = np.random.default_rng(0)
    obs = rng.random(1_037) < 0.37
    scalar = QEstimator(horizon=100, delta=0.25)
    for o in obs:
        scalar.observe(bool(o))
    for split in ([3, 10, 250, 251, 600, 1000], [100], [1036], []):
        batched = QEstimator(horizon=100, delta=0.25)
        crossed = 0
        for chunk in np.array_split(obs, split):
            crossed += batched.observe_batch(chunk)
        assert batched.q == scalar.q
        assert batched.version == scalar.version == crossed
        assert (batched._count, batched._positives) == \
            (scalar._count, scalar._positives)


def test_selection_tables_match_scalar_ds_pgm():
    """The batched JAX decision tables are bit-identical to the scalar
    DS_PGM path, including the CS_FNO candidate restriction."""
    from repro.core.batched import selection_tables
    from repro.core.policies import ds_pgm

    rng = np.random.default_rng(1)
    n, v = 4, 17
    costs = rng.uniform(0.5, 5.0, n)
    pi = rng.uniform(0.0, 1.0, (v, n))
    nu = rng.uniform(0.0, 1.0, (v, n))
    m = 100.0
    fna_tab = selection_tables(costs, pi, nu, m)
    fno_tab = selection_tables(costs, pi, nu, m, fno=True)
    for vi in range(v):
        for p in range(1 << n):
            rhos = [pi[vi, j] if (p >> j) & 1 else nu[vi, j] for j in range(n)]
            assert sorted(np.nonzero(fna_tab[vi, p])[0]) == \
                ds_pgm(costs, rhos, m)
            pos = [j for j in range(n) if (p >> j) & 1]
            want = []
            if pos:
                sub = ds_pgm([costs[j] for j in pos],
                             [pi[vi, j] for j in pos], m)
                want = sorted(pos[t] for t in sub)
            assert sorted(np.nonzero(fno_tab[vi, p])[0]) == want
