"""Pallas kernel tests: shape/dtype sweeps, allclose vs the pure-jnp oracles.

All kernels run in interpret mode on CPU (the kernel bodies execute in
Python) — this validates BlockSpec indexing, scratch carry semantics, and
numerics; the same code path compiles for the TPU target.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bloom import bloom_probe, bloom_probe_ref, build_indicator
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.ssd import ssd_ref, ssd_scan


# ---------------------------------------------------------------------------
# bloom
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mbytes,k,n_caches,n_keys", [
    (2048, 7, 2, 256),
    (4096, 10, 5, 512),
    (2048, 3, 1, 300),
])
def test_bloom_probe_matches_ref(mbytes, k, n_caches, n_keys):
    m = mbytes * 8
    rng = np.random.default_rng(42)
    bits = []
    members = []
    for j in range(n_caches):
        ks = jnp.asarray(rng.integers(0, 10_000_000, 400))
        members.append(np.asarray(ks))
        bits.append(np.asarray(build_indicator(ks, m, k, seed=j)))
    bits = jnp.asarray(np.stack(bits))
    keys = jnp.asarray(rng.integers(0, 20_000_000, n_keys).astype(np.int32))
    out = bloom_probe(bits, keys, k=k)
    ref = bloom_probe_ref(bits, keys, k)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_bloom_probe_no_false_negatives():
    mbytes, k = 2048, 8
    rng = np.random.default_rng(1)
    member = jnp.asarray(rng.integers(0, 1_000_000, 512).astype(np.int32))
    bits = jnp.asarray(build_indicator(member, mbytes * 8, k, seed=0))[None]
    out = bloom_probe(bits, member, k=k)
    assert bool(jnp.all(out == 1))  # a fresh Bloom filter never FNs


def test_bloom_probe_fp_rate_sane():
    mbytes, k, n_items = 2048, 10, 1000  # bpe ~ 16
    rng = np.random.default_rng(2)
    member = jnp.asarray(rng.integers(0, 1_000_000, n_items))
    bits = jnp.asarray(build_indicator(member, mbytes * 8, k, seed=0))[None]
    probes = jnp.asarray(rng.integers(2_000_000, 9_000_000, 4096).astype(np.int32))
    fp = float(jnp.mean(bloom_probe(bits, probes, k=k).astype(jnp.float32)))
    assert fp < 0.01, fp  # designed fp ~ 0.5^10


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,hq,hkv,d", [
    (2, 256, 4, 2, 64),
    (1, 512, 8, 8, 64),
    (2, 256, 4, 1, 128),
    (1, 384, 6, 3, 64),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(b, s, hq, hkv, d, causal):
    ks = jax.random.split(jax.random.PRNGKey(b * 100 + s), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32)
    out = flash_attention(q, k, v, causal=causal)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 256, 2, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 256, 2, 64), jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True)
    ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=3e-2, atol=3e-2)


def test_flash_attention_block_shape_invariance():
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (1, 512, 2, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 512, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 512, 2, 64), jnp.float32)
    a = flash_attention(q, k, v, block_q=128, block_k=128)
    b = flash_attention(q, k, v, block_q=64, block_k=256)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ssd
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 256, 4, 64, 64, 128),
    (1, 128, 2, 32, 16, 32),
    (1, 512, 1, 64, 128, 128),
])
def test_ssd_matches_sequential_ref(b, s, h, p, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(s + h), 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)) - 1.0)
    A = -jnp.exp(jax.random.uniform(ks[2], (h,), minval=-1.0, maxval=1.0))
    B = jax.random.normal(ks[3], (b, s, n), jnp.float32)
    C = jax.random.normal(ks[4], (b, s, n), jnp.float32)
    y_k, st_k = ssd_scan(x, dt, A, B, C, chunk=chunk)
    y_r, st_r = ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_r),
                               rtol=2e-3, atol=2e-3)


def test_ssd_chunk_invariance():
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    b, s, h, p, n = 1, 256, 2, 32, 32
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)) - 1.0)
    A = -jnp.exp(jax.random.uniform(ks[2], (h,), minval=-1.0, maxval=1.0))
    B = jax.random.normal(ks[3], (b, s, n), jnp.float32)
    C = jax.random.normal(ks[4], (b, s, n), jnp.float32)
    y1, s1 = ssd_scan(x, dt, A, B, C, chunk=64)
    y2, s2 = ssd_scan(x, dt, A, B, C, chunk=256)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-3, atol=2e-3)


def test_ssd_model_path_consistency():
    """models.ssm.ssd_chunked (the jnp path the dry-run lowers) agrees with
    the Pallas kernel on the same inputs."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(11), 5)
    b, s, h, p, n = 1, 256, 2, 32, 32
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)) - 1.0)
    A = -jnp.exp(jax.random.uniform(ks[2], (h,), minval=-1.0, maxval=1.0))
    B = jax.random.normal(ks[3], (b, s, n), jnp.float32)
    C = jax.random.normal(ks[4], (b, s, n), jnp.float32)
    y_m, st_m = ssd_chunked(x, dt, A, B, C, chunk=64)
    y_k, st_k = ssd_scan(x, dt, A, B, C, chunk=64)
    np.testing.assert_allclose(np.asarray(y_m), np.asarray(y_k), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st_m), np.asarray(st_k), rtol=2e-3, atol=2e-3)
