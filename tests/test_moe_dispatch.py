"""MoE dispatch-path correctness: sort-based capacity dispatch must match the
dense oracle when capacity is ample, and degrade gracefully (drops) when not."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import get_model, make_concrete_batch


@pytest.mark.parametrize("arch", ["qwen3-moe-30b-a3b", "granite-moe-3b-a800m"])
def test_dispatch_matches_dense_with_ample_capacity(arch):
    cfg_d = get_config(arch).reduced()  # dense oracle
    cfg_s = dataclasses.replace(cfg_d, moe_mode="dispatch", capacity_factor=8.0)
    m_d, m_s = get_model(cfg_d), get_model(cfg_s)
    params = m_d.init(jax.random.PRNGKey(0))
    batch = make_concrete_batch(cfg_d, 2, 32, jax.random.PRNGKey(1), with_labels=False)
    ld = jax.jit(m_d.forward)(params, batch)
    ls = jax.jit(m_s.forward)(params, batch)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(ls), rtol=2e-4, atol=2e-4)


def test_dispatch_with_expert_padding():
    """Padded experts must never receive tokens (masked router)."""
    cfg = get_config("granite-moe-3b-a800m").reduced()          # 8 experts
    cfg_pad = dataclasses.replace(cfg, expert_pad=16)           # padded to 16
    m, mp = get_model(cfg), get_model(cfg_pad)
    params_p = mp.init(jax.random.PRNGKey(0))
    batch = make_concrete_batch(cfg, 2, 32, jax.random.PRNGKey(1), with_labels=False)
    logits = jax.jit(mp.forward)(params_p, batch)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # routing probabilities for padded experts are exactly zero
    x = params_p["embed"][batch["tokens"]]
    router = jax.tree.leaves({"r": params_p["layers"]["moe"]["router"]})[0][0]
    probs = jax.nn.softmax(jnp.where(jnp.arange(16) >= 8, -1e30,
                                     x.astype(jnp.float32) @ router), axis=-1)
    assert float(probs[..., 8:].max()) == 0.0


def test_dispatch_drops_bounded():
    """With cf=1.0 and adversarially-skewed routing, output stays finite and
    a majority of token mass is still served."""
    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    cfg = dataclasses.replace(cfg, moe_mode="dispatch", capacity_factor=1.0)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_concrete_batch(cfg, 2, 32, jax.random.PRNGKey(1), with_labels=False)
    logits = jax.jit(m.forward)(params, batch)
    assert bool(jnp.all(jnp.isfinite(logits)))
