"""Fault tolerance: straggler detection, preemption, elastic mesh, and the
preempt->checkpoint->resume contract end to end (subprocess)."""
import os
import subprocess
import sys
import tempfile
from pathlib import Path

import pytest

from repro.distributed.compression import compress_with_error_feedback, quantize_dequantize
from repro.distributed.ft import PreemptionHandler, StepTimer, elastic_mesh

ROOT = Path(__file__).resolve().parent.parent


def test_step_timer_flags_stragglers():
    t = StepTimer(threshold=2.0, warmup=3)
    for i in range(10):
        t.observe(i, 0.1)
    assert not t.stragglers
    t.observe(10, 0.5)
    assert t.stragglers == [10]
    # EMA not poisoned: the next normal step is not flagged
    t.observe(11, 0.1)
    assert t.stragglers == [10]


def test_preemption_handler_trigger():
    h = PreemptionHandler()
    assert not h.preempted
    h.trigger()
    assert h.preempted


def test_elastic_mesh_single_device():
    mesh = elastic_mesh(model_dim=1)
    assert dict(mesh.shape) == {"data": 1, "model": 1}
    with pytest.raises(RuntimeError):
        elastic_mesh(model_dim=64)


def test_quantize_dequantize_error_bounded():
    import jax
    import jax.numpy as jnp
    g = jax.random.normal(jax.random.PRNGKey(0), (5000,))
    gq = quantize_dequantize(g)
    err = jnp.abs(g - gq).max()
    scale = jnp.abs(g).max() / 127.0
    assert float(err) <= float(scale) * 1.01


def test_error_feedback_accumulates():
    import jax.numpy as jnp
    g = {"g": jnp.full((1024,), 1e-4)}   # tiny gradient, big quant noise
    ef = {"g": jnp.zeros((1024,))}
    total = jnp.zeros((1024,))
    for _ in range(50):
        ghat, ef = compress_with_error_feedback(g, ef)
        total = total + ghat["g"]
    # with EF the long-run average converges to the true gradient
    assert float(jnp.abs(total / 50 - 1e-4).max()) < 5e-5


@pytest.mark.slow
def test_preempt_resume_bit_exact():
    env = dict(os.environ, PYTHONPATH="src")
    common = ["--arch", "smollm-135m", "--reduced", "--steps", "14",
              "--batch", "2", "--seq", "32", "--ckpt-interval", "4",
              "--log-every", "1"]

    def run(extra):
        return subprocess.run(
            [sys.executable, "-m", "repro.launch.train", *common, *extra],
            cwd=ROOT, env=env, capture_output=True, text=True)

    def final_loss(out):
        for line in reversed(out.splitlines()):
            if "final loss" in line:
                return line.rsplit(" ", 1)[-1]
        raise AssertionError(out)

    with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
        ref = run(["--ckpt-dir", d1])
        assert ref.returncode == 0, ref.stderr[-2000:]
        r1 = run(["--ckpt-dir", d2, "--kill-at", "7"])
        assert r1.returncode == 42, (r1.returncode, r1.stderr[-2000:])
        r2 = run(["--ckpt-dir", d2, "--resume"])
        assert r2.returncode == 0, r2.stderr[-2000:]
        assert final_loss(r2.stdout) == final_loss(ref.stdout)
