"""End-to-end simulator tests reproducing the paper's qualitative claims."""
import dataclasses

import numpy as np
import pytest

from repro.cachesim import SimConfig, Simulator, get_trace
from repro.cachesim.simulator import run_policies

N_REQ = 30_000


@pytest.fixture(scope="module")
def gradle_trace():
    return get_trace("gradle", N_REQ, seed=1)


@pytest.fixture(scope="module")
def wiki_trace():
    return get_trace("wiki", N_REQ, seed=1)


def test_pi_is_lower_bound(gradle_trace):
    base = SimConfig(cache_size=2000, update_interval=200)
    res = run_policies(gradle_trace, base)
    assert res["pi"].mean_cost <= res["fna"].mean_cost + 1e-9
    assert res["pi"].mean_cost <= res["fno"].mean_cost + 1e-9


def test_fna_beats_fno_under_staleness(gradle_trace):
    """Paper Sec. V-C: with large update intervals on a recency-biased
    workload, FNA's negative accesses recover hits FNO forfeits."""
    base = SimConfig(cache_size=2000, update_interval=1000)
    res = run_policies(gradle_trace, base, policies=("fna", "fno"))
    assert res["fna"].neg_accesses > 0
    assert res["fna"].mean_cost < res["fno"].mean_cost, (
        res["fna"].to_dict(), res["fno"].to_dict())


def test_fna_matches_fno_with_fresh_indicators(wiki_trace):
    """With frequent updates the FN ratio is tiny and the policies agree
    (paper Fig. 4: similar performance up to interval ~128)."""
    base = SimConfig(cache_size=2000, update_interval=16)
    res = run_policies(wiki_trace, base, policies=("fna", "fno"))
    assert abs(res["fna"].mean_cost - res["fno"].mean_cost) / res["fno"].mean_cost < 0.05


def test_fn_ratio_grows_with_update_interval(gradle_trace):
    """Fig. 1: staleness-induced FN ratio increases with the interval."""
    ratios = []
    for interval in (50, 400, 3200):
        cfg = SimConfig(cache_size=2000, update_interval=interval, policy="fno")
        res = Simulator(cfg).run(gradle_trace)
        ratios.append(res.fn_ratio)
    assert ratios[0] < ratios[1] < ratios[2], ratios
    assert ratios[2] > 0.05  # the effect is material, not epsilon


def test_identical_cache_dynamics_across_policies(gradle_trace):
    """Hash placement makes hit opportunities policy-independent."""
    base = SimConfig(cache_size=2000, update_interval=500)
    res = run_policies(gradle_trace, base)
    assert res["fna"].fn_opportunities == res["fno"].fn_opportunities == \
        res["pi"].fn_opportunities


def test_explicit_costs_mismatch_raises():
    """An explicitly-passed costs vector whose length mismatches n_caches
    is a config typo and must fail loudly (it used to be silently
    replaced with a synthetic (1, 2, 3, ...) vector)."""
    with pytest.raises(ValueError, match="costs"):
        SimConfig(n_caches=4, costs=(1.0, 2.0))
    with pytest.raises(ValueError, match="expected n_caches=2"):
        SimConfig(n_caches=2, costs=(1.0, 2.0, 4.0))
    # the class default still synthesises one cost per cache
    assert SimConfig(n_caches=5).costs == (1.0, 2.0, 3.0, 1.0, 2.0)
    assert SimConfig(n_caches=1).costs == (1.0,)
    assert SimConfig().costs == (1.0, 2.0, 3.0)
    # matched explicit vectors pass through untouched (fig7-style cells)
    assert SimConfig(n_caches=4, costs=(2.0,) * 4).costs == (2.0,) * 4


def test_idx_memo_stays_bounded():
    """The reference engine's per-cache scalar hash memo must stay
    O(cache size) even when the trace streams fresh ids through a small
    cache (it used to grow one entry per distinct key and leak hundreds
    of MB on million-request recency runs)."""
    trace = np.arange(20_000, dtype=np.int64)    # every key distinct
    cfg = SimConfig(n_caches=2, costs=(1.0, 2.0), cache_size=200,
                    update_interval=100, engine="reference")
    sim = Simulator(cfg)
    sim.run(trace)
    for nd in sim.nodes:
        assert len(nd._idx_memo) <= nd._idx_memo_cap
        assert nd._idx_memo_cap <= max(2 * 200, 1024)


def test_exhaustive_subroutine_no_worse(gradle_trace):
    base = SimConfig(cache_size=2000, update_interval=1000, alg="exhaustive")
    res_ex = run_policies(gradle_trace[:10_000], base, policies=("fna",))
    base2 = dataclasses.replace(base, alg="ds_pgm")
    res_pgm = run_policies(gradle_trace[:10_000], base2, policies=("fna",))
    # ds_pgm is near-optimal in practice; allow 2%
    assert res_pgm["fna"].mean_cost <= res_ex["fna"].mean_cost * 1.02
