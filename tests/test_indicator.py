"""Bloom filter / CBF / staleness-estimation tests (Eqs. 7-8)."""
import math

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property-based tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import CountingBloomFilter, StaleIndicatorPair, optimal_k, theoretical_fp
from repro.core.indicator import hash_indices


def test_no_false_negatives_when_fresh():
    cbf = CountingBloomFilter(m=14 * 1000, k=optimal_k(14))
    keys = np.arange(1000)
    for x in keys:
        cbf.add(int(x))
    assert all(cbf.query(int(x)) for x in keys)


def test_remove_restores_state():
    cbf = CountingBloomFilter(m=2048, k=7, seed=3)
    before = cbf.counters.copy()
    for x in range(100):
        cbf.add(x)
    for x in range(100):
        cbf.remove(x)
    np.testing.assert_array_equal(cbf.counters, before)


def test_fp_rate_close_to_theory():
    bpe, n = 14.0, 2000
    k = optimal_k(bpe)
    cbf = CountingBloomFilter(m=int(bpe * n), k=k, seed=1)
    for x in range(n):
        cbf.add(x)
    probes = np.arange(10_000) + 10_000_000
    fp = sum(cbf.query(int(x)) for x in probes) / len(probes)
    theory = theoretical_fp(bpe, k)
    assert fp < 4 * theory + 2e-3, (fp, theory)


def test_theoretical_fp_explicit_k():
    """``k`` is honoured literally: ``k=0`` means a degenerate no-hash
    filter (always positive), not "substitute the optimal k" (the old
    ``k or optimal_k(bpe)`` silently rewrote an explicit 0), and only
    ``k=None`` picks the optimum."""
    bpe = 14.0
    assert theoretical_fp(bpe) == theoretical_fp(bpe, optimal_k(bpe))
    assert theoretical_fp(bpe, 0) == 1.0
    assert theoretical_fp(bpe, 1) == 1.0 - math.exp(-1.0 / bpe)
    assert theoretical_fp(bpe, 2) != theoretical_fp(bpe)


def test_hash_indices_deterministic_and_spread():
    idx1 = hash_indices(np.arange(100), k=8, m=4096, seed=5)
    idx2 = hash_indices(np.arange(100), k=8, m=4096, seed=5)
    np.testing.assert_array_equal(idx1, idx2)
    idx3 = hash_indices(np.arange(100), k=8, m=4096, seed=6)
    assert (idx1 != idx3).any()
    # roughly uniform occupancy
    counts = np.bincount(idx1.reshape(-1), minlength=4096)
    assert counts.max() <= 8


def test_staleness_fn_estimate_bounds_empirical():
    """Eq. (7) models a resident item's bits as uniform over the B1 set
    bits, so when staleness is concentrated in few (new) items it
    OVERESTIMATES the population FN ratio while the new items themselves
    are ~always false-negative.  (The paper explicitly calls Eqs. (7)-(8)
    'only estimations'.)  We assert the documented sandwich:
    true overall FN <= Eq.(7) estimate, and new items are FN-prone."""
    n, bpe = 2000, 14.0
    k = optimal_k(bpe)
    pair = StaleIndicatorPair(m=int(bpe * n), k=k, seed=2)
    for x in range(n):
        pair.cbf.add(x)
    pair.advertise()
    # stale replica now matches; insert 400 new items (20% churn)
    for x in range(n, n + 400):
        pair.cbf.add(x)
    fp_est, fn_est = pair.estimate_rates()
    new_items = list(range(n, n + 400))
    measured_new = sum(not pair.stale_query(x) for x in new_items) / len(new_items)
    overall = sum(not pair.stale_query(x) for x in range(n + 400)) / (n + 400)
    assert measured_new > 0.9            # fresh items invisible to stale replica
    assert overall - 0.02 <= fn_est <= 1.0, (fn_est, overall)
    assert fn_est > 0.3                  # materially non-zero signal for CS_FNA


def test_staleness_fp_estimate_tracks_evictions():
    """Evicted-but-still-advertised items inflate FP; Eq. (8) sees it."""
    n, bpe = 2000, 8.0
    k = optimal_k(bpe)
    pair = StaleIndicatorPair(m=int(bpe * n), k=k, seed=4)
    for x in range(n):
        pair.cbf.add(x)
    pair.advertise()
    fp0, _ = pair.estimate_rates()
    for x in range(800):  # evict 40%
        pair.cbf.remove(x)
    fp1, _ = pair.estimate_rates()
    # evicted items still hit in the stale bitmap -> measured stale-FP high
    measured = sum(pair.stale_query(x) for x in range(800)) / 800
    assert measured > 0.9
    # Eq. (8) estimates the probability for a RANDOM (hash-uniform) probe —
    # it must not decrease after evictions made the stale filter staler.
    assert fp1 >= fp0 - 1e-9


@settings(max_examples=30, deadline=None)
@given(n_new=st.integers(0, 500))
def test_fn_estimate_monotone_in_staleness(n_new):
    """More un-advertised insertions => higher estimated FN (property)."""
    n, bpe = 1000, 10.0
    pair = StaleIndicatorPair(m=int(bpe * n), k=optimal_k(bpe), seed=7)
    for x in range(n):
        pair.cbf.add(x)
    pair.advertise()
    for x in range(n, n + n_new):
        pair.cbf.add(x)
    _, fn = pair.estimate_rates()
    pair2 = StaleIndicatorPair(m=int(bpe * n), k=optimal_k(bpe), seed=7)
    for x in range(n):
        pair2.cbf.add(x)
    pair2.advertise()
    for x in range(n, n + n_new + 100):
        pair2.cbf.add(x)
    _, fn2 = pair2.estimate_rates()
    assert fn2 >= fn - 1e-9
