"""Content-addressed artifact store (``repro.cachesim.store``) suite.

The store's contract has three load-bearing claims, each pinned here:

  * **bit-identity** — a store-hydrated ``SystemTrace`` replays exactly
    like cold compute, across every golden scenario x policy, and the
    ``run_grid(workers=N)`` parallel path is bit-identical to serial;
  * **structural invalidation** — any input change (a trace byte, a
    system-side config field, the schema version) misses by
    construction; corrupt/truncated entries read as misses and rebuild;
  * **durability** — concurrent writers racing on one entry leave a
    loadable archive (atomic ``os.replace``).

Plus the satellite integrations: the tracefiles parse cache routed
through a ``REPRO_STORE`` root (with legacy next-to-source fallback) and
the ``tools/store_tool.py`` maintenance CLI.
"""
import dataclasses
import multiprocessing
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import repro.cachesim.store as store_mod
import repro.cachesim.systemstate as systemstate
from repro.cachesim import (
    ArtifactStore,
    SimConfig,
    SimResult,
    Simulator,
    get_scenario,
)
from repro.cachesim.scenarios import GOLDEN_SCENARIOS, run_scenario
from repro.cachesim.sweep import _sweep_worker, run_grid
from repro.cachesim.systemstate import SystemTrace
from repro.cachesim.traces import get_trace

RESULT_FIELDS = tuple(f.name for f in dataclasses.fields(SimResult))

PENALTIES = (25.0, 100.0, 500.0)


def _assert_grids_identical(a, b):
    assert set(a) == set(b)
    for key, cell in a.items():
        assert set(cell) == set(b[key])
        for p, res in cell.items():
            for f in RESULT_FIELDS:
                assert getattr(res, f) == getattr(b[key][p], f), (key, p, f)


def _small_grid(store=None, workers=0, trace_n=5_000, **base_kw):
    traces = {"gradle": get_trace("gradle", trace_n, seed=0)}
    base = SimConfig(engine="fast", update_interval=200, **base_kw)
    return run_grid(traces, base, "miss_penalty", PENALTIES,
                    policies=("fna", "fno", "pi"), store=store,
                    workers=workers)


# ---------------------------------------------------------------------------
# Serialisation round-trip
# ---------------------------------------------------------------------------

def test_to_arrays_roundtrip_is_lossless():
    """from_arrays(to_arrays(st)) re-serialises byte-for-byte: every
    array the replay phase consumes survives the round trip exactly."""
    trace = get_trace("gradle", 5_000, seed=0)
    cfg = SimConfig(engine="fast", update_interval=200)
    st = SystemTrace.compute(Simulator(cfg), trace)
    arrays = st.to_arrays()
    st2 = SystemTrace.from_arrays(arrays, key=st.key, trace=st._trace)
    arrays2 = st2.to_arrays()
    assert set(arrays) == set(arrays2)
    for k in arrays:
        a, b = np.asarray(arrays[k]), np.asarray(arrays2[k])
        assert a.dtype == b.dtype and a.shape == b.shape, k
        assert a.tobytes() == b.tobytes(), k
    assert st2.key == st.key and st2.from_fresh == st.from_fresh
    assert st2.plan_cache == {}


# ---------------------------------------------------------------------------
# Hit / miss / bit-identity through the grid runner
# ---------------------------------------------------------------------------

def test_store_hit_skips_sweep_and_is_bit_identical(tmp_path):
    cold = _small_grid()
    store = ArtifactStore(tmp_path / "store")
    populated = _small_grid(store=store)
    before = systemstate.SWEEPS_COMPUTED
    warm = _small_grid(store=store)
    assert systemstate.SWEEPS_COMPUTED == before, \
        "warm run recomputed a stored sweep"
    assert store.stats["sweep_hits"] >= 1
    assert store.stats["table_hits"] >= 1, \
        "warm run rebuilt tables instead of preloading them"
    _assert_grids_identical(populated, cold)
    _assert_grids_identical(warm, cold)


def test_store_invalidates_on_trace_byte_change(tmp_path):
    store = ArtifactStore(tmp_path)
    trace = np.asarray(get_trace("gradle", 3_000, seed=0), np.uint64)
    cfg = SimConfig(engine="fast")
    st = SystemTrace.compute(Simulator(cfg), trace)
    store.save_sweep(st)
    assert store.load_sweep(trace, st.key) is not None
    mutated = trace.copy()
    mutated[1_500] += 1
    assert store.load_sweep(mutated, st.key) is None
    assert not store.has_sweep(store.trace_digest(mutated), st.key)


def test_store_invalidates_on_system_key_change(tmp_path):
    store = ArtifactStore(tmp_path)
    trace = np.asarray(get_trace("gradle", 3_000, seed=0), np.uint64)
    cfg = SimConfig(engine="fast", update_interval=200)
    st = SystemTrace.compute(Simulator(cfg), trace)
    store.save_sweep(st)
    other = SystemTrace.system_key(
        SimConfig(engine="fast", update_interval=400))
    assert other != st.key
    assert store.load_sweep(trace, st.key) is not None
    assert store.load_sweep(trace, other) is None


def test_store_invalidates_on_schema_bump(tmp_path, monkeypatch):
    store = ArtifactStore(tmp_path)
    trace = np.asarray(get_trace("gradle", 3_000, seed=0), np.uint64)
    st = SystemTrace.compute(Simulator(SimConfig(engine="fast")), trace)
    store.save_sweep(st)
    assert store.load_sweep(trace, st.key) is not None
    monkeypatch.setattr(store_mod, "SCHEMA_VERSION",
                        store_mod.SCHEMA_VERSION + 1)
    assert store.load_sweep(trace, st.key) is None


def test_corrupt_entry_reads_as_miss_and_rebuilds(tmp_path):
    store = ArtifactStore(tmp_path)
    trace = np.asarray(get_trace("gradle", 3_000, seed=0), np.uint64)
    st = SystemTrace.compute(Simulator(SimConfig(engine="fast")), trace)
    store.save_sweep(st)
    entries = list((tmp_path / "sweeps").glob("*.npz"))
    assert len(entries) == 1
    # truncate mid-archive: np.load must fail, not return garbage
    data = entries[0].read_bytes()
    entries[0].write_bytes(data[:len(data) // 2])
    assert store.load_sweep(trace, st.key) is None
    assert store.stats["corrupt_dropped"] == 1
    assert not entries[0].exists(), "corrupt entry not unlinked"
    store.save_sweep(st)                          # rebuild lands cleanly
    hydrated = store.load_sweep(trace, st.key)
    assert hydrated is not None
    assert hydrated.to_arrays()["pats"].tobytes() == \
        st.to_arrays()["pats"].tobytes()


def test_foreign_meta_reads_as_miss_not_corruption(tmp_path):
    """A colliding/foreign file whose archive IS loadable but whose meta
    differs must read as a plain miss and stay on disk untouched."""
    store = ArtifactStore(tmp_path)
    digest = "0" * 64
    key = (3,)
    meta = store.sweep_meta(digest, key)
    path = store._path("sweep", meta)
    store._write(path, {"pats": np.arange(3)}, "some-other-meta")
    assert store._read(path, meta) is None
    assert store.stats["corrupt_dropped"] == 0
    assert path.exists()


# ---------------------------------------------------------------------------
# Concurrency
# ---------------------------------------------------------------------------

def test_concurrent_writers_leave_loadable_entry(tmp_path):
    """Two spawn processes race _sweep_worker on the SAME (trace, cfg):
    both must succeed, and the surviving entry must verify + hydrate."""
    trace = np.asarray(get_trace("gradle", 3_000, seed=0), np.uint64)
    cfg = SimConfig(engine="fast", update_interval=200)
    ctx = multiprocessing.get_context("spawn")
    with ctx.Pool(2) as pool:
        results = pool.starmap(_sweep_worker,
                               [(str(tmp_path), trace, cfg)] * 2)
    assert set(results) <= {"hit", "computed"} and "computed" in results
    store = ArtifactStore(tmp_path)
    assert all(ok for _, ok in store.verify())
    st = store.load_sweep(trace, SystemTrace.system_key(cfg))
    assert st is not None
    ref = SystemTrace.compute(Simulator(cfg), trace)
    assert st.to_arrays()["pats"].tobytes() == \
        ref.to_arrays()["pats"].tobytes()


def test_run_grid_workers_bit_identical_to_serial(tmp_path):
    traces = {"gradle": get_trace("gradle", 5_000, seed=0)}
    base = SimConfig(engine="fast")
    serial = run_grid(traces, base, "update_interval", (100, 400),
                      policies=("fna", "fno"))
    store = ArtifactStore(tmp_path)
    before = systemstate.SWEEPS_COMPUTED
    parallel = run_grid(traces, base, "update_interval", (100, 400),
                        policies=("fna", "fno"), store=store, workers=2)
    _assert_grids_identical(parallel, serial)
    # the farm computed both sweeps out-of-process; the parent's serial
    # pass hydrated them from the store
    assert systemstate.SWEEPS_COMPUTED == before
    assert store.stats["sweep_hits"] == 2


def test_run_grid_workers_without_store_uses_ephemeral_root():
    traces = {"gradle": get_trace("gradle", 5_000, seed=0)}
    base = SimConfig(engine="fast")
    serial = run_grid(traces, base, "update_interval", (100, 400),
                      policies=("fna",))
    parallel = run_grid(traces, base, "update_interval", (100, 400),
                        policies=("fna",), workers=2)
    _assert_grids_identical(parallel, serial)


# ---------------------------------------------------------------------------
# Golden-scenario hydration parity (the acceptance bar)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def golden_store(tmp_path_factory):
    return ArtifactStore(tmp_path_factory.mktemp("golden-store"))


@pytest.mark.parametrize("name", sorted(GOLDEN_SCENARIOS))
def test_golden_scenario_store_hydrated_bit_identical(name, golden_store):
    """Populate-then-warm on each golden scenario's pinned sub-grid: the
    warm (fully store-hydrated) run must reproduce every record of the
    cold run exactly — every scenario, every policy, every raw
    accumulator — while computing ZERO sweeps."""
    sc = get_scenario(name)
    cold = run_scenario(sc, golden=True, store=golden_store)
    before = systemstate.SWEEPS_COMPUTED
    warm = run_scenario(sc, golden=True, store=golden_store)
    assert systemstate.SWEEPS_COMPUTED == before, \
        f"{name}: warm golden run recomputed a sweep"
    assert warm == cold, f"{name}: store-hydrated records drifted"


# ---------------------------------------------------------------------------
# tracefiles parse cache under the store root
# ---------------------------------------------------------------------------

@pytest.fixture
def keys_log(tmp_path):
    src = tmp_path / "src"
    src.mkdir()
    p = src / "t.log"
    p.write_text("".join(f"k{i % 17}\n" for i in range(300)))
    return p


def test_tracefiles_cache_lands_under_store_root(keys_log, tmp_path,
                                                 monkeypatch):
    from repro.cachesim.tracefiles import load_trace_file
    root = tmp_path / "store"
    monkeypatch.setenv("REPRO_STORE", str(root))
    ids = load_trace_file(keys_log)
    assert ids.shape[0] == 300
    assert list((root / "traces").glob("t.log.*.npz")), \
        "parse cache not under the store root"
    assert not list(keys_log.parent.glob("t.log.*.npz")), \
        "parse cache leaked next to the source despite REPRO_STORE"
    # warm load comes from the store-rooted cache, not a re-parse
    import repro.cachesim.tracefiles as tf
    monkeypatch.setattr(tf, "parse_trace_file",
                        lambda *a, **k: pytest.fail("re-parsed despite cache"))
    again = load_trace_file(keys_log)
    assert np.array_equal(again, ids)


def test_tracefiles_legacy_cache_still_hits_with_store_set(
        keys_log, tmp_path, monkeypatch):
    """A pre-existing next-to-source cache (written before REPRO_STORE
    existed) must still be honoured once the env var is set."""
    import repro.cachesim.tracefiles as tf
    from repro.cachesim.tracefiles import load_trace_file
    monkeypatch.delenv("REPRO_STORE", raising=False)
    ids = load_trace_file(keys_log)               # legacy location
    assert list(keys_log.parent.glob("t.log.*.npz"))
    monkeypatch.setenv("REPRO_STORE", str(tmp_path / "store"))
    monkeypatch.setattr(tf, "parse_trace_file",
                        lambda *a, **k: pytest.fail("legacy cache ignored"))
    again = load_trace_file(keys_log)
    assert np.array_equal(again, ids)


def test_tracefiles_default_stays_next_to_source(keys_log, monkeypatch):
    from repro.cachesim.tracefiles import load_trace_file
    monkeypatch.delenv("REPRO_STORE", raising=False)
    load_trace_file(keys_log)
    assert list(keys_log.parent.glob("t.log.*.npz"))


# ---------------------------------------------------------------------------
# Maintenance CLI
# ---------------------------------------------------------------------------

def test_store_tool_ls_verify_gc(tmp_path):
    repo = Path(__file__).resolve().parents[1]
    store = ArtifactStore(tmp_path)
    store.save_table("a" * 64, (3,), ("k1",), np.arange(8))
    store.save_table("b" * 64, (3,), ("k2",), np.arange(8))
    env = {**os.environ, "PYTHONPATH": str(repo / "src")}

    def tool(*args):
        return subprocess.run(
            [sys.executable, str(repo / "tools" / "store_tool.py"),
             "--store", str(tmp_path), *args],
            capture_output=True, text=True, env=env, cwd=repo)

    ls = tool("ls")
    assert ls.returncode == 0 and "total: 2 entries" in ls.stdout
    ver = tool("verify")
    assert ver.returncode == 0 and "0 corrupt" in ver.stdout
    gc = tool("gc", "--max-bytes", "1K")
    assert gc.returncode == 0
    assert len(store.entries()) < 2
    # a corrupt entry fails verify with exit 1
    [(path, _, _, _)] = store.entries()
    path.write_bytes(b"not an archive")
    bad = tool("verify")
    assert bad.returncode == 1 and "CORRUPT" in bad.stdout
