"""Advertisement-event subsystem suite (``repro.cachesim.advert``).

Pins the tentpole contract of the budgeted/self-adjusting advertisement
work (arXiv:2104.01386 / 2405.17801):

  * **strict special case** — every pre-existing golden scenario,
    re-expressed with an EXPLICIT ``periodic`` advert policy (and noisy
    budget knobs the policy must ignore), reproduces its committed
    golden file bit-identically on the fast engine, and spot-checked on
    the reference engine;
  * **bit-exact twins** — the ``delta`` and ``self_adjusting`` policies
    produce identical results, advert event streams, and end-of-run
    system state on both engines;
  * **budget semantics** — the token bucket genuinely bounds the wire
    spend, and drift below threshold keeps caches silent;
  * **cadence reconstruction** — end-of-sweep staleness counters are
    exact at advertisement boundaries (boundary-aligned traces across
    staggered cadences);
  * **key anatomy** — ``system_key`` grows the canonical advert spec
    (budget knobs a policy does not read cannot split sweep sharing),
    and the store round-trips the event streams bit-exactly.

Plus the satellite bugfixes: store gc touch-on-hit ordering, per-cache
config length/value validation, ``QEstimator`` horizon validation, and
``store_tool._parse_bytes`` robustness.
"""
import dataclasses
import importlib.util
import json
import math
import os
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from repro.cachesim import (
    ArtifactStore,
    SimConfig,
    SimResult,
    Simulator,
    get_scenario,
    get_trace,
)
from repro.cachesim.advert import (
    ADVERT_POLICIES,
    delta_advert_bytes,
    full_advert_bytes,
    predicted_fn,
    resolve_advert,
)
from repro.cachesim.scenarios import GOLDEN_SCENARIOS
from repro.cachesim.simulator import run_policies
from repro.cachesim.sweep import (
    cell_label,
    cell_overrides,
    hashable_label,
    run_grid,
    sweep_records,
)
from repro.cachesim.systemstate import SystemTrace
from repro.core import QEstimator

GOLDEN_DIR = Path(__file__).parent / "golden"
RESULT_FIELDS = tuple(f.name for f in dataclasses.fields(SimResult))

#: golden scenarios that predate the advert axis (implicit periodic) —
#: the "strict special case" claim is about exactly these
PRE_ADVERT_SCENARIOS = tuple(
    n for n in GOLDEN_SCENARIOS
    if get_scenario(n).base.get("advert_policy", "periodic") == "periodic")

#: budget knobs an explicit periodic policy must IGNORE (resolve_advert
#: zeroes them, so they change neither evolution nor system_key)
NOISY_KNOBS = dict(advert_bandwidth=7.0, advert_burst=123.0,
                   advert_threshold=0.5, advert_check=17)


def _node_state(nd):
    return (tuple(nd.advert_events), nd._since_adv, nd._since_est,
            nd._since_chk, nd._n_ins, nd.adv_tokens,
            nd.ind.cbf.counters.tobytes(), nd.ind.stale.tobytes(),
            nd.ind.fp_est, nd.ind.fn_est, nd.version)


def _run(policy, engine, trace, **kw):
    cfg = SimConfig(policy=policy, engine=engine, **kw)
    sim = Simulator(cfg)
    return sim, sim.run(trace)


# ---------------------------------------------------------------------------
# Strict special case: periodic advert events == committed golden files
# ---------------------------------------------------------------------------

def test_pre_advert_scenarios_cover_the_legacy_registry():
    assert set(PRE_ADVERT_SCENARIOS) == \
        set(GOLDEN_SCENARIOS) - {"advert_budget", "advert_delta"}


@pytest.mark.parametrize("name", PRE_ADVERT_SCENARIOS)
def test_periodic_event_stream_reproduces_golden(name):
    """Every pre-existing golden (trace, cell, policy), re-run with the
    advert policy spelled out as ``periodic`` plus budget knobs it must
    ignore, matches the committed file bit-for-bit (fast engine)."""
    payload = json.loads((GOLDEN_DIR / f"{name}.json").read_text())
    sc = get_scenario(name)
    traces, values = sc.golden_grid()
    base = sc.config(engine="fast", advert_policy="periodic",
                     **NOISY_KNOBS, **sc.golden_base)
    grid = run_grid(traces, base, sc.axis, values, policies=sc.policies)
    for cell in payload["cells"]:
        res = grid[(cell["trace"], hashable_label(cell["label"]))][
            cell["policy"]]
        for f in RESULT_FIELDS:
            assert getattr(res, f) == cell["result"][f], \
                (name, cell["trace"], cell["label"], cell["policy"], f)
        # the event-stream accounting rode along (zero is legitimate
        # when a cell's insertions never reach its cadence)
        assert res.advert_events >= 0 and res.advert_bytes >= 0.0


def test_periodic_event_stream_reference_spot_check():
    """One golden cell on the REFERENCE engine with the explicit periodic
    advert spec — the special case holds in the oracle loop too."""
    name = "fig4_gradle"
    payload = json.loads((GOLDEN_DIR / f"{name}.json").read_text())
    sc = get_scenario(name)
    traces, golden_values = sc.golden_grid()
    first = payload["cells"][0]
    values = [v for v in golden_values
              if hashable_label(cell_label(sc.axis, v)) ==
              hashable_label(first["label"])]
    cfg = sc.config(engine="reference", advert_policy="periodic",
                    **NOISY_KNOBS, **sc.golden_base)
    cfg = dataclasses.replace(cfg, **cell_overrides(sc.axis, values[0]))
    out = run_policies(traces[first["trace"]], cfg, policies=sc.policies)
    for cell in payload["cells"]:
        if cell["trace"] != first["trace"] or cell["label"] != first["label"]:
            continue
        for f in RESULT_FIELDS:
            assert getattr(out[cell["policy"]], f) == cell["result"][f], \
                (cell["policy"], f)


# ---------------------------------------------------------------------------
# Bit-exact engine twins on the new policies
# ---------------------------------------------------------------------------

ADVERT_CONFIGS = (
    dict(advert_policy="delta", update_interval=80),
    dict(advert_policy="self_adjusting", advert_bandwidth=2.0,
         advert_threshold=0.05, est_interval=50),
    dict(advert_policy="self_adjusting", advert_bandwidth=25.0,
         advert_threshold=0.02, advert_check=30, advert_burst=2_000.0),
    # heterogeneous: one cache periodic, one delta, one self-adjusting
    dict(advert_policy=("periodic", "delta", "self_adjusting"),
         advert_bandwidth=8.0, update_interval=120),
)


@pytest.mark.parametrize("advert", ADVERT_CONFIGS)
@pytest.mark.parametrize("policy", ("fna", "fno", "fna_cal"))
def test_fast_reference_parity(advert, policy):
    """Results, advert event streams, and the full end-of-run node state
    agree between engines for every new-policy configuration."""
    trace = get_trace("wiki", 8_000, seed=3)
    kw = dict(cache_size=400, **advert)
    sf, rf = _run(policy, "fast", trace, **kw)
    sr, rr = _run(policy, "reference", trace, **kw)
    for f in RESULT_FIELDS:
        assert getattr(rf, f) == getattr(rr, f), (advert, policy, f)
    assert rf.advert_events == rr.advert_events
    assert rf.advert_bytes == rr.advert_bytes
    for nf, nr in zip(sf.nodes, sr.nodes):
        assert _node_state(nf) == _node_state(nr), (advert, policy)
    # the SystemTrace exposes the same streams the nodes recorded
    for (ins, byt), nd in zip(sf.last_system.advert_streams(), sr.nodes):
        assert ins.tolist() == [e[0] for e in nd.advert_events]
        assert byt.tolist() == [e[1] for e in nd.advert_events]


def test_delta_costs_below_full_at_tight_cadence():
    """A tight cadence changes few bits between adverts, so the measured
    delta encoding genuinely undercuts the full bitmap (and never
    exceeds it)."""
    trace = get_trace("gradle", 8_000, seed=1)
    sim, res = _run("fna", "fast", trace, cache_size=2_000,
                    advert_policy="delta", update_interval=64)
    full = sim.nodes[0].ind.cbf.m / 8.0
    costs = [e[1] for nd in sim.nodes for e in nd.advert_events]
    assert costs and all(c <= full for c in costs)
    assert min(costs) < full            # at least one genuine delta win


def test_self_adjusting_budget_is_respected():
    """Token-bucket semantics: every advert costs the full bitmap, fires
    on a check boundary, and total spend never exceeds the initial burst
    plus the total refill the run could have earned."""
    trace = get_trace("wiki", 10_000, seed=0)
    bw, chk = 3.0, 50
    sim, res = _run("fna", "reference", trace, cache_size=500,
                    advert_policy="self_adjusting", advert_bandwidth=bw,
                    advert_threshold=0.05, advert_check=chk)
    assert res.advert_events > 0
    for nd in sim.nodes:
        full = nd.ind.cbf.m / 8.0
        assert nd.adv_burst == full          # default burst = one advert
        spent = 0.0
        for ins, cost in nd.advert_events:
            assert cost == full
            assert ins % chk == 0            # only at check boundaries
            spent += cost
        assert spent <= nd.adv_burst + bw * nd._n_ins + 1e-9
        assert nd.adv_tokens >= 0.0


def test_self_adjusting_silent_below_threshold_and_on_empty_budget():
    trace = get_trace("wiki", 6_000, seed=0)
    # threshold above 1: Eq. (7) prediction can never cross it
    sim, res = _run("fna", "fast", trace, cache_size=500,
                    advert_policy="self_adjusting", advert_bandwidth=50.0,
                    advert_threshold=1.5)
    assert res.advert_events == 0
    # zero bandwidth: the prepaid burst covers exactly one advert ever
    sim, res = _run("fna", "fast", trace, cache_size=500,
                    advert_policy="self_adjusting", advert_bandwidth=0.0,
                    advert_threshold=0.05)
    assert all(len(nd.advert_events) <= 1 for nd in sim.nodes)
    assert res.advert_events == sum(len(nd.advert_events)
                                    for nd in sim.nodes)


def test_update_interval_does_not_fire_under_self_adjusting():
    """The fixed cadence is inert in self-adjusting mode: an absurdly
    short update_interval produces no periodic adverts."""
    trace = get_trace("wiki", 4_000, seed=0)
    sim, res = _run("fna", "reference", trace, cache_size=500,
                    update_interval=1,
                    advert_policy="self_adjusting", advert_bandwidth=0.0,
                    advert_threshold=1.5)
    assert res.advert_events == 0


# ---------------------------------------------------------------------------
# Boundary-aligned cadence reconstruction (the systemstate.py:158 audit)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("intervals", [(10, 20, 40), (24, 40, 60)])
def test_boundary_aligned_reconstruction(intervals):
    """Unique-key trace, per-cache insertion counts an exact multiple of
    each (staggered) cadence: the walk's end-of-sweep ``_since_adv``/
    ``_since_est`` reconstruction must land exactly ON the boundary
    (zero), the final advert event exactly AT the last insertion, and
    the whole node state must match the reference loop."""
    n_per = 120                              # multiple of every cadence
    trace = np.arange(3 * n_per, dtype=np.uint64)   # dj = key % 3
    kw = dict(cache_size=200, update_interval=intervals, est_interval=12)
    sf, rf = _run("fna", "fast", trace, **kw)
    sr, rr = _run("fna", "reference", trace, **kw)
    for j, (nf, nr) in enumerate(zip(sf.nodes, sr.nodes)):
        assert nf._n_ins == nr._n_ins == n_per
        assert nf._since_adv == nr._since_adv == 0, j
        assert nf._since_est == nr._since_est == 0, j
        assert nf.advert_events[-1][0] == n_per, j
        assert len(nf.advert_events) == n_per // intervals[j]
        assert _node_state(nf) == _node_state(nr), j
    for f in RESULT_FIELDS:
        assert getattr(rf, f) == getattr(rr, f), f


def test_boundary_aligned_reconstruction_self_adjusting():
    """Same boundary discipline for the drift-check cadence: with the
    check interval dividing the insertion count, ``_since_chk`` lands on
    zero in both engines."""
    n_per = 120
    trace = np.arange(3 * n_per, dtype=np.uint64)
    kw = dict(cache_size=200, advert_policy="self_adjusting",
              advert_bandwidth=5.0, advert_threshold=0.05,
              advert_check=30, est_interval=12)
    sf, _ = _run("fna", "fast", trace, **kw)
    sr, _ = _run("fna", "reference", trace, **kw)
    for nf, nr in zip(sf.nodes, sr.nodes):
        assert nf._since_chk == nr._since_chk == 0
        assert _node_state(nf) == _node_state(nr)


# ---------------------------------------------------------------------------
# Key anatomy + store round-trip
# ---------------------------------------------------------------------------

def test_system_key_grows_canonical_advert_spec():
    base = SimConfig()
    k0 = SystemTrace.system_key(base)
    # periodic ignores budget knobs: same key, sharing not split
    noisy = SimConfig(**NOISY_KNOBS)
    assert SystemTrace.system_key(noisy) == k0
    # scalar and broadcast sequence resolve identically
    seq = SimConfig(advert_policy=("periodic",) * 3)
    assert SystemTrace.system_key(seq) == k0
    # policy and live budget knobs DO shift the key
    for kw in (dict(advert_policy="delta"),
               dict(advert_policy="self_adjusting"),
               dict(advert_policy="self_adjusting", advert_bandwidth=2.0),
               dict(advert_policy="self_adjusting", advert_check=25)):
        assert SystemTrace.system_key(SimConfig(**kw)) != k0, kw


def test_resolve_advert_defaults():
    cfg = SimConfig(cache_size=500, advert_policy="self_adjusting",
                    advert_bandwidth=1.0, est_interval=40)
    spec = resolve_advert(cfg)
    m = int(cfg.bpes[0] * cfg.cache_sizes[0])
    for pol, bw, burst, th, chk in spec:
        assert pol == "self_adjusting" and bw == 1.0
        assert burst == m / 8.0              # 0 -> one full advertisement
        assert chk == 40                     # 0 -> est_interval
    assert resolve_advert(SimConfig(**NOISY_KNOBS)) == \
        (("periodic", 0.0, 0.0, 0.0, 0),) * 3


def test_store_roundtrip_carries_advert_streams(tmp_path):
    """save_sweep -> load_sweep preserves the advert event streams and
    token state bit-exactly, and a hydrated install() leaves a fresh
    simulator in the donor's exact advert state."""
    trace = get_trace("wiki", 6_000, seed=2)
    cfg = SimConfig(engine="fast", cache_size=400,
                    advert_policy="self_adjusting", advert_bandwidth=4.0,
                    advert_threshold=0.05)
    donor = Simulator(cfg)
    donor.run(trace)
    st = donor.last_system
    store = ArtifactStore(tmp_path)
    store.save_sweep(st)
    hyd = store.load_sweep(trace, SystemTrace.system_key(cfg))
    assert hyd is not None
    for (a_ins, a_b), (b_ins, b_b) in zip(st.advert_streams(),
                                          hyd.advert_streams()):
        assert a_ins.tolist() == b_ins.tolist()
        assert a_b.tolist() == b_b.tolist()
    fresh = Simulator(cfg)
    hyd.install(fresh, trace)
    for nf, nd in zip(fresh.nodes, donor.nodes):
        assert _node_state(nf) == _node_state(nd)
    res = SimResult(policy="fna")
    hyd.add_advert(res)
    assert res.advert_events == sum(len(nd.advert_events)
                                    for nd in donor.nodes)


def test_run_grid_advert_bandwidth_axis():
    """advert_bandwidth is a sweepable system axis end to end, and the
    flattened records carry the advert totals."""
    traces = {"gradle": get_trace("gradle", 5_000, seed=1)}
    base = SimConfig(engine="fast", cache_size=2_000, est_interval=50,
                     advert_policy="self_adjusting", advert_threshold=0.05)
    grid = run_grid(traces, base, "advert_bandwidth", (2.0, 32.0),
                    policies=("fna", "pi"))
    recs = sweep_records(grid, axis="advert_bandwidth")
    assert {r["advert_bandwidth"] for r in recs} == {2.0, 32.0}
    by_bw = {r["advert_bandwidth"]: r for r in recs if r["policy"] == "fna"}
    assert by_bw[2.0]["advert_bytes"] < by_bw[32.0]["advert_bytes"]
    assert all(r["advert_events"] > 0 for r in recs)


# ---------------------------------------------------------------------------
# Eq. (7) drift signal + wire-cost helpers
# ---------------------------------------------------------------------------

def test_predicted_fn_matches_estimate_rates_without_mutation():
    trace = get_trace("wiki", 3_000, seed=0)
    sim, _ = _run("fna", "reference", trace, cache_size=300)
    for nd in sim.nodes:
        fp0, fn0 = nd.ind.fp_est, nd.ind.fn_est
        drift = predicted_fn(nd.ind)
        assert (nd.ind.fp_est, nd.ind.fn_est) == (fp0, fn0)  # no mutation
        nd.ind.estimate_rates()
        assert drift == nd.ind.fn_est        # identical arithmetic
        assert full_advert_bytes(nd.ind) == nd.ind.cbf.m / 8.0
        assert 0.0 <= delta_advert_bytes(nd.ind) <= full_advert_bytes(nd.ind)


# ---------------------------------------------------------------------------
# Satellite: store gc touch-on-hit (LRU ordering regression)
# ---------------------------------------------------------------------------

def test_gc_touch_on_hit_keeps_warm_entries(tmp_path):
    """Reads refresh mtime, so ``gc`` (oldest-mtime deletion) evicts the
    COLD entry, not the one just hit — the documented LRU behaviour."""
    store = ArtifactStore(tmp_path)
    store.save_table("a" * 64, (1,), ("warm",), np.arange(64))
    store.save_table("b" * 64, (1,), ("cold",), np.arange(64))
    warm_path = store._path("table", store.table_meta("a" * 64, (1,),
                                                      ("warm",)))
    cold_path = store._path("table", store.table_meta("b" * 64, (1,),
                                                      ("cold",)))
    # age the warm entry far below the cold one, then HIT it
    old = time.time() - 10_000
    os.utime(warm_path, (old, old))
    assert store.load_table("a" * 64, (1,), ("warm",)) is not None
    assert warm_path.stat().st_mtime > cold_path.stat().st_mtime - 1.0
    # gc to below two entries: the cold one (oldest mtime now) must go
    keep = warm_path.stat().st_size + 1
    deleted = store.gc(keep)
    assert cold_path in deleted and warm_path.exists()


# ---------------------------------------------------------------------------
# Satellite: per-cache config validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("field,bad", [
    ("cache_size", (100, 100)), ("bpe", (8.0, 8.0)),
    ("update_interval", (10, 10)), ("est_interval", (5, 5)),
    ("advert_policy", ("periodic", "periodic")),
    ("advert_bandwidth", (1.0, 1.0)), ("advert_burst", (1.0, 1.0)),
    ("advert_threshold", (0.1, 0.1)), ("advert_check", (5, 5)),
])
def test_per_cache_wrong_length_raises_at_construction(field, bad):
    with pytest.raises(ValueError, match=field):
        SimConfig(n_caches=3, **{field: bad})


@pytest.mark.parametrize("field,bad", [
    ("cache_size", 0), ("bpe", 0.0), ("bpe", -2.0),
    ("update_interval", 0), ("est_interval", 0),
    ("advert_bandwidth", -1.0), ("advert_burst", -5.0),
    ("advert_threshold", -0.1), ("advert_check", -3),
])
def test_degenerate_per_cache_values_raise(field, bad):
    with pytest.raises(ValueError):
        SimConfig(**{field: bad})


def test_unknown_advert_policy_raises():
    with pytest.raises(ValueError, match="unknown advert_policy"):
        SimConfig(advert_policy="shout")
    with pytest.raises(ValueError, match="unknown advert_policy"):
        SimConfig(advert_policy=("periodic", "nope", "delta"))
    assert ADVERT_POLICIES == ("periodic", "delta", "self_adjusting")


# ---------------------------------------------------------------------------
# Satellite: QEstimator horizon + store_tool._parse_bytes validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("horizon", (0, -1, -100))
def test_qestimator_rejects_nonpositive_horizon(horizon):
    with pytest.raises(ValueError, match="horizon"):
        QEstimator(horizon=horizon)


def test_simconfig_rejects_nonpositive_q_horizon():
    with pytest.raises(ValueError, match="q_horizon"):
        SimConfig(q_horizon=0)
    with pytest.raises(ValueError, match="q_horizon"):
        SimConfig(q_horizon=-5)


def _store_tool():
    path = Path(__file__).resolve().parents[1] / "tools" / "store_tool.py"
    spec = importlib.util.spec_from_file_location("store_tool_mod", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.mark.parametrize("raw,expected", [
    ("4096", 4096), ("1K", 1 << 10), ("1k", 1 << 10),
    ("1KB", 1 << 10), ("1kb", 1 << 10),
    ("1.5K", int(1.5 * (1 << 10))), ("2M", 2 << 20), ("2MB", 2 << 20),
    ("3G", 3 << 30), ("1.5 GB", int(1.5 * (1 << 30))),
    (" 500 M ", 500 << 20), ("0", 0),
])
def test_parse_bytes_accepts(raw, expected):
    assert _store_tool()._parse_bytes(raw) == expected


@pytest.mark.parametrize("raw", ["", "abc", "12Q", "K", "--3", "-1K",
                                 "-4096", "1..5K", "1e3e4"])
def test_parse_bytes_rejects_with_clear_error(raw):
    import argparse
    with pytest.raises(argparse.ArgumentTypeError, match="invalid size"):
        _store_tool()._parse_bytes(raw)


def test_store_tool_gc_rejects_bad_size_as_usage_error(tmp_path):
    repo = Path(__file__).resolve().parents[1]
    env = {**os.environ, "PYTHONPATH": str(repo / "src")}
    r = subprocess.run(
        [sys.executable, str(repo / "tools" / "store_tool.py"),
         "--store", str(tmp_path), "gc", "--max-bytes", "12Q"],
        capture_output=True, text=True, env=env, cwd=repo)
    assert r.returncode == 2                 # argparse usage error
    assert "invalid size" in r.stderr
