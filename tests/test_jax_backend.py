"""JAX/Pallas table-core differential suite.

The fast engine's table layer gained a jitted/Pallas backend this PR:

  * ``repro.kernels.subsetdp`` — the Eq. (10) subset-DP product as a
    row-tiled Pallas kernel (+ jnp mirror), BIT-EXACT with the NumPy
    oracle ``repro.core.batched._subset_dp`` by construction (the
    ascending-index sweep argument in ``kernels/subsetdp/ref.py``);
  * ``selection_tables_cells_jax`` — one jitted ``vmap(ds_pgm_batched)``
    over whole sweep-cell stacks, optionally sharded over the devices of
    ``launch.mesh.make_sweep_mesh()``.

NumPy stays the golden oracle.  The subset-DP paths assert tobytes-level
equality; the ds_pgm paths assert EXACT mask agreement away from the
~1e-12 near-tie dead-band (XLA FMA contraction can shift a prefix cost
by 1 ulp — see ``selection_tables_cells_jax``), and the end-to-end
differential replays every golden scenario through
``run_grid(backend="jax")`` expecting bit-identical SimResults.
"""
import dataclasses

import numpy as np
import pytest

from repro.cachesim import SimResult, get_scenario
from repro.cachesim.scenarios import GOLDEN_SCENARIOS
from repro.cachesim.sweep import run_grid
from repro.core.batched import (
    EPS,
    _subset_dp,
    ds_pgm_batched,
    exhaustive_tables,
    rho_exhaustive_tables,
    selection_tables,
    selection_tables_cells,
    selection_tables_cells_jax,
)
from repro.kernels.subsetdp import (
    default_row_block,
    subset_argmin,
    subset_dp,
    subset_dp_ref,
)

RESULT_FIELDS = tuple(f.name for f in dataclasses.fields(SimResult))


def _instance(rng, n, b):
    costs = rng.uniform(0.05, 5.0, n)
    rhos = rng.uniform(0.0, 1.0, (b, n))
    M = float(rng.uniform(1.5, 1000.0))
    return costs, rhos, M


# ---------------------------------------------------------------------------
# Subset-DP kernel: bit-exact vs the NumPy oracle (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 2, 3, 5, 8, 11])
@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_subset_dp_bit_exact_vs_oracle(n, backend):
    """Every [B, 2^n] subset value from the jitted mirror and the Pallas
    kernel (interpret mode) equals ``_subset_dp`` BIT-FOR-BIT — the
    ascending-sweep restructure makes the IEEE operation chains
    identical, so this is tobytes equality, not a tolerance."""
    rng = np.random.default_rng(100 + n)
    b = 3 if n > 8 else 37                  # off row-block sizes: pad path
    costs, rhos, M = _instance(rng, n, b)
    ref = _subset_dp(costs, rhos, M)
    got = subset_dp(costs, rhos, M, backend=backend, interpret=True)
    assert got.shape == ref.shape
    assert got.tobytes() == ref.tobytes(), (n, backend)


def test_subset_dp_eager_ref_bit_exact():
    """The eager jnp mirror itself (no jit, no pallas) is bit-exact —
    pinning the ascending-sweep argument independently of the kernel
    plumbing."""
    rng = np.random.default_rng(7)
    costs, rhos, M = _instance(rng, 6, 19)
    ref = _subset_dp(costs, rhos, M)
    from jax.experimental import enable_x64
    with enable_x64():
        got = np.asarray(subset_dp_ref(costs, rhos, M))
    assert got.tobytes() == ref.tobytes()


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_subset_argmin_matches_rho_exhaustive_tables(backend):
    """The on-device masked argmin reproduces the NumPy enumeration's
    winning subset per row, with and without the CS_FNO ``allowed``
    restriction (subset values are bit-identical, and both argmins take
    the FIRST minimum in ascending-mask order)."""
    rng = np.random.default_rng(8)
    for n in (1, 3, 6, 9):
        costs, rhos, M = _instance(rng, n, 41)
        k = 1 << n
        want = rho_exhaustive_tables(costs, rhos, M)
        got = subset_argmin(costs, rhos, M, backend=backend, interpret=True)
        assert np.array_equal(
            ((got[:, None] >> np.arange(n)[None, :]) & 1).astype(bool),
            want), (n, backend)
        allowed = rng.integers(0, k, 41, dtype=np.int64)
        want = rho_exhaustive_tables(costs, rhos, M, allowed=allowed)
        got = subset_argmin(costs, rhos, M, allowed=allowed,
                            backend=backend, interpret=True)
        assert np.array_equal(
            ((got[:, None] >> np.arange(n)[None, :]) & 1).astype(bool),
            want), (n, backend, "allowed")


def test_rho_exhaustive_tables_backend_param():
    """``rho_exhaustive_tables(backend=...)`` routes through the kernel
    package and returns the same masks as the NumPy oracle."""
    rng = np.random.default_rng(9)
    costs, rhos, M = _instance(rng, 5, 23)
    ref = rho_exhaustive_tables(costs, rhos, M)
    for backend in ("jax", "pallas"):
        assert np.array_equal(
            rho_exhaustive_tables(costs, rhos, M, backend=backend), ref)


def test_exhaustive_tables_chunk_and_backend():
    """The chunked pattern-grid build is invariant to chunk size and
    backend (n = 10 exercises the raised n <= 12 dispatch tier), and
    the auto-sized default chunk is reachable from the engine provider
    via ``ExhaustiveTables.chunk_rows``."""
    from repro.cachesim.engine import ExhaustiveTables
    assert ExhaustiveTables.chunk_rows is None   # auto-size by default
    rng = np.random.default_rng(10)
    n, v = 10, 2
    costs = rng.uniform(0.05, 5.0, n)
    pi = rng.uniform(0.0, 1.0, (v, n))
    nu = rng.uniform(0.0, 1.0, (v, n))
    M = 250.0
    ref = exhaustive_tables(costs, pi, nu, M, fno=True)
    assert np.array_equal(
        exhaustive_tables(costs, pi, nu, M, fno=True, chunk=777), ref)
    assert np.array_equal(
        exhaustive_tables(costs, pi, nu, M, fno=True, backend="jax"), ref)
    # per-row twin agrees on the same grid (the n <= 16 tier)
    k = 1 << n
    pats = ((np.arange(k)[:, None] >> np.arange(n)[None, :]) & 1)
    rhos = np.where(pats[None, :, :] > 0,
                    pi[:, None, :], nu[:, None, :]).reshape(v * k, n)
    allowed = np.tile(np.arange(k, dtype=np.int64), v)
    pow2 = (1 << np.arange(n)).astype(np.int64)
    per_row = rho_exhaustive_tables(costs, rhos, M, allowed=allowed) @ pow2
    assert np.array_equal(per_row.reshape(v, k), ref)


def test_default_row_block_scales_down_with_n():
    assert default_row_block(1) == 256
    assert default_row_block(8) == 256
    assert default_row_block(12) == 16
    assert default_row_block(16) == 1
    assert default_row_block(20) == 1


# ---------------------------------------------------------------------------
# Stacked cells kernel: near-tie-gated mask agreement vs the NumPy mirror
# ---------------------------------------------------------------------------

def _near_tie_rows(costs_cells, pi, nu, penalties, margin=1e-9):
    """[C, V*K] bool: rows whose two best DS_PGM prefix values are
    within ``margin`` of each other (the only rows where the jitted
    path's 1-ulp FMA drift may legitimately flip the argmin)."""
    v, n = pi.shape
    k = 1 << n
    pats = ((np.arange(k)[:, None] >> np.arange(n)[None, :]) & 1)
    rhos = np.where(pats[None, :, :] > 0,
                    pi[:, None, :], nu[:, None, :]).reshape(v * k, n)
    out = np.zeros((len(costs_cells), v * k), bool)
    for ci, (costs, M) in enumerate(zip(costs_cells, penalties)):
        r = np.clip(rhos, EPS, 1.0 - EPS)
        order = np.argsort(costs[None, :] / -np.log(r), axis=1, kind="stable")
        csum = np.cumsum(np.take_along_axis(
            np.broadcast_to(costs, r.shape), order, 1), axis=1)
        lprod = np.cumsum(np.log(np.take_along_axis(r, order, 1)), axis=1)
        phi = np.concatenate(
            [np.full((v * k, 1), M), csum + M * np.exp(lprod)], axis=1)
        two = np.sort(phi, axis=1)[:, :2]
        out[ci] = (two[:, 1] - two[:, 0]) <= margin * np.maximum(
            np.abs(two[:, 0]), 1.0)
    return out


def test_cells_jax_matches_numpy_mirror_away_from_ties():
    """Every (cell, version, pattern) mask from the jitted stacked build
    equals the NumPy mirror except (at most) on rows flagged as near-tie
    dead-band — the tolerance-based differential of the issue."""
    rng = np.random.default_rng(11)
    n, v, c = 4, 6, 9
    pi = rng.uniform(0.0, 1.0, (v, n))
    nu = rng.uniform(0.0, 1.0, (v, n))
    costs_cells = rng.uniform(0.05, 5.0, (c, n))
    penalties = rng.uniform(5.0, 500.0, c)
    fno_cells = (np.arange(c) % 2).astype(bool)
    a = selection_tables_cells(costs_cells, pi, nu, penalties, fno_cells)
    b = selection_tables_cells_jax(costs_cells, pi, nu, penalties, fno_cells)
    assert a.shape == b.shape == (c, v, 1 << n, n)
    diff_rows = (a != b).any(axis=3).reshape(c, -1)
    ties = _near_tie_rows(costs_cells, pi, nu, penalties)
    assert not np.any(diff_rows & ~ties), \
        f"{int((diff_rows & ~ties).sum())} rows differ outside the dead-band"


def test_cells_jax_single_and_empty_cells():
    rng = np.random.default_rng(12)
    n, v = 3, 4
    pi = rng.uniform(0.0, 1.0, (v, n))
    nu = rng.uniform(0.0, 1.0, (v, n))
    empty = selection_tables_cells_jax(
        np.empty((0, n)), pi, nu, np.empty(0), np.empty(0, bool))
    assert empty.shape == (0, v, 1 << n, n)
    one = selection_tables_cells_jax(
        np.full((1, n), 2.0), pi, nu, [100.0], [True])
    ref = selection_tables(np.full(n, 2.0), pi, nu, 100.0, fno=True)
    assert np.array_equal(one[0], ref)


def test_cells_jax_sharded_equals_unsharded():
    """With a (possibly host-faked) multi-device mesh the sharded build
    returns exactly the single-device answer — cells are row-independent,
    so sharding (and its repeat-last-row padding) must be invisible.
    On a 1-device host ``make_sweep_mesh()`` is None and this reduces to
    a smoke test of the auto-selection path."""
    from repro.launch.mesh import make_sweep_mesh
    rng = np.random.default_rng(13)
    n, v, c = 3, 5, 7                 # 7 cells never divide a mesh evenly
    pi = rng.uniform(0.0, 1.0, (v, n))
    nu = rng.uniform(0.0, 1.0, (v, n))
    costs_cells = rng.uniform(0.05, 5.0, (c, n))
    penalties = rng.uniform(5.0, 500.0, c)
    fno_cells = (np.arange(c) % 2).astype(bool)
    plain = selection_tables_cells_jax(
        costs_cells, pi, nu, penalties, fno_cells)
    mesh = make_sweep_mesh()
    sharded = selection_tables_cells_jax(
        costs_cells, pi, nu, penalties, fno_cells, mesh=mesh)
    assert np.array_equal(plain, sharded)


def test_shard_cells_pads_and_reports_count():
    from repro.launch.mesh import make_sweep_mesh
    mesh = make_sweep_mesh()
    if mesh is None:
        pytest.skip("single-device host (set "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=4)")
    from repro.distributed.sharding import shard_cells
    size = mesh.shape["cells"]
    arrs = [np.arange(size + 1, dtype=np.float64),
            np.arange(2 * (size + 1), dtype=np.float64).reshape(size + 1, 2)]
    (a, b), count = shard_cells(arrs, mesh)
    assert count == size + 1
    assert a.shape[0] == b.shape[0] == 2 * size    # padded to a multiple
    assert np.asarray(a)[size + 1] == np.asarray(a)[size]  # repeat-last pad


# ---------------------------------------------------------------------------
# End-to-end golden differential: run_grid(backend="jax") == numpy backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", GOLDEN_SCENARIOS)
def test_run_grid_jax_backend_matches_numpy(name):
    """Every golden scenario replayed through the JAX table backend
    yields bit-identical SimResults to the NumPy backend on every
    (trace, cell, policy) — near-tie flips are possible in principle but
    never observed on the golden grids (which is the point of pinning
    them)."""
    sc = get_scenario(name)
    traces, values = sc.golden_grid()
    base = sc.config(engine="fast", **sc.golden_base)
    ref = run_grid(traces, base, sc.axis, values, policies=sc.policies)
    got = run_grid(traces, base, sc.axis, values, policies=sc.policies,
                   backend="jax")
    assert set(ref) == set(got)
    for key, cell in ref.items():
        for p, res in cell.items():
            for f in RESULT_FIELDS:
                assert getattr(got[key][p], f) == getattr(res, f), \
                    (name, key, p, f)


def test_prefetch_jax_stacks_single_job():
    """Unlike the NumPy path (which skips groups of < 2 jobs), the JAX
    prefetch seeds the cache even for a single (cell, policy) build —
    every table then comes off the one compiled path."""
    from repro.cachesim.engine import DsPgmTables, prefetch_tables
    from repro.cachesim.simulator import SimConfig, Simulator
    from repro.cachesim.systemstate import SystemTrace
    from repro.cachesim.traces import get_trace
    trace = get_trace("gradle", 2_000, seed=3)
    cfg = SimConfig(policy="fna", update_interval=200)
    system = SystemTrace.compute(Simulator(cfg), trace)
    prefetch_tables(system, [cfg], ["fna"])
    assert not system.plan_cache                  # numpy path: skipped
    prefetch_tables(system, [cfg], ["fna"], backend="jax")
    key = DsPgmTables().cache_key(cfg)
    assert key in system.plan_cache
    tab = system.plan_cache[key]
    v = system.pi_v.shape[0]
    assert tab.shape == (v * (1 << system.n),) and tab.dtype == np.int64


def test_ds_pgm_batched_all_ones_fno_mask_is_identity():
    """The cells kernel always passes a mask array (vmap needs one
    shape); an all-ones mask must therefore be an EXACT no-op."""
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    rng = np.random.default_rng(14)
    costs, rhos, M = _instance(rng, 5, 33)
    with enable_x64():
        plain = np.asarray(ds_pgm_batched(
            jnp.asarray(costs), jnp.asarray(rhos), M))
        masked = np.asarray(ds_pgm_batched(
            jnp.asarray(costs), jnp.asarray(rhos), M,
            fno_mask=jnp.ones(rhos.shape, jnp.int64)))
    assert np.array_equal(plain, masked)
