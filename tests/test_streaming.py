"""Streaming phase-1 tests: chunked system sweeps, spill-backed outputs,
chunked trace parsing, and single-pass trace statistics.

The design invariant under test is *bit-identity*: the one-shot sweep is
literally the chunk loop run once, so every chunked result — per-request
arrays, view-version history, end-of-run node state including the PR-8
advertisement counters and token balances — must equal the one-shot
output exactly, for ANY chunk size, aligned with the advert cadences or
not.  Same contract on the ingestion side: concatenated
``iter_trace_chunks`` output equals ``parse_trace_file``, and
``stream_trace_info`` equals the in-memory ``trace_info`` to the last
float.
"""
import dataclasses
import json
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.cachesim import SimConfig, SimResult, Simulator, get_scenario
from repro.cachesim.scenarios import GOLDEN_SCENARIOS
from repro.cachesim.store import ArtifactStore
from repro.cachesim.sweep import hashable_label, run_grid
from repro.cachesim.systemstate import SystemTrace
from repro.cachesim.tracefiles import (
    iter_trace_chunks,
    load_trace_file,
    parse_trace_file,
    stream_trace_info,
)
from repro.cachesim.traces import get_trace

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tools"))
import make_trace_file  # noqa: E402

GOLDEN_DIR = Path(__file__).parent / "golden"
DATA = Path(__file__).parent / "data"
RESULT_FIELDS = tuple(f.name for f in dataclasses.fields(SimResult))

#: the acceptance chunk sweep: degenerate (1), prime + cadence-hostile
#: (7), and production-sized (4096)
CHUNK_SIZES = (1, 7, 4096)

#: system shapes whose carry-state differs: homogeneous baseline,
#: heterogeneous tiers with staggered cadences, and both non-periodic
#: advert policies (token buckets / delta encodings cross boundaries)
SWEEP_CONFIGS = {
    "scalar": dict(),
    "hetero": dict(n_caches=3, cache_size=(500, 1_500, 3_000),
                   costs=(1.0, 2.0, 4.0),
                   update_interval=(64, 256, 1_024), est_interval=50),
    "self_adjusting": dict(advert_policy="self_adjusting",
                           advert_bandwidth=2.0, advert_threshold=0.05,
                           cache_size=2_000, est_interval=50),
    "delta": dict(advert_policy="delta", update_interval=128),
}


def _sweep_pair(cfg_name: str, chunk_size, n=3_000, spill=None):
    trace = get_trace("gradle", n, seed=1)
    cfg = SimConfig(engine="fast", **SWEEP_CONFIGS[cfg_name])
    one = SystemTrace.compute(Simulator(cfg), trace)
    chunked = SystemTrace.compute(Simulator(cfg), trace,
                                  chunk_size=chunk_size, spill=spill)
    return one, chunked


def _assert_traces_equal(one: SystemTrace, chunked: SystemTrace, ctx):
    a, b = one.to_arrays(), chunked.to_arrays()
    assert set(a) == set(b), ctx
    for k in a:
        assert np.asarray(a[k]).tobytes() == np.asarray(b[k]).tobytes(), \
            (ctx, k)
    assert one.quality == chunked.quality, ctx


# ---------------------------------------------------------------------------
# Chunked system sweep == one-shot, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk_size", CHUNK_SIZES)
@pytest.mark.parametrize("cfg_name", sorted(SWEEP_CONFIGS))
def test_chunked_compute_bit_identical(cfg_name, chunk_size):
    one, chunked = _sweep_pair(cfg_name, chunk_size)
    _assert_traces_equal(one, chunked, (cfg_name, chunk_size))


def test_chunk_size_larger_than_trace():
    one, chunked = _sweep_pair("hetero", 10 ** 9)
    _assert_traces_equal(one, chunked, "oversized chunk")


def test_chunk_size_validation():
    trace = get_trace("gradle", 100, seed=0)
    with pytest.raises(ValueError):
        SystemTrace.compute(Simulator(SimConfig(engine="fast")), trace,
                            chunk_size=0)


def test_chunk_boundary_advert_counters_non_aligned():
    """The end-of-run node snapshots — advertisement ordinals, drift-check
    and estimate cadence counters, token-bucket balances — must cross
    NON-ALIGNED chunk boundaries exactly (997 is coprime to every cadence
    in play)."""
    trace = get_trace("gradle", 4_000, seed=2)
    cfg = SimConfig(engine="fast", n_caches=3,
                    advert_policy="self_adjusting", advert_bandwidth=1.0,
                    advert_threshold=0.05, advert_check=13,
                    update_interval=(48, 48, 640), est_interval=50)
    one = SystemTrace.compute(Simulator(cfg), trace)
    chunked = SystemTrace.compute(Simulator(cfg), trace, chunk_size=997)
    for j, (na, nb) in enumerate(zip(one.final_state["nodes"],
                                     chunked.final_state["nodes"])):
        for k in ("n_ins", "since_adv", "since_est", "since_chk",
                  "adv_tokens", "version", "fp_est", "fn_est"):
            assert na[k] == nb[k], (j, k)
        assert na["adv_ins"] == nb["adv_ins"], j
        assert na["adv_bytes"] == nb["adv_bytes"], j
        assert np.array_equal(na["counters"], nb["counters"]), j
        assert list(na["lru_keys"]) == list(nb["lru_keys"]), j
    _assert_traces_equal(one, chunked, "non-aligned boundaries")


@pytest.mark.parametrize("name", GOLDEN_SCENARIOS)
def test_chunked_grid_matches_golden(name):
    """Every committed golden (trace, cell, policy) result, reproduced by
    the fast engine with a CHUNKED phase-1 sweep."""
    payload = json.loads((GOLDEN_DIR / f"{name}.json").read_text())
    sc = get_scenario(name)
    traces, values = sc.golden_grid()
    base = sc.config(engine="fast", **sc.golden_base)
    grid = run_grid(traces, base, sc.axis, values, policies=sc.policies,
                    share_system=True, chunk_size=4096)
    for cell in payload["cells"]:
        res = grid[(cell["trace"], hashable_label(cell["label"]))]
        for f in RESULT_FIELDS:
            got = getattr(res[cell["policy"]], f)
            assert got == cell["result"][f], \
                (name, cell["trace"], cell["label"], cell["policy"], f)


@pytest.mark.parametrize("chunk_size", CHUNK_SIZES)
def test_chunked_grid_chunk_sweep_staggered(chunk_size):
    """One cadence-heavy golden scenario across the full acceptance chunk
    sweep {1, 7, 4096} (the other scenarios run at 4096 above)."""
    name = "staggered_adverts"
    payload = json.loads((GOLDEN_DIR / f"{name}.json").read_text())
    sc = get_scenario(name)
    traces, values = sc.golden_grid()
    base = sc.config(engine="fast", **sc.golden_base)
    grid = run_grid(traces, base, sc.axis, values, policies=sc.policies,
                    share_system=True, chunk_size=chunk_size)
    for cell in payload["cells"]:
        res = grid[(cell["trace"], hashable_label(cell["label"]))]
        for f in RESULT_FIELDS:
            got = getattr(res[cell["policy"]], f)
            assert got == cell["result"][f], \
                (chunk_size, cell["label"], cell["policy"], f)


def test_simulator_run_chunked_result_identical():
    """The full three-phase result (not just the sweep) is unchanged
    under chunking, through the public Simulator.run."""
    trace = get_trace("scarab", 3_000, seed=3)
    for policy in ("fna", "fna_cal"):
        cfg = SimConfig(engine="fast", policy=policy)
        a = Simulator(cfg).run(trace)
        b = Simulator(cfg).run(trace, chunk_size=7)
        for f in RESULT_FIELDS:
            assert getattr(a, f) == getattr(b, f), (policy, f)


# ---------------------------------------------------------------------------
# Spill: memmap-backed per-request arrays
# ---------------------------------------------------------------------------

def test_spill_outputs_memmap_backed(tmp_path):
    one, chunked = _sweep_pair("hetero", 512, spill=tmp_path)
    assert isinstance(chunked.ind_all, np.memmap)
    assert isinstance(chunked.dj_all, np.memmap)
    _assert_traces_equal(one, chunked, "spill path")
    # the backing .npy files live under the caller-owned directory
    assert any(tmp_path.rglob("*.npy"))


def test_spill_via_artifact_store(tmp_path):
    store = ArtifactStore(tmp_path / "store")
    one, chunked = _sweep_pair("scalar", 997, spill=store)
    assert isinstance(chunked.ind_all, np.memmap)
    _assert_traces_equal(one, chunked, "store spill")
    spill_root = Path(store.root) / "spill"
    assert spill_root.exists() and any(spill_root.rglob("*.npy"))
    # scratch space is invisible to the store's entry machinery
    assert store.entries() == []


def test_spill_dir_unique_per_call(tmp_path):
    store = ArtifactStore(tmp_path)
    assert store.spill_dir() != store.spill_dir()


# ---------------------------------------------------------------------------
# Chunked parsing == one-shot parsing, on the committed sample logs
# ---------------------------------------------------------------------------

SAMPLES = (
    ("sample_recency.log.gz", {}),
    ("sample_zipf.csv.gz", {"key_column": "key"}),
)


@pytest.mark.parametrize("fname,kw", SAMPLES)
@pytest.mark.parametrize("chunk_size", (1, 7, 997, 1 << 20))
def test_iter_trace_chunks_concat_identical(fname, kw, chunk_size):
    path = DATA / fname
    one = parse_trace_file(path, **kw)
    chunks = list(iter_trace_chunks(path, chunk_size=chunk_size, **kw))
    assert all(c.dtype == np.int64 for c in chunks)
    assert np.array_equal(np.concatenate(chunks), one)
    # and the loader (modulo its cache) agrees
    assert np.array_equal(load_trace_file(path, cache=False, **kw), one)


def test_iter_trace_chunks_carry_remap_across_files(tmp_path):
    """An externally supplied remap dict continues one id space across
    several files — the multi-file-log use case."""
    a = get_trace("gradle", 1_000, seed=9)
    b = get_trace("gradle", 1_000, seed=10)
    pa = make_trace_file.write_trace_file(a, tmp_path / "a.log", "keys")
    pb = make_trace_file.write_trace_file(b, tmp_path / "b.log", "keys")
    mapping = {}
    got = np.concatenate(
        list(iter_trace_chunks(pa, chunk_size=128, remap=mapping)) +
        list(iter_trace_chunks(pb, chunk_size=128, remap=mapping)))
    pc = make_trace_file.write_trace_file(np.concatenate([a, b]),
                                          tmp_path / "c.log", "keys")
    assert np.array_equal(got, parse_trace_file(pc))


@pytest.mark.parametrize("fname,kw", SAMPLES)
@pytest.mark.parametrize("head,stride", ((None, 1), (1_000, 1), (None, 3),
                                         (500, 7), (0, 2)))
def test_stream_trace_info_exact(fname, kw, head, stride):
    path = DATA / fname
    _, want = load_trace_file(path, cache=False, with_info=True,
                              head=head, stride=stride, **kw)
    got = stream_trace_info(path, head=head, stride=stride,
                            chunk_size=997, **kw)
    # dataclass equality: every field, top1pct_share to the last float
    assert got == want


def test_stream_trace_info_validation():
    with pytest.raises(ValueError):
        stream_trace_info(DATA / "sample_recency.log.gz", stride=0)


# ---------------------------------------------------------------------------
# Chunk-written trace files: byte-reproducible at any write chunking
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ("keys", "csv"))
@pytest.mark.parametrize("compress", (False, True))
def test_write_trace_file_chunking_invariant(tmp_path, monkeypatch, fmt,
                                             compress):
    ids = get_trace("wiki", 3_000, seed=4, catalog=800)
    # identical basenames: gzip embeds the output name in its header
    p1 = make_trace_file.write_trace_file(ids, tmp_path / "a" / "t.log",
                                          fmt, compress=compress)
    monkeypatch.setattr(make_trace_file, "WRITE_CHUNK", 7)
    p2 = make_trace_file.write_trace_file(ids, tmp_path / "b" / "t.log",
                                          fmt, compress=compress)
    assert p1.read_bytes() == p2.read_bytes()
    # and regeneration is deterministic outright (gzip mtime zeroed)
    p3 = make_trace_file.write_trace_file(ids, tmp_path / "c" / "t.log",
                                          fmt, compress=compress)
    assert p1.read_bytes() == p3.read_bytes()
