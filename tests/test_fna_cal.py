"""Beyond-paper policy: fna_cal (empirical exclusion-probability feedback).

The deployable configuration must DOMINATE: never worse than FNO (it can
always learn nu ~ 1 and stop probing) and at least as good as paper-FNA in
the staleness regime.
"""
import numpy as np
import pytest

from repro.cachesim import SimConfig, get_trace
from repro.cachesim.simulator import run_policies

N = 40_000


@pytest.mark.parametrize("trace_name,interval", [
    ("wiki", 512), ("wiki", 2048), ("gradle", 128), ("gradle", 1024),
])
def test_fna_cal_dominates(trace_name, interval):
    trace = get_trace(trace_name, N, seed=3)
    base = SimConfig(cache_size=2000, update_interval=interval)
    res = run_policies(trace, base, policies=("fna", "fna_cal", "fno", "pi"))
    cal, fno, fna, pi = (res[k].mean_cost for k in ("fna_cal", "fno", "fna", "pi"))
    assert pi <= cal + 1e-9
    assert cal <= fno * 1.03, (cal, fno)       # never worse than FNO
    assert cal <= fna * 1.03, (cal, fna)       # never worse than paper-FNA


def test_fna_cal_big_win_on_recency_bias():
    trace = get_trace("gradle", N, seed=3)
    base = SimConfig(cache_size=2000, update_interval=512)
    res = run_policies(trace, base, policies=("fna_cal", "fno"))
    assert res["fna_cal"].mean_cost < 0.75 * res["fno"].mean_cost
