"""Data pipeline determinism/resume + optimizer behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataConfig, Prefetcher, SyntheticLMData
from repro.optim import OptConfig, global_norm, init_train_state, lr_at, make_train_step
from repro.optim.adamw import clip_by_global_norm


def test_batch_is_pure_function_of_step():
    d1 = SyntheticLMData(DataConfig(seed=5))
    d2 = SyntheticLMData(DataConfig(seed=5))
    for s in (0, 3, 1000):
        b1, b2 = d1.batch_at(s), d2.batch_at(s)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d1.batch_at(0)["tokens"], d1.batch_at(1)["tokens"])


def test_labels_are_next_tokens():
    d = SyntheticLMData(DataConfig())
    b = d.batch_at(0)
    # the affine structure: most labels equal (a*tok + b) % V
    pred = (31 * b["tokens"] + 7) % 256
    agree = (pred == b["labels"]).mean()
    assert agree > 0.8  # 10% corruption


def test_prefetcher_matches_sync_iteration():
    d = SyntheticLMData(DataConfig(seed=2))
    pf = Prefetcher(d.iterate(start_step=4), depth=2)
    try:
        for s in range(4, 8):
            got = pf.get()
            np.testing.assert_array_equal(got["tokens"], d.batch_at(s)["tokens"])
    finally:
        pf.close()


def test_lr_schedule():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(lr_at(cfg, 0)) == 0.0
    assert float(lr_at(cfg, 10)) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr_at(cfg, 100)) == pytest.approx(1e-4, rel=1e-2)
    assert float(lr_at(cfg, 55)) < 1e-3


def test_global_norm_clip():
    tree = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, g = clip_by_global_norm(tree, 1.0)
    assert float(g) == pytest.approx(10.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_train_step_decreases_loss_quadratic():
    """Sanity: AdamW minimises a simple supervised proxy via model protocol."""

    class Toy:
        def loss(self, params, batch):
            pred = batch["x"] @ params["w"]
            l = jnp.mean((pred - batch["y"]) ** 2)
            return l, {"loss": l}

    cfg = OptConfig(lr=0.05, warmup_steps=1, total_steps=200, weight_decay=0.0)
    step = jax.jit(make_train_step(Toy(), cfg))
    k = jax.random.PRNGKey(0)
    w_true = jax.random.normal(k, (8, 1))
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 8))
    batch = {"x": x, "y": x @ w_true}
    state = init_train_state({"w": jnp.zeros((8, 1))}, cfg)
    first = None
    for _ in range(60):
        state, m = step(state, batch)
        first = first if first is not None else float(m["loss"])
    assert float(m["loss"]) < 0.05 * first


def test_int8_ef_compression_trains():
    class Toy:
        def loss(self, params, batch):
            l = jnp.mean((params["w"] - 3.0) ** 2)
            return l, {"loss": l}

    cfg = OptConfig(lr=0.1, warmup_steps=1, total_steps=100, weight_decay=0.0,
                    compression="int8_ef")
    step = jax.jit(make_train_step(Toy(), cfg))
    state = init_train_state({"w": jnp.zeros((2048,))}, cfg)
    assert "ef" in state
    for _ in range(50):
        state, m = step(state, {})
    assert float(m["loss"]) < 0.05
