"""Algorithm 1 (HoCS_FNA) end to end in the homogeneous simulator.

In a fully-homogeneous system (equal costs, shared workload statistics),
Algorithm 1 and the heterogeneous Algorithm-2 machinery must agree — the
paper proves HoCS_FNA optimal for exactly this case (Thm. 4)."""
import dataclasses

import pytest

from repro.cachesim import SimConfig, get_trace
from repro.cachesim.simulator import run_policies


def test_hocs_close_to_cs_fna_on_homogeneous_system():
    trace = get_trace("gradle", 30_000, seed=5)
    base = SimConfig(n_caches=4, costs=(2.0, 2.0, 2.0, 2.0), cache_size=2000,
                     update_interval=512)
    res = run_policies(trace, base, policies=("hocs", "fna", "fno", "pi"))
    # HoCS uses pooled (pi, nu); CS_FNA per-cache estimates. On a
    # homogeneous system they land within a few percent of each other,
    # and both beat FNO under staleness.
    assert res["hocs"].mean_cost <= res["fna"].mean_cost * 1.10
    assert res["hocs"].mean_cost < res["fno"].mean_cost
    assert res["pi"].mean_cost <= res["hocs"].mean_cost
    assert res["hocs"].neg_accesses > 0  # it exercises negative accesses
