"""Hierarchical cache topologies (``repro.cachesim.topology``).

The load-bearing property is DEGENERACY: a depth-1 PATH with zero hop
knobs is the flat engine, bit for bit — every pre-existing golden
scenario x policy reproduces through the ``TierSystem`` path exactly.
On top of that: fast == reference on deep paths/trees (hand-sized
here; the pinned cells live in the ``topo_path`` / ``topo_tree`` golden
files), hand-computed queue/latency/origin accounting, cross-cell tier
sweep sharing (observed via ``SWEEPS_COMPUTED`` and the artifact
store), and the satellite validations (``chunk_size``, benchmark
``--only``).
"""
import dataclasses
import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.cachesim import SimConfig, SimResult, Simulator, get_scenario
from repro.cachesim.scenarios import GOLDEN_SCENARIOS
from repro.cachesim.simulator import run_policies
from repro.cachesim.store import ArtifactStore
from repro.cachesim.sweep import cell_label, hashable_label, run_grid
from repro.cachesim.systemstate import SystemTrace
from repro.cachesim.topology import (
    TopoConfig,
    TopoResult,
    edge_assignment,
    run_topo_grid,
    run_topology,
    topo_cell,
)
from repro.cachesim.traces import get_trace
import repro.cachesim.systemstate as systemstate

REPO = Path(__file__).resolve().parent.parent
GOLDEN_DIR = Path(__file__).parent / "golden"
SIM_FIELDS = tuple(f.name for f in dataclasses.fields(SimResult))

#: the pre-existing flat golden scenarios (topology ones excluded —
#: those pin TopoResult cells directly)
FLAT_GOLDEN = tuple(n for n in GOLDEN_SCENARIOS
                    if get_scenario(n).topology is None)


def _wrap_depth1(cfg: SimConfig) -> TopoConfig:
    """The degenerate hierarchy: one tier, no knobs — must BE ``cfg``."""
    return TopoConfig(base=cfg, kind="path", depth=1)


# ---------------------------------------------------------------------------
# Degeneracy: depth-1 PATH == flat engine, on every flat golden scenario
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", FLAT_GOLDEN)
def test_depth1_path_reproduces_flat_golden(name):
    """Every committed flat (trace, cell, policy) SimResult accumulator,
    reproduced bit-for-bit by the FAST engine running through the
    topology path (TierSystem sweep + DecisionPlan.selections + the
    shared topology accounting) at depth 1."""
    payload = json.loads((GOLDEN_DIR / f"{name}.json").read_text())
    sc = get_scenario(name)
    traces, values = sc.golden_grid()
    base = _wrap_depth1(sc.config(engine="fast", **sc.golden_base))
    grid = run_grid(traces, base, sc.axis, values,
                    policies=sc.policies, share_system=True)
    assert payload["cells"], name
    for cell in payload["cells"]:
        res = grid[(cell["trace"], hashable_label(cell["label"]))]
        topo_res = res[cell["policy"]]
        assert isinstance(topo_res, TopoResult)
        for f, want in cell["result"].items():
            got = getattr(topo_res, f)
            assert got == want, (
                f"{name}/{cell['trace']}/{cell['label']}/{cell['policy']}"
                f": field {f!r}: depth-1 topology {got!r} != flat golden "
                f"{want!r}")


@pytest.mark.parametrize("engine", ("fast", "reference"))
def test_depth1_path_matches_flat_run_policies(engine):
    """Direct flat-vs-wrapped comparison on BOTH engines, including the
    advertisement totals the golden files don't pin."""
    trace = get_trace("gradle", 2_500, seed=3)
    cfg = SimConfig(engine=engine, cache_size=500, update_interval=120,
                    advert_policy="self_adjusting", advert_bandwidth=0.5,
                    advert_threshold=0.05, advert_check=16)
    policies = ("fna", "fna_cal", "fno", "pi")
    flat = run_policies(trace, cfg, policies=policies)
    topo = run_topology(np.asarray(trace, np.uint64), _wrap_depth1(cfg),
                        policies)
    for p in policies:
        for f in SIM_FIELDS:
            assert getattr(topo[p], f) == getattr(flat[p], f), (engine, p, f)
        assert topo[p].advert_events == flat[p].advert_events, (engine, p)
        assert topo[p].advert_bytes == flat[p].advert_bytes, (engine, p)
        # the hierarchy metrics collapse to their degenerate values
        assert topo[p].tier_arrivals == [len(trace)]
        assert topo[p].rejected == 0
        assert topo[p].total_latency == 0.0
        assert topo[p].origin_fetches == len(trace) - topo[p].hits


# ---------------------------------------------------------------------------
# Deep topologies: fast == reference, and the accounting is hand-checkable
# ---------------------------------------------------------------------------

def _asdict_panel(out):
    return {p: dataclasses.asdict(r) for p, r in out.items()}


@pytest.mark.parametrize("kind,kw", (
    ("path", dict(depth=3)),
    ("tree", dict(depth=2, fanout=3)),
))
def test_deep_fast_matches_reference(kind, kw):
    trace = np.asarray(get_trace("gradle", 2_000, seed=5), np.uint64)
    topo = TopoConfig(
        base=SimConfig(engine="fast", update_interval=80),
        kind=kind,
        tiers=(dict(cache_size=200, tier_latency=1.0, hop_penalty=4.0,
                    queue_capacity=30, queue_window=32),
               dict(cache_size=500, update_interval=160, tier_latency=8.0),
               dict(cache_size=900, update_interval=320)),
        origin_penalty=80.0, origin_latency=32.0, **kw)
    policies = ("fna", "fna_cal", "pi")
    fast = run_topology(trace, topo, policies)
    ref = run_topology(
        trace, dataclasses.replace(
            topo, base=dataclasses.replace(topo.base, engine="reference")),
        policies)
    assert _asdict_panel(fast) == _asdict_panel(ref)
    for p in policies:
        assert fast[p].advert_events == ref[p].advert_events
        assert fast[p].advert_bytes == ref[p].advert_bytes


@pytest.mark.parametrize("engine", ("fast", "reference"))
def test_hand_computed_queue_latency_origin(engine):
    """Four arrivals of one key through a single queued tier: every
    accounting term (admission, hit, rejection, origin penalty/latency)
    hand-derived.  in_dj = F,T,T,T (big cache); the 1-per-2 window
    admits arrivals 0 and 2; ``pi`` probes the designated cache only
    when resident, so arrival 2 is the single hit."""
    trace = np.asarray([7, 7, 7, 7], np.uint64)
    cfg = SimConfig(engine=engine, cache_size=1_000)
    topo = TopoConfig(
        base=cfg, kind="path", depth=1,
        tiers=(dict(queue_capacity=1, queue_window=2, tier_latency=2.0),),
        origin_penalty=50.0, origin_latency=5.0)
    res = run_topology(trace, topo, ("pi",))["pi"]
    dj = 7 % cfg.n_caches
    probe_cost = float(cfg.costs[dj])
    assert res.n_requests == 4
    assert res.tier_arrivals == [4]
    assert res.tier_rejected == [2] and res.rejected == 2
    assert res.tier_hits == [1] and res.hits == 1
    assert res.origin_fetches == 3
    # cost: one admitted resident probe + three origin fetches
    assert res.total_cost == 3 * 50.0 + probe_cost
    # latency: every arrival pays the tier, the unserved pay the origin
    assert res.total_latency == 4 * 2.0 + 3 * 5.0
    assert res.pos_accesses + res.neg_accesses == 1
    assert res.mean_latency == res.total_latency / 4
    assert res.rejection_rate == 2 / 4
    for key in ("mean_latency", "rejection_rate", "origin_fetches"):
        assert key in res.to_dict()


def test_tree_leaf_routing_partitions_trace():
    """Leaf assignment is a deterministic partition of the client
    stream, and level-1 arrivals are exactly the leaves' residency
    misses."""
    trace = np.asarray(get_trace("wiki", 3_000, seed=2), np.uint64)
    leaves = edge_assignment(trace, 4)
    assert leaves.shape == trace.shape
    assert int(np.bincount(leaves, minlength=4).sum()) == trace.shape[0]
    assert set(np.unique(leaves)) <= set(range(4))
    topo = TopoConfig(base=SimConfig(engine="fast"), kind="tree",
                      depth=2, fanout=4,
                      tiers=(dict(cache_size=300),
                             dict(cache_size=1_200)))
    res = run_topology(trace, topo, ("fna",))["fna"]
    assert res.tier_arrivals[0] == trace.shape[0]
    # forwarded = leaf arrivals minus leaf residents (policy-independent)
    assert res.tier_arrivals[1] == trace.shape[0] - sum(
        int(SystemTrace.compute(
            Simulator(topo.node_config(0, i)),
            trace[leaves == i]).in_dj.sum())
        for i in range(4))


# ---------------------------------------------------------------------------
# Cross-tier sweep sharing: the depth axis recomputes nothing it has seen
# ---------------------------------------------------------------------------

def _depth_axis_base() -> TopoConfig:
    return TopoConfig(
        base=SimConfig(engine="fast", update_interval=100),
        kind="path", depth=3,
        tiers=(dict(cache_size=250), dict(cache_size=600),
               dict(cache_size=1_100)))


def test_depth_axis_shares_tier_sweeps():
    """Sweeping depth (1, 2, 3) with one shared pool computes exactly
    one sweep per DISTINCT tier stream — 3 total, not 1 + 2 + 3 = 6 —
    and the shared grid is bit-identical to per-cell recompute."""
    traces = {"gradle": get_trace("gradle", 2_000, seed=7)}
    base = _depth_axis_base()
    before = systemstate.SWEEPS_COMPUTED
    shared = run_topo_grid(traces, base, "depth", (1, 2, 3),
                           policies=("fna", "pi"), share_system=True)
    assert systemstate.SWEEPS_COMPUTED - before == 3
    before = systemstate.SWEEPS_COMPUTED
    indep = run_topo_grid(traces, base, "depth", (1, 2, 3),
                          policies=("fna", "pi"), share_system=False)
    assert systemstate.SWEEPS_COMPUTED - before == 6
    assert set(shared) == set(indep)
    for key in shared:
        assert {p: dataclasses.asdict(r) for p, r in shared[key].items()} \
            == {p: dataclasses.asdict(r) for p, r in indep[key].items()}, key


def test_topology_store_reuses_tier_sweeps(tmp_path):
    """A store-backed grid persists every tier sweep; a SECOND grid over
    the same cells computes zero sweeps and returns identical results."""
    store = ArtifactStore(tmp_path / "store")
    traces = {"gradle": get_trace("gradle", 2_000, seed=7)}
    base = _depth_axis_base()
    first = run_topo_grid(traces, base, "depth", (1, 2, 3),
                          policies=("fna",), share_system=True, store=store)
    assert store.stats["sweep_misses"] == 3
    before = systemstate.SWEEPS_COMPUTED
    again = run_topo_grid(traces, base, "depth", (1, 2, 3),
                          policies=("fna",), share_system=True, store=store)
    assert systemstate.SWEEPS_COMPUTED - before == 0
    assert store.stats["sweep_hits"] >= 3
    for key in first:
        assert dataclasses.asdict(first[key]["fna"]) \
            == dataclasses.asdict(again[key]["fna"]), key


def test_topo_cell_routing_and_validation():
    base = _depth_axis_base()
    # TopoConfig field
    assert topo_cell(base, {"depth": 2}).depth == 2
    # tier knob broadcast vs per-depth distribution
    bcast = topo_cell(base, {"hop_penalty": 3.0})
    assert [bcast.tier_spec(d).hop_penalty for d in range(3)] == [3.0] * 3
    per = topo_cell(base, {"tier_latency": (1.0, 2.0, 4.0)})
    assert [per.tier_spec(d).tier_latency for d in range(3)] == [1.0, 2.0, 4.0]
    with pytest.raises(ValueError, match="length"):
        topo_cell(base, {"tier_latency": (1.0, 2.0)})
    # SimConfig field lands on the base and propagates to every tier
    sim = topo_cell(base, {"miss_penalty": 64.0})
    assert sim.base.miss_penalty == 64.0
    assert sim.origin_penalty_value() == 64.0
    with pytest.raises(ValueError, match="kind"):
        TopoConfig(base=base.base, kind="ring")
    with pytest.raises(ValueError, match="depth"):
        TopoConfig(base=base.base, depth=0)
    with pytest.raises(ValueError, match="fanout"):
        TopoConfig(base=base.base, kind="tree", fanout=0)
    with pytest.raises(ValueError, match="neither"):
        TopoConfig(base=base.base, tiers=(dict(cache_sz=10),))


# ---------------------------------------------------------------------------
# Satellites: chunk_size validation + benchmark --only validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", (0, -3, 2.5, True, "64"))
def test_compute_chunk_size_validated(bad):
    trace = get_trace("gradle", 50, seed=0)
    with pytest.raises(ValueError, match="chunk_size"):
        SystemTrace.compute(Simulator(SimConfig(engine="fast")), trace,
                            chunk_size=bad)


@pytest.mark.parametrize("bad", (0, -3, 2.5, True, "64"))
def test_iter_trace_chunks_chunk_size_validated_eagerly(bad, tmp_path):
    """The generator used to defer the error to the first next(); the
    bad argument must now raise AT THE CALL, file untouched."""
    from repro.cachesim.tracefiles import iter_trace_chunks
    with pytest.raises(ValueError, match="chunk_size"):
        iter_trace_chunks(tmp_path / "never_read.log", chunk_size=bad)


def test_benchmarks_only_unknown_section_errors():
    """``--only`` with an unknown section used to run NOTHING and exit
    0; it must argparse-error, naming the bad section and the valid
    ones."""
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "sim_bogus"],
        capture_output=True, text=True, cwd=REPO, env=env)
    assert proc.returncode == 2
    assert "unknown --only section" in proc.stderr
    assert "sim_bogus" in proc.stderr
    assert "sim_topology" in proc.stderr        # valid list shown
