"""Multi-device semantics via a subprocess with 8 forced host devices
(XLA_FLAGS must be set before jax import, so these run out of process).

Covers: sharded train step numerics == single-device, elastic restore onto
a smaller mesh, and the int8 compressed_psum collective under shard_map.
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from functools import partial

assert len(jax.devices()) == 8

from repro.configs import get_config
from repro.models import get_model, make_concrete_batch
from repro.optim import OptConfig, init_train_state, make_train_step
from repro.distributed.sharding import param_shardings
from repro.distributed.ft import elastic_mesh
from repro.checkpoint import save, restore
from repro.distributed.compression import compressed_psum
from jax.experimental.shard_map import shard_map

# ---- 1) sharded train step == single-device train step ----
cfg = get_config("smollm-135m").reduced()
model = get_model(cfg)
ocfg = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
step = make_train_step(model, ocfg)
params = model.init(jax.random.PRNGKey(0))
state = init_train_state(params, ocfg)
batch = make_concrete_batch(cfg, 4, 32, jax.random.PRNGKey(1))

ref_state, ref_metrics = jax.jit(step)(jax.tree.map(jnp.copy, state), batch)

mesh = jax.make_mesh((2, 4), ("data", "model"))
psh = param_shardings(mesh, params, cfg.tie_embeddings)
state_sh = {"params": psh, "m": psh, "v": psh,
            "step": NamedSharding(mesh, P())}
batch_sh = {k: NamedSharding(mesh, P("data", None)) for k in batch}
st = jax.device_put(state, state_sh)
bt = jax.device_put(batch, batch_sh)
out_state, metrics = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None))(st, bt)
np.testing.assert_allclose(float(metrics["loss"]), float(ref_metrics["loss"]),
                           rtol=1e-4)
for a, b in zip(jax.tree.leaves(ref_state["params"]),
                jax.tree.leaves(out_state["params"])):
    np.testing.assert_allclose(np.asarray(a), np.asarray(jax.device_get(b)),
                               rtol=2e-4, atol=2e-4)
print("OK sharded==single")

# ---- 2) elastic restore onto a smaller mesh ----
import tempfile
with tempfile.TemporaryDirectory() as d:
    save(jax.device_get(out_state), d, step=1)
    small = elastic_mesh(model_dim=2, devices=jax.devices()[:4])
    psh2 = param_shardings(small, params, cfg.tie_embeddings)
    sh2 = {"params": psh2, "m": psh2, "v": psh2,
           "step": NamedSharding(small, P())}
    abs_state = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
    restored = restore(d, abs_state, shardings=sh2)
    for a, b in zip(jax.tree.leaves(out_state["params"]),
                    jax.tree.leaves(restored["params"])):
        np.testing.assert_allclose(np.asarray(jax.device_get(a)),
                                   np.asarray(jax.device_get(b)), rtol=1e-6)
print("OK elastic reshard")

# ---- 3) compressed int8 psum == float psum (within quant error) ----
mesh1d = jax.make_mesh((8,), ("data",))
x = jax.random.normal(jax.random.PRNGKey(2), (8, 4096))

@partial(shard_map, mesh=mesh1d, in_specs=P("data", None), out_specs=P("data", None))
def f_comp(xl):
    return compressed_psum(xl[0], "data")[None]

@partial(shard_map, mesh=mesh1d, in_specs=P("data", None), out_specs=P("data", None))
def f_exact(xl):
    return jax.lax.psum(xl[0], "data")[None]

got = np.asarray(f_comp(x))[0]
want = np.asarray(f_exact(x))[0]
scale = np.abs(x).max() / 127.0 * 8
assert np.abs(got - want).max() <= scale * 1.05, np.abs(got - want).max()
print("OK compressed_psum")

# ---- 4) MoE shard_map EP path == pure-jit dispatch path ----
import dataclasses
from repro.distributed.sharding import MeshRules, activation_rules
cfgm = dataclasses.replace(get_config("qwen3-moe-30b-a3b").reduced(),
                           moe_mode="dispatch", capacity_factor=8.0,
                           seq_parallel=True)
mm = get_model(cfgm)
mparams = mm.init(jax.random.PRNGKey(3))
mbatch = make_concrete_batch(cfgm, 4, 32, jax.random.PRNGKey(4))
ref_loss, _ = jax.jit(mm.loss)(mparams, mbatch)   # no rules -> pure-jit path

mesh2 = jax.make_mesh((2, 4), ("data", "model"))
psh2 = param_shardings(mesh2, mparams, cfgm.tie_embeddings)
bsh2 = {k: NamedSharding(mesh2, P("data", *([None] * (v.ndim - 1))))
        for k, v in mbatch.items()}
rules = MeshRules(mesh=mesh2, data_axes=("data",))
with activation_rules(rules):
    loss_fn = jax.jit(mm.loss, in_shardings=(psh2, bsh2))
    sm_loss, _ = loss_fn(jax.device_put(mparams, psh2),
                         jax.device_put(mbatch, bsh2))
    # gradients flow through the a2a path
    g = jax.jit(jax.grad(lambda pp, bb: mm.loss(pp, bb)[0]),
                in_shardings=(psh2, bsh2))(jax.device_put(mparams, psh2),
                                           jax.device_put(mbatch, bsh2))
gn = sum(float(jnp.sum(jnp.square(l.astype(jnp.float32)))) for l in jax.tree.leaves(g))
assert gn > 0 and np.isfinite(gn)
np.testing.assert_allclose(float(sm_loss), float(ref_loss), rtol=2e-3)
print("OK moe shard_map")
"""


@pytest.mark.slow
def test_multidevice_semantics():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], cwd=ROOT, env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout[-3000:]}\nstderr:\n{r.stderr[-3000:]}"
    for marker in ("OK sharded==single", "OK elastic reshard",
                   "OK compressed_psum", "OK moe shard_map"):
        assert marker in r.stdout
