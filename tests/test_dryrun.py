"""Dry-run machinery tests.

The 512-device lowering itself runs in a subprocess (device count locks at
first jax init).  One small cell compiles end to end and the JSON contract
is checked; mesh/spec helpers are unit-tested in-process.
"""
import json
import os
import subprocess
import sys
import tempfile
from pathlib import Path

import pytest

from repro.configs import ARCHS, SHAPES_BY_NAME, cells, get_config, shape_applicable

ROOT = Path(__file__).resolve().parent.parent


def test_cell_enumeration_rules():
    cs = list(cells())
    assert len(cs) == 32  # 10 archs x 3 shapes + 2 ssm/hybrid long_500k
    assert ("mamba2-370m", "long_500k") in cs
    assert ("zamba2-7b", "long_500k") in cs
    assert ("deepseek-coder-33b", "long_500k") not in cs
    for arch in ARCHS:
        assert (arch, "train_4k") in cs and (arch, "decode_32k") in cs


def test_input_specs_cover_all_cells():
    import jax
    from repro.configs import get_shape
    from repro.models import input_specs
    for arch, shape_name in cells():
        sp = input_specs(get_config(arch), get_shape(shape_name))
        leaves = jax.tree.leaves(sp)
        assert leaves and all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)


def test_param_pspec_divisibility():
    """Every generated spec must divide its dim by the mesh axis size."""
    import jax
    from repro.distributed.sharding import param_pspecs
    from repro.models import get_model
    axis_sizes = {"model": 16, "data": 16, "pod": 2}
    for arch in ARCHS:
        cfg = get_config(arch)
        model = get_model(cfg)
        params = jax.eval_shape(lambda m=model: m.init(jax.random.PRNGKey(0)))
        specs = param_pspecs(params, cfg.tie_embeddings, axis_sizes)
        for (path, leaf), (_, spec) in zip(
                jax.tree_util.tree_flatten_with_path(params)[0],
                jax.tree_util.tree_flatten_with_path(
                    specs, is_leaf=lambda s: hasattr(s, "index"))[0]):
            for dim, ax in enumerate(spec):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                size = 1
                for a in axes:
                    size *= axis_sizes[a]
                assert leaf.shape[dim] % size == 0, (arch, path, spec, leaf.shape)


@pytest.mark.slow
def test_dryrun_single_cell_subprocess(tmp_path):
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "smollm-135m",
         "--shape", "decode_32k", "--mesh", "single", "--out", str(tmp_path)],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    rec = json.loads((tmp_path / "smollm-135m__decode_32k__single.json").read_text())
    assert rec["chips"] == 256
    rl = rec["roofline"]
    assert rl["flops_per_device"] > 0
    assert rl["hbm_bytes_per_device"] > 0
    assert rl["bottleneck"] in ("compute", "memory", "collective")
    assert rec["memory_analysis"]["temp_size_in_bytes"] > 0
