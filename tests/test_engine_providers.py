"""Decision-plan layer tests: registry dispatch + batched-table parity.

The provider registry (``repro.cachesim.engine``) replaced the fast
engine's ``if/elif`` policy ladder; these tests pin

  * which provider each configuration dispatches to (and that
    out-of-budget configurations dispatch to ``None`` — the reference
    fallback), plus registry extensibility;
  * seeded-random parity of the batched table builders against the
    scalar loops they replaced: ``hocs_fna_batched`` vs the scalar
    Algorithm-1 version loop, and the calibrated engine's batched bridge
    tables (``selection_tables`` backend="numpy" /
    ``exhaustive_tables``) vs per-pattern scalar ``mask_fn`` rows (the
    hypothesis-driven versions of these properties live in
    ``tests/test_policy_properties.py`` and skip when hypothesis is
    absent — these backstops always run);
  * the stacked cross-cell build (``selection_tables_cells``) slicing
    bit-identically to per-cell calls;
  * the ``sweep_records`` axis-name collision fix.
"""
import numpy as np

from repro.cachesim import SimConfig, SimResult
from repro.cachesim.engine import (
    DecisionPlan,
    PROVIDERS,
    plan_for,
    register_provider,
)
from repro.cachesim.sweep import axis_column, sweep_records
from repro.core.batched import (
    exhaustive_tables,
    exhaustive_tables_cells,
    hocs_fna_batched,
    hocs_selection_tables,
    hocs_selection_tables_cells,
    selection_tables,
    selection_tables_cells,
)
from repro.core.policies import ds_pgm_mask, exhaustive_mask, hocs_fna



# ---------------------------------------------------------------------------
# Registry dispatch
# ---------------------------------------------------------------------------

def _plan_name(**kw):
    plan = plan_for(SimConfig(**kw))
    return None if plan is None else plan.name


def test_registry_dispatch():
    """Every configuration lands on the documented provider; anything
    outside every budget lands on None (the reference fallback)."""
    assert _plan_name(policy="fna") == "ds_pgm"
    assert _plan_name(policy="fno") == "ds_pgm"
    assert _plan_name(policy="hocs", costs=(2.0, 2.0, 2.0)) == "hocs"
    assert _plan_name(policy="pi") == "pi"
    assert _plan_name(policy="fna_cal") == "fna_cal"
    assert _plan_name(policy="fna_cal", alg="exhaustive") == "fna_cal"
    assert _plan_name(policy="fna", alg="exhaustive", n_caches=4) == \
        "exhaustive"
    # the chunked batched build covers the full table budget (n <= 12):
    # configurations that used to fall through to the scalar loop at
    # 8 < n <= 12 now dispatch to the batched enumeration
    assert _plan_name(policy="fna", alg="exhaustive", n_caches=9) == \
        "exhaustive"
    assert _plan_name(policy="fno", alg="exhaustive", n_caches=12) == \
        "exhaustive"
    assert _plan_name(policy="fna_cal", alg="exhaustive", n_caches=9) == \
        "fna_cal"
    # out of every budget -> reference loop
    assert _plan_name(policy="fna", n_caches=13) is None
    assert _plan_name(policy="pi", n_caches=13) is None
    assert _plan_name(policy="fna_cal", alg="exhaustive", n_caches=13) is None


def test_register_provider_shadows_builtin():
    class Shadow(DecisionPlan):
        name = "shadow"

        def matches(self, cfg):
            return cfg.policy == "pi"

    shadow = Shadow()
    register_provider(shadow)
    try:
        assert plan_for(SimConfig(policy="pi")) is shadow
        assert _plan_name(policy="fna") == "ds_pgm"
    finally:
        PROVIDERS.remove(shadow)
    assert _plan_name(policy="pi") == "pi"


# ---------------------------------------------------------------------------
# Seeded-random parity backstops (the hypothesis-driven versions live in
# tests/test_policy_properties.py; these run even without hypothesis)
# ---------------------------------------------------------------------------

def test_hocs_batched_mirror_matches_scalar_seeded():
    rng = np.random.default_rng(11)
    for _ in range(200):
        n = int(rng.integers(1, 10))
        pi, nu = float(rng.uniform(0, 1)), float(rng.uniform(0, 1))
        M = float(rng.uniform(1.5, 1000.0))
        nx = np.arange(n + 1, dtype=np.int64)
        r0b, r1b = hocs_fna_batched(nx, n, pi, nu, M)
        for x in range(n + 1):
            assert (int(r0b[x]), int(r1b[x])) == hocs_fna(x, n, pi, nu, M), \
                (n, pi, nu, M, x)


def test_fna_cal_bridge_tables_match_scalar_seeded():
    rng = np.random.default_rng(12)
    for _ in range(60):
        n = int(rng.integers(1, 5))
        costs = rng.uniform(0.05, 5.0, n).tolist()
        rp = rng.uniform(0.0, 1.0, n).tolist()
        rn = rng.uniform(0.0, 1.0, n).tolist()
        M = float(rng.uniform(1.5, 1000.0))
        pow2 = (1 << np.arange(n)).astype(np.int64)
        ds_tab = (selection_tables(costs, [rp], [rn], M, backend="numpy")
                  .reshape(-1, n) @ pow2)
        ex_tab = exhaustive_tables(costs, [rp], [rn], M).reshape(-1)
        for p in range(1 << n):
            rhos = [rp[j] if (p >> j) & 1 else rn[j] for j in range(n)]
            assert ds_tab[p] == ds_pgm_mask(costs, rhos, M), (p, costs, M)
            assert ex_tab[p] == exhaustive_mask(costs, rhos, M), (p, costs, M)


# ---------------------------------------------------------------------------
# Stacked cross-cell build == per-cell builds
# ---------------------------------------------------------------------------

def test_selection_tables_cells_bit_identical_to_per_cell():
    rng = np.random.default_rng(3)
    n, v = 3, 23
    pi = rng.uniform(0.0, 1.0, (v, n))
    nu = rng.uniform(0.0, 1.0, (v, n))
    cells = [(rng.uniform(0.5, 5.0, n).tolist(),
              float(rng.uniform(10.0, 800.0)), bool(i % 2))
             for i in range(7)]
    stacked = selection_tables_cells(
        [c for c, _, _ in cells], pi, nu,
        [m for _, m, _ in cells], [f for _, _, f in cells])
    for i, (c, m, f) in enumerate(cells):
        assert np.array_equal(stacked[i], selection_tables(c, pi, nu, m,
                                                           fno=f)), i


def test_selection_tables_cells_chunked_matches_unchunked():
    """Tiny max_rows forces the per-chunk path; rows are independent so
    the output must not change."""
    rng = np.random.default_rng(4)
    n, v = 3, 5
    pi = rng.uniform(0.0, 1.0, (v, n))
    nu = rng.uniform(0.0, 1.0, (v, n))
    costs = [rng.uniform(0.5, 5.0, n).tolist() for _ in range(4)]
    pens = [50.0, 100.0, 200.0, 400.0]
    fnos = [False, True, False, True]
    full = selection_tables_cells(costs, pi, nu, pens, fnos)
    tiny = selection_tables_cells(costs, pi, nu, pens, fnos, max_rows=1)
    assert np.array_equal(full, tiny)


def test_exhaustive_tables_cells_bit_identical_to_per_cell():
    """The stacked subset-DP build (per-row penalty seeded into the DP
    product) must reproduce each per-cell exhaustive_tables call exactly
    — rows are independent and the penalty enters only the seed, so the
    IEEE operation order per row is unchanged."""
    rng = np.random.default_rng(5)
    n, v = 3, 11
    pi = rng.uniform(0.0, 1.0, (v, n))
    nu = rng.uniform(0.0, 1.0, (v, n))
    costs = rng.uniform(0.5, 5.0, n).tolist()
    pens = [10.0, 50.0, 100.0, 400.0, 900.0]
    for fno in (False, True):
        stacked = exhaustive_tables_cells(costs, pi, nu, pens, fno=fno)
        assert stacked.shape == (len(pens), v, 1 << n)
        for i, m in enumerate(pens):
            assert np.array_equal(
                stacked[i], exhaustive_tables(costs, pi, nu, m, fno=fno)), \
                (fno, i)


def test_exhaustive_tables_cells_chunked_matches_unchunked():
    rng = np.random.default_rng(6)
    n, v = 3, 4
    pi = rng.uniform(0.0, 1.0, (v, n))
    nu = rng.uniform(0.0, 1.0, (v, n))
    costs = rng.uniform(0.5, 5.0, n).tolist()
    pens = [25.0, 100.0, 500.0]
    full = exhaustive_tables_cells(costs, pi, nu, pens)
    tiny = exhaustive_tables_cells(costs, pi, nu, pens, chunk=1)
    assert np.array_equal(full, tiny)


def test_hocs_selection_tables_cells_matches_single_cell():
    """The C-cell tiling (np.tile/np.repeat row layout) must place each
    penalty's rows exactly where the single-cell build computes them."""
    rng = np.random.default_rng(7)
    n, v = 4, 9
    pi = rng.uniform(0.0, 1.0, (v, n))
    nu = rng.uniform(0.0, 1.0, (v, n))
    pens = [10.0, 75.0, 300.0, 1000.0]
    stacked = hocs_selection_tables_cells(pi, nu, pens)
    assert stacked.shape == (len(pens), v, 1 << n)
    for i, m in enumerate(pens):
        assert np.array_equal(stacked[i],
                              hocs_selection_tables(pi, nu, m)), i


# ---------------------------------------------------------------------------
# sweep_records: axis-name collision (satellite fix)
# ---------------------------------------------------------------------------

def test_sweep_records_prefixes_colliding_axis():
    """An axis label that collides with a SimResult.to_dict() key (or the
    trace column) must not be silently overwritten — it lands in a
    prefixed column instead."""
    res = SimResult(policy="fna", n_requests=7, total_cost=21.0, hits=3)
    grid = {("gradle", 123): {"fna": res}}
    for axis in ("mean_cost", "policy", "n", "trace"):
        assert axis_column(axis) == f"axis_{axis}"
        recs = sweep_records(grid, axis=axis)
        assert recs[0][f"axis_{axis}"] == 123
        # the result field keeps its own value
        assert recs[0]["policy"] == "fna"
        assert recs[0]["n"] == 7
        assert recs[0]["trace"] == "gradle"
    # a non-colliding axis keeps its bare name
    assert axis_column("miss_penalty") == "miss_penalty"
    assert sweep_records(grid, axis="miss_penalty")[0]["miss_penalty"] == 123
