"""Unit + property tests for the paper's cost model and policies."""
import itertools
import math
import random

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property-based tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    CacheView,
    cs_fna,
    cs_fno,
    ds_pgm,
    exclusion_probabilities,
    exhaustive,
    expected_cost,
    hit_ratio_from_q,
    hocs_fna,
    is_sufficiently_accurate,
    perfect_information,
    phi_hat,
    positive_indication_ratio,
    rho_vector,
    service_cost,
)

from hypothesis import assume

probs = st.floats(0.001, 0.6)
hits = st.floats(0.01, 0.99)
# Theorem 4 / the inversion of Eq. (1) require a sufficiently-accurate
# system (FP + FN < 1, Sec. II); the strategies can exceed it jointly.


# ---------------------------------------------------------------------------
# Eqs. (1)-(3)
# ---------------------------------------------------------------------------

@settings(max_examples=200, deadline=None)
@given(h=hits, fp=probs, fn=probs)
def test_q_inversion_roundtrip(h, fp, fn):
    assume(fp + fn < 0.95)
    q = positive_indication_ratio(h, fp, fn)
    assert 0.0 <= q <= 1.0
    h2 = hit_ratio_from_q(q, fp, fn)
    assert abs(h - h2) < 1e-9


@settings(max_examples=200, deadline=None)
@given(h=hits, fp=probs, fn=probs)
def test_proposition_1_sufficiently_accurate_iff_nu_gt_pi(h, fp, fn):
    """Prop. 1: FP + FN < 1  <=>  nu > pi (for h in (0,1))."""
    pi, nu = exclusion_probabilities(h, fp, fn)
    if is_sufficiently_accurate(fp, fn):
        assert nu > pi - 1e-12
    # (converse needs exact arithmetic at the boundary; covered by construction)


@settings(max_examples=200, deadline=None)
@given(h=hits, fp=probs, fn=probs)
def test_bayes_consistency(h, fp, fn):
    """Law of total probability: q*(1-pi) + (1-q)*(1-nu) == h."""
    q = positive_indication_ratio(h, fp, fn)
    pi, nu = exclusion_probabilities(h, fp, fn)
    assert abs(q * (1 - pi) + (1 - q) * (1 - nu) - h) < 1e-9


# ---------------------------------------------------------------------------
# Algorithm 1 (HoCS_FNA) — optimality, Theorem 4
# ---------------------------------------------------------------------------

def brute_force_hocs(n_x, n, pi, nu, M):
    best = None
    for r1 in range(n_x + 1):
        for r0 in range(n - n_x + 1):
            v = phi_hat(r0, r1, nu, pi, M)
            if best is None or v < best[0] - 1e-12:
                best = (v, r0, r1)
    return best


@settings(max_examples=300, deadline=None)
@given(h=hits, fp=probs, fn=probs,
       n=st.integers(1, 12), n_x=st.integers(0, 12),
       M=st.floats(1.5, 1000.0))
def test_hocs_fna_matches_brute_force(h, fp, fn, n, n_x, M):
    assume(fp + fn < 0.95)  # sufficiently-accurate (Thm. 4 precondition)
    n_x = min(n_x, n)
    pi, nu = exclusion_probabilities(h, fp, fn)
    r0, r1 = hocs_fna(n_x, n, pi, nu, M)
    assert 0 <= r1 <= n_x and 0 <= r0 <= n - n_x
    v = phi_hat(r0, r1, nu, pi, M)
    best_v, _, _ = brute_force_hocs(n_x, n, pi, nu, M)
    assert v <= best_v + 1e-6, (v, best_v, r0, r1)


def test_proposition_5_negative_access_conditions():
    """Prop. 5(i): with n_x=0, a negative access helps iff nu < 1 - 1/M."""
    M = 100.0
    for nu in [0.5, 0.9, 0.985, 0.995]:
        r0, r1 = hocs_fna(0, 5, pi=0.5, nu=nu, miss_penalty=M)
        helps = nu < 1 - 1 / M
        assert (r0 >= 1) == helps, (nu, r0)


def test_proposition_6_no_access_when_fp_dominates():
    """If (1-h)FP >= h(1-FN)(M-1), best policy accesses nothing."""
    h, fp, fn, M = 0.01, 0.5, 0.1, 1.5
    assert (1 - h) * fp >= h * (1 - fn) * (M - 1)
    pi, nu = exclusion_probabilities(h, fp, fn)
    r0, r1 = hocs_fna(3, 5, pi, nu, M)
    assert r0 == 0 and r1 == 0


# ---------------------------------------------------------------------------
# Heterogeneous: DS_PGM vs exhaustive, Theorem 7 reduction
# ---------------------------------------------------------------------------

def _random_instance(rng, n):
    costs = [rng.uniform(1, 4) for _ in range(n)]
    rhos = [rng.uniform(0.0, 1.0) for _ in range(n)]
    M = rng.choice([10.0, 50.0, 100.0, 500.0])
    return costs, rhos, M


def test_ds_pgm_near_optimal_random():
    rng = random.Random(7)
    worst = 1.0
    for _ in range(400):
        n = rng.randint(1, 8)
        costs, rhos, M = _random_instance(rng, n)
        sel_a = ds_pgm(costs, rhos, M)
        sel_o = exhaustive(costs, rhos, M)
        ca = service_cost(costs, rhos, M, sel_a)
        co = service_cost(costs, rhos, M, sel_o)
        ratio = ca / max(co, 1e-12)
        worst = max(worst, ratio)
        # [14]: log(M)-approximation; empirically near 1
        assert ratio <= 1.0 + math.log(M), (costs, rhos, M)
    assert worst < 1.5  # paper: "close-to-optimal in practice"


def test_homogeneous_ds_pgm_equals_hocs():
    """On homogeneous inputs the heterogeneous machinery reduces to Alg. 1."""
    h, fp, fn, M, n = 0.6, 0.02, 0.3, 100.0, 6
    pi, nu = exclusion_probabilities(h, fp, fn)
    for n_x in range(n + 1):
        indications = [1] * n_x + [0] * (n - n_x)
        q = positive_indication_ratio(h, fp, fn)
        views = [CacheView(cost=1.0, fp=fp, fn=fn, q=q) for _ in range(n)]
        sel = cs_fna(views, indications, M, alg=exhaustive)
        r1 = sum(1 for j in sel if indications[j])
        r0 = sum(1 for j in sel if not indications[j])
        r0_star, r1_star = hocs_fna(n_x, n, pi, nu, M)
        assert phi_hat(r0, r1, nu, pi, M) == pytest.approx(
            phi_hat(r0_star, r1_star, nu, pi, M), abs=1e-6)


def test_cs_fna_dominates_cs_fno_in_expectation():
    """Theorem 7 consequence: with exact estimates and the SAME optimal
    subroutine, FNA expected cost <= FNO expected cost (FNO's feasible set
    is a subset of FNA's)."""
    rng = random.Random(3)
    for _ in range(200):
        n = rng.randint(1, 6)
        views = [CacheView(cost=rng.uniform(1, 3), fp=rng.uniform(0.001, 0.3),
                           fn=rng.uniform(0.0, 0.5), q=rng.uniform(0.05, 0.95))
                 for _ in range(n)]
        indications = [rng.random() < 0.4 for _ in range(n)]
        M = 100.0
        sel_a = cs_fna(views, indications, M, alg=exhaustive)
        sel_o = cs_fno(views, indications, M, alg=exhaustive)
        ca = expected_cost(views, indications, sel_a, M)
        co = expected_cost(views, indications, sel_o, M)
        assert ca <= co + 1e-9


def test_perfect_information():
    assert perfect_information([3, 1, 2], [True, False, True]) == [2]
    assert perfect_information([3, 1, 2], [False, False, False]) == []
    assert perfect_information([3, 1, 2], [True, True, True]) == [1]
