"""Unit tests for the loop-aware HLO cost model (launch/hlo_cost.py)."""
import pytest

from repro.launch.hlo_cost import analyze, parse_module

SYNTH = """\
HloModule test

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16] get-tuple-element(%p), index=1
  %w = f32[16,16] constant({...})
  %dot.1 = f32[8,16] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16] all-reduce(%dot.1), channel_id=1, replica_groups={}, to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (arg: f32[8,16]) -> f32[8,16] {
  %arg = f32[8,16] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,16]) tuple(%zero, %arg)
  %loop = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
  ROOT %out = f32[8,16] get-tuple-element(%loop), index=1
}
"""


def test_parse_module_finds_computations():
    comps = parse_module(SYNTH)
    assert {"body", "cond", "add", "main"} <= set(comps)
    kinds = [op.kind for op in comps["body"].ops]
    assert "dot" in kinds and "all-reduce" in kinds


def test_loop_aware_flops_and_collectives():
    r = analyze(SYNTH)
    # dot: 2 * 8*16 * 16 = 4096 flops, x12 trips
    assert r["flops"] == pytest.approx(4096 * 12)
    # all-reduce operand: 8*16*4 bytes, x12
    assert r["collectives"]["all-reduce"] == pytest.approx(8 * 16 * 4 * 12)
    assert r["collective_bytes"] == r["collectives"]["all-reduce"]


def test_trip_count_fallback_from_condition():
    txt = SYNTH.replace(', backend_config={"known_trip_count":{"n":"12"}}', "")
    r = analyze(txt)
    assert r["flops"] == pytest.approx(4096 * 12)  # recovered from compare const


def test_bytes_positive_and_bounded():
    r = analyze(SYNTH)
    assert r["bytes"] > 0
    # per-trip traffic is a handful of 512B tensors; sanity upper bound
    assert r["bytes"] < 1e6
